"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig9 fig14 # a subset
    PYTHONPATH=src python -m benchmarks.run --engine=events fig9
                                                       # event-driven engine
    PYTHONPATH=src python -m benchmarks.run --engine=events --bench=tails
                                 # per-priority-class p99/p999 tail rows
    PYTHONPATH=src python -m benchmarks.run --spec=my_experiment.json
                                 # a declarative ExperimentSpec file

Each benchmark prints ``name,metric,value`` CSV rows (plus section
headers).  Simulation benches replay bursty traces through the real
TokenScale control plane on the analytic cluster model; micro benches time
the real JAX engines on CPU (note: Pallas kernels execute in interpret
mode on CPU — wall numbers are correctness artifacts, the TPU story lives
in the dry-run roofline, EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (CHIPS, ExperimentSpec, InstanceSpec, OutputPredictor,
                        TokenScalePolicy, plan_convertible, profile)
from repro.core.autoscaler import ComboPolicy
from repro.core.velocity import BUCKETS
from repro.sim import get_trace, step_trace
from repro.sim.runner import (compare_engines, compare_policies, get_engine,
                              hetero_demo_spec, make_policy, run_policy,
                              run_spec)

ROWS: list[str] = []

# simulation engine used by every sim-shaped bench; --engine=events switches
# the whole harness to the discrete-event simulator (DESIGN.md)
ENGINE = "fluid"

# flight-recorder output base path (--trace-out=PATH); when set, the obs
# bench and --spec runs attach a recorder and write JSONL (+ Chrome-trace
# JSON) traces next to it.  None = telemetry off everywhere (default).
TRACE_OUT = None


def _trace_paths(tag: str) -> tuple[str, str]:
    """Derive per-run trace paths from --trace-out: base-<tag>.jsonl plus
    the Perfetto-loadable base-<tag>.chrome.json."""
    base, ext = os.path.splitext(TRACE_OUT)
    return (f"{base}-{tag}{ext or '.jsonl'}", f"{base}-{tag}.chrome.json")


def emit(bench: str, metric: str, value):
    if isinstance(value, float):
        value = f"{value:.6g}"
    row = f"{bench},{metric},{value}"
    ROWS.append(row)
    print(row, flush=True)


# ---------------------------------------------------------------------------
# Fig. 2/3 — burstiness of the traces + overprovisioning sweep
# ---------------------------------------------------------------------------

def fig3_overprovisioning():
    """% of tokens/requests beyond an X-times-average provisioned system."""
    for trace_name in ["azure_conv", "azure_code", "burstgpt1", "burstgpt2"]:
        trace = get_trace(trace_name, duration_s=300.0, rps=10.0, seed=0)
        ts = np.array([r.t for r in trace])
        toks = np.array([float(r.in_len) for r in trace])
        grid_n = 301
        per_sec_req = np.zeros(grid_n)
        per_sec_tok = np.zeros(grid_n)
        idx = np.clip(ts.astype(int), 0, grid_n - 1)
        np.add.at(per_sec_req, idx, 1.0)
        np.add.at(per_sec_tok, idx, toks)
        for x in (1, 2, 3, 4):
            cap_r = per_sec_req.mean() * x
            cap_t = per_sec_tok.mean() * x
            br = np.maximum(per_sec_req - cap_r, 0).sum() / per_sec_req.sum()
            bt = np.maximum(per_sec_tok - cap_t, 0).sum() / per_sec_tok.sum()
            emit("fig3", f"{trace_name},overprov={x}x,req_burst_pct",
                 100 * br)
            emit("fig3", f"{trace_name},overprov={x}x,tok_burst_pct",
                 100 * bt)


# ---------------------------------------------------------------------------
# Table II — per-bucket decode Token Velocity (+ Fig. 7 characterization)
# ---------------------------------------------------------------------------

def table2_velocities():
    for model, tp in [("llama31_8b", 1), ("qwen25_32b", 4)]:
        cfg = get_config(model)
        prof = profile(cfg, InstanceSpec(CHIPS["a100"], tp=tp))
        for b in BUCKETS:
            emit("table2", f"{cfg.name},tp={tp},a100,{b},v_decode",
                 prof.v_decode[b])
        emit("table2", f"{cfg.name},tp={tp},a100,v_prefill", prof.v_prefill)
        emit("table2", f"{cfg.name},tp={tp},a100,v_network", prof.v_network)


def fig7_characterization():
    for chip in ["a100", "h100", "v5e"]:
        for model in ["llama31_8b", "qwen25_32b"]:
            cfg = get_config(model)
            prof = profile(cfg, InstanceSpec(CHIPS[chip], tp=1))
            vd = sorted(prof.v_decode.values())
            emit("fig7", f"{cfg.name},{chip},v_prefill", prof.v_prefill)
            emit("fig7", f"{cfg.name},{chip},v_network", prof.v_network)
            emit("fig7", f"{cfg.name},{chip},v_decode_min", vd[0])
            emit("fig7", f"{cfg.name},{chip},v_decode_max", vd[-1])


# ---------------------------------------------------------------------------
# Fig. 9 — end-to-end SLO attainment vs GPU usage
# ---------------------------------------------------------------------------

def fig9_end_to_end(model="llama31_8b", tp=1, tag="small",
                    duration=120.0, rps=10.0):
    for trace in ["azure_conv", "azure_code", "mixed"]:
        reps = compare_policies(trace, model=model, tp=tp,
                                duration=duration, rps=rps, seed=0,
                                engine=ENGINE)
        for name, r in reps.items():
            emit("fig9", f"{tag},{trace},{name},slo_pct",
                 100 * r.slo_attainment())
            emit("fig9", f"{tag},{trace},{name},ttft_pct",
                 100 * r.ttft_attainment())
            emit("fig9", f"{tag},{trace},{name},tpot_pct",
                 100 * r.tpot_attainment())
            emit("fig9", f"{tag},{trace},{name},avg_gpus", r.avg_gpus())


def fig9b_large_model():
    fig9_end_to_end(model="qwen25_32b", tp=4, tag="large",
                    duration=90.0, rps=6.0)


# ---------------------------------------------------------------------------
# Fig. 10 — burst adaptation timeline (10x RPS step at t=10 s)
# ---------------------------------------------------------------------------

def fig10_burst_adaptation():
    for pol in ["tokenscale", "distserve", "aibrix", "blitzscale"]:
        rep = _run_step_trace(pol)
        burst_ttfts = [r.ttft for r in rep.requests
                       if 10.0 <= r.src.t < 14.0 and r.t_first_token >= 0]
        post = [r.ttft for r in rep.requests
                if 16.0 <= r.src.t < 25.0 and r.t_first_token >= 0]
        emit("fig10", f"{pol},burst_ttft_p99_ms",
             1e3 * float(np.percentile(burst_ttfts, 99))
             if burst_ttfts else -1.0)
        emit("fig10", f"{pol},post_burst_ttft_p99_ms",
             1e3 * float(np.percentile(post, 99)) if post else -1.0)


def _run_step_trace(policy_name: str):
    cfg = get_config("llama31_8b")
    inst = InstanceSpec(CHIPS["a100"], tp=1)
    prof = profile(cfg, inst)
    # 20x step: the burst exceeds one prefiller's velocity while instance
    # startup (5 s) is longer than the burst itself — only a standing
    # rapid-response buffer (the Convertible Decoder) can absorb it
    trace = step_trace(30.0, base_rps=1.0, burst_rps=20.0, burst_start=10.0,
                       burst_len=4.0, seed=3)
    policy = make_policy(policy_name, prof, 1, trace=trace)
    conv = plan_convertible(cfg, inst, 32, 1200.0, 0.2, 8)
    n_conv = 1 if policy_name == "tokenscale" else 0
    cl = get_engine(ENGINE)(cfg, inst, prof, policy,
                           OutputPredictor(0.85, 3),
                           conv_cfg=conv, n_convertible=n_conv)
    return cl.run(trace, 30.0)


# ---------------------------------------------------------------------------
# Fig. 11 — provisioned vs required instances (Pearson correlation)
# ---------------------------------------------------------------------------

def fig11_provision_correlation():
    """Provisioned vs required instance counts under large load swings
    (5->25->10->35->8 RPS); Pearson r per policy.  Requirement series is
    the ground-truth velocity quotient with TRUE lengths; both series are
    5 s-smoothed (the provisioning loop runs at 1 s + hysteresis)."""
    from repro.core import (OutputPredictor, bucket_of, plan_convertible)
    from repro.sim.runner import make_policy
    from repro.sim.traces import varying_rate_trace
    cfg = get_config("llama31_8b")
    inst = InstanceSpec(CHIPS["a100"], tp=1)
    prof = profile(cfg, inst)
    segments = [(40.0, 5.0), (40.0, 25.0), (40.0, 10.0), (40.0, 35.0),
                (40.0, 8.0)]
    trace = varying_rate_trace(segments, seed=0)
    T = int(sum(d for d, _ in segments)) + 1
    req_p = np.zeros(T)
    req_d = np.zeros(T)
    for r in trace:
        i = min(int(r.t), T - 1)
        req_p[i] += r.in_len / prof.v_prefill
        b = bucket_of(r.in_len, r.out_len)
        req_d[i] += (r.in_len + r.out_len) / prof.v_decode[b]

    def smooth(x, w=5):
        return np.convolve(x, np.ones(w) / w, mode="same")

    conv = plan_convertible(cfg, inst, 32, 1200.0, 0.2, 8)
    for pol in ["tokenscale", "distserve", "aibrix", "blitzscale"]:
        policy = make_policy(pol, prof, 1, trace=trace)
        cl = get_engine(ENGINE)(cfg, inst, prof, policy,
                               OutputPredictor(0.85, 0), conv_cfg=conv,
                               n_convertible=1 if pol == "tokenscale" else 0)
        rep = cl.run(list(trace), float(T - 1))
        prov_p = np.zeros(T)
        prov_d = np.zeros(T)
        cnt = np.zeros(T) + 1e-9
        for snap in rep.timeline:
            i = min(int(snap["t"]), T - 1)
            prov_p[i] += snap["prefillers"]
            prov_d[i] += snap["decoders"] + snap["convertibles"]
            cnt[i] += 1
        prov_p /= cnt
        prov_d /= cnt
        n = T - 2
        rp = float(np.corrcoef(smooth(req_p)[:n], smooth(prov_p)[:n])[0, 1])
        rd = float(np.corrcoef(smooth(req_d)[:n], smooth(prov_d)[:n])[0, 1])
        emit("fig11", f"{pol},pearson_prefill", rp)
        emit("fig11", f"{pol},pearson_decode", rd)


# ---------------------------------------------------------------------------
# Fig. 12 — output-predictor accuracy sweep
# ---------------------------------------------------------------------------

def fig12_predictor_accuracy():
    for acc in [1.0, 0.85, 0.7, 0.5]:
        rep = run_policy("tokenscale", "mixed", duration=90.0, rps=8.0,
                         seed=2, predictor_accuracy=acc, engine=ENGINE)
        emit("fig12", f"acc={acc},slo_pct", 100 * rep.slo_attainment())
        emit("fig12", f"acc={acc},avg_gpus", rep.avg_gpus())


# ---------------------------------------------------------------------------
# Fig. 13 — number of Convertible Decoders
# ---------------------------------------------------------------------------

def fig13_convertible_count():
    for n in [0, 1, 2, 3]:
        rep = run_policy("tokenscale", "mixed", duration=90.0, rps=8.0,
                         seed=1, n_convertible=n, engine=ENGINE)
        emit("fig13", f"n_convertible={n},slo_pct",
             100 * rep.slo_attainment())
        emit("fig13", f"n_convertible={n},ttft_pct",
             100 * rep.ttft_attainment())


# ---------------------------------------------------------------------------
# Fig. 14 — ablation: B -> B+P -> B+P+D -> TokenScale
# ---------------------------------------------------------------------------

def fig14_ablation():
    cfg = get_config("llama31_8b")
    inst = InstanceSpec(CHIPS["a100"], tp=1)
    prof = profile(cfg, inst)
    trace = get_trace("mixed", duration_s=120.0, rps=10.0, seed=0)

    def ds():
        return make_policy("distserve", prof, 0, trace=trace)

    def ts():
        return TokenScalePolicy(prof, convertible=0)

    variants = {
        "B": (ds(), 0),
        "B+P": (ComboPolicy(ts(), ds(), "B+P"), 0),
        "B+P+D": (ComboPolicy(ts(), ts(), "B+P+D"), 0),
        "TokenScale": (TokenScalePolicy(prof, convertible=1), 1),
    }
    conv = plan_convertible(cfg, inst, 32, 1200.0, 0.2, 8)
    for name, (policy, n_conv) in variants.items():
        cl = get_engine(ENGINE)(cfg, inst, prof, policy,
                               OutputPredictor(0.85, 0),
                               conv_cfg=conv, n_convertible=n_conv)
        rep = cl.run(list(trace), 150.0)
        emit("fig14", f"{name},slo_pct", 100 * rep.slo_attainment())
        emit("fig14", f"{name},ttft_pct", 100 * rep.ttft_attainment())
        emit("fig14", f"{name},tpot_pct", 100 * rep.tpot_attainment())
        emit("fig14", f"{name},avg_gpus", rep.avg_gpus())


# ---------------------------------------------------------------------------
# Fig. 15 — generality on H100
# ---------------------------------------------------------------------------

def fig15_h100():
    for trace in ["azure_conv", "azure_code", "mixed"]:
        for pol in ["tokenscale", "distserve"]:
            rep = run_policy(pol, trace, chip="h100", duration=90.0,
                             rps=10.0, seed=0, engine=ENGINE)
            emit("fig15", f"h100,{trace},{pol},slo_pct",
                 100 * rep.slo_attainment())
            emit("fig15", f"h100,{trace},{pol},avg_gpus", rep.avg_gpus())


# ---------------------------------------------------------------------------
# Engine micro-benchmarks (CPU wall time; us_per_call)
# ---------------------------------------------------------------------------

def engine_microbench():
    from repro.models import decode_step, init_params, init_state, prefill
    cfg = get_config("llama31_8b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 4, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    lengths = jnp.full((B,), S, jnp.int32)
    st = init_state(cfg, B, S + 32)
    pf = jax.jit(lambda p, s, t, ln: prefill(cfg, p, s, t, ln))
    logits, st = pf(params, st, toks, lengths)     # compile
    t0 = time.perf_counter()
    for _ in range(10):
        logits, _ = pf(params, st, toks, lengths)
    jax.block_until_ready(logits)
    emit("micro", "prefill_us_per_call",
         1e6 * (time.perf_counter() - t0) / 10)

    dc = jax.jit(lambda p, s, t, ln: decode_step(cfg, p, s, t, ln))
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    dl, st2 = dc(params, st, nxt, lengths)
    t0 = time.perf_counter()
    for _ in range(20):
        dl, st2 = dc(params, st2, nxt, lengths + 1)
    jax.block_until_ready(dl)
    emit("micro", "decode_us_per_call",
         1e6 * (time.perf_counter() - t0) / 20)


def sim_throughput():
    t0 = time.perf_counter()
    rep = run_policy("tokenscale", "mixed", duration=60.0, rps=8.0,
                     seed=0, engine=ENGINE)
    dt = time.perf_counter() - t0
    emit("micro", "sim_requests_per_wall_s", len(rep.requests) / dt)


def kv8_velocity():
    """Beyond-paper: the int8 KV cache folded back into TokenScale's own
    math — per-bucket decode Token Velocity roughly doubles, so Eq. 3
    provisions ~half the decoders for the same arrival rates, and the
    end-to-end sim serves the same trace with fewer GPUs."""
    cfg16 = get_config("llama31_8b")
    cfg8 = cfg16.replace(kv_cache_dtype="int8")
    inst = InstanceSpec(CHIPS["a100"], tp=1)
    p16 = profile(cfg16, inst)
    p8 = profile(cfg8, inst)
    for b in ("S-S", "M-M", "L-L"):
        emit("kv8", f"{b},v_decode_bf16", p16.v_decode[b])
        emit("kv8", f"{b},v_decode_int8", p8.v_decode[b])
        emit("kv8", f"{b},speedup", p8.v_decode[b] / p16.v_decode[b])
    # Eq.3 decoder counts for an identical arrival pattern
    lam = {b: p16.v_decode[b] * 0.8 for b in ("S-S", "M-M", "L-L")}
    import math as _m
    n16 = sum(r / p16.v_decode[b] for b, r in lam.items())
    n8 = sum(r / p8.v_decode[b] for b, r in lam.items())
    emit("kv8", "eq3_decoders_bf16", _m.ceil(n16))
    emit("kv8", "eq3_decoders_int8", _m.ceil(n8))
    # end-to-end: same trace, int8 profile
    r16 = run_policy("tokenscale", "mixed", duration=90.0, rps=10.0,
                     seed=0, prof=p16, engine=ENGINE)
    r8 = run_policy("tokenscale", "mixed", duration=90.0, rps=10.0,
                    seed=0, prof=p8, engine=ENGINE)
    emit("kv8", "e2e_bf16_slo_pct", 100 * r16.slo_attainment())
    emit("kv8", "e2e_bf16_gpus", r16.avg_gpus())
    emit("kv8", "e2e_int8_slo_pct", 100 * r8.slo_attainment())
    emit("kv8", "e2e_int8_gpus", r8.avg_gpus())


def pd_runtime():
    """PD-disaggregated runtime on real engines: measured network-stage
    velocity (the paper's V_N, from actual KVC transfer bytes) for an
    attention arch vs an attention-free SSM."""
    from repro.core import TokenScalePolicy
    from repro.models import init_params
    from repro.serving import PDCluster, Request
    for arch in ["llama31_8b", "rwkv6_3b"]:
        cfg = get_config(arch, smoke=True)
        params = init_params(cfg, jax.random.PRNGKey(0))
        prof = profile(get_config(arch), InstanceSpec(CHIPS["v5e"], 1))
        cl = PDCluster(cfg, params, TokenScalePolicy(prof, convertible=0),
                       n_prefillers=1, n_decoders=1, n_convertible=0,
                       max_len=160)
        rng = np.random.RandomState(0)
        # longer prompts: KVC grows with length, SSM state does not — the
        # §III-C asymmetry needs prompts >> the fixed-state equivalent
        for i in range(6):
            cl.submit(Request(
                rid=i, prompt=rng.randint(0, cfg.vocab_size,
                                          size=(80,)).astype(np.int32),
                max_new_tokens=4))
        cl.run_until_drained()
        emit("pd", f"{arch},kvc_bytes_per_token",
             cl.transfers.bytes_per_token())
        emit("pd", f"{arch},measured_v_network_toks",
             cl.measured_network_velocity())
        emit("pd", f"{arch},transfers", cl.transfers.n_transfers)


def multipod_scaling():
    """Multi-pod (512-chip) vs single-pod (256-chip) roofline terms from
    the dry-run artifact: per-chip terms should ~halve for batch-sharded
    shapes if the 'pod' axis actually shards (deliverable e)."""
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "..",
                        "results_dryrun.jsonl")
    if not os.path.exists(path):
        emit("multipod", "skipped", "results_dryrun.jsonl missing")
        return
    recs = {}
    for line in open(path):
        r = json.loads(line)
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    for arch in ["llama31_8b", "kimi_k2_1t_a32b", "jamba_v0_1_52b",
                 "rwkv6_3b"]:
        for shape in ["train_4k", "decode_32k"]:
            a = recs.get((arch, shape, "16x16"))
            b = recs.get((arch, shape, "2x16x16"))
            if not a or not b or a["status"] != "ok" or b["status"] != "ok":
                continue
            for term in ["t_compute_s", "t_memory_s"]:
                if a[term] > 1e-9:
                    emit("multipod", f"{arch},{shape},{term}_ratio",
                         b[term] / a[term])


# ---------------------------------------------------------------------------
# Differential validation: fluid vs event engine on identical inputs
# ---------------------------------------------------------------------------

def diffval():
    """Agreement between the dt-stepped fluid simulator and the
    discrete-event simulator on throughput / mean TTFT / mean TPOT
    (the bench twin of tests/test_sim_differential.py)."""
    for trace in ["azure_conv", "mixed"]:
        for pol in ["tokenscale", "distserve"]:
            reps = compare_engines(pol, trace, duration=60.0, rps=8.0,
                                   seed=0)
            fl, ev = reps["fluid"], reps["events"]
            emit("diffval", f"{trace},{pol},thr_fluid", fl.throughput())
            emit("diffval", f"{trace},{pol},thr_events", ev.throughput())
            emit("diffval", f"{trace},{pol},ttft_ms_fluid",
                 1e3 * fl.mean("ttft"))
            emit("diffval", f"{trace},{pol},ttft_ms_events",
                 1e3 * ev.mean("ttft"))
            emit("diffval", f"{trace},{pol},tpot_ms_fluid",
                 1e3 * fl.mean("tpot"))
            emit("diffval", f"{trace},{pol},tpot_ms_events",
                 1e3 * ev.mean("tpot"))
            emit("diffval", f"{trace},{pol},ttft_p99_ms_events",
                 1e3 * ev.percentile("ttft", 99))


# ---------------------------------------------------------------------------
# Tails — Fig. 9/10-style p99/p999 at event fidelity, per priority class
# ---------------------------------------------------------------------------

#: the memory-tight fleet where HBM backpressure actually bites: qwen25-32B
#: at TP2 on A100-40G leaves ~6.5 GB of KV headroom (~27 resident requests
#: per decoder) and the 2-instance cap keeps bursts from being absorbed by
#: scale-out — exactly the contention regime preemption policies target.
TAILS_CFG = dict(model="qwen25_32b", tp=2, duration=30.0, rps=8.0, seed=0,
                 max_instances=2)
PREEMPTION_MODES = ["none", "evict-lowest", "pause-requeue"]


def tails():
    """Per-priority-class tail latencies (p99/p999 TTFT, p99 TPOT) and SLO
    attainment for every trace x policy x preemption variant, at event
    fidelity (run with --engine=events; the fluid engine smears exactly the
    tails this bench exists to expose, so it is skipped there — including
    in the no-argument run-everything invocation)."""
    from repro.sim.traces import DEFAULT_PRIORITY_MIX
    if ENGINE != "events":
        emit("tails", "skipped", "needs --engine=events")
        return
    for trace in ["azure_conv", "azure_code", "burstgpt1", "burstgpt2",
                  "mixed"]:
        for pol in ["tokenscale", "distserve", "aibrix", "blitzscale"]:
            for mode in PREEMPTION_MODES:
                rep = run_policy(pol, trace, engine=ENGINE, preemption=mode,
                                 priority_mix=DEFAULT_PRIORITY_MIX,
                                 **TAILS_CFG)
                for cls in rep.priority_classes():
                    pre = f"{trace},{pol},{mode},class{cls}"
                    emit("tails", f"{pre},ttft_p99_ms",
                         1e3 * rep.percentile("ttft", 99, priority=cls))
                    emit("tails", f"{pre},ttft_p999_ms",
                         1e3 * rep.percentile("ttft", 99.9, priority=cls))
                    emit("tails", f"{pre},tpot_p99_ms",
                         1e3 * rep.percentile("tpot", 99, priority=cls))
                    emit("tails", f"{pre},slo_pct",
                         100 * rep.slo_attainment(cls))
                emit("tails", f"{trace},{pol},{mode},preemptions",
                     len(rep.preemptions))


#: the kvtiers contention fleet: qwen25-32B TP2 on A100-40G (2-instance
#: cap) over azure_code — long prompts make a KV recomputation (~2.7K
#: tokens at prefill velocity, plus the prefill backlog the burst itself
#: created) far more expensive than a host-DRAM swap-in at PCIe bandwidth,
#: which is exactly the gap the tiered subsystem exists to expose.
KVTIERS_CFG = dict(model="qwen25_32b", tp=2, duration=30.0, rps=7.0,
                   seed=0, max_instances=2)
KVTIERS_TRACE = "azure_code"
KVTIERS_BLOCK = 16
KVTIERS_SESSIONS = 0.5

#: variant -> (preemption mode, prefix_cache); all run the paged
#: allocator so the comparison isolates the *policy*, not the accounting
KVTIERS_VARIANTS = {
    "none": ("none", False),
    "recompute": ("evict-lowest", False),
    "swap": ("pause-requeue", False),
    "swap+prefix": ("pause-requeue", True),
}


def run_kvtiers_variant(variant: str, duration: float = None,
                        engine: str = "events"):
    """One kvtiers bench cell (shared with the golden regenerator and the
    smoke row, so the fixture and the bench can never drift apart)."""
    from repro.sim.traces import DEFAULT_PRIORITY_MIX
    mode, prefix = KVTIERS_VARIANTS[variant]
    cfg = dict(KVTIERS_CFG)
    if duration is not None:
        cfg["duration"] = duration
    return run_policy("tokenscale", KVTIERS_TRACE, engine=engine,
                      preemption=mode, priority_mix=DEFAULT_PRIORITY_MIX,
                      session_prob=KVTIERS_SESSIONS,
                      block_size=KVTIERS_BLOCK, prefix_cache=prefix, **cfg)


def kvtiers():
    """Tiered-KV ablation on the memory-tight fleet over a session-style
    trace: none / recompute (evict-lowest) / swap (pause-requeue into the
    host-DRAM tier) / swap+prefix (adding copy-on-write prefix reuse).
    Swap must strictly improve the preempted-request p99 TTFT/TPOT over
    recompute, and prefix reuse must cut the prefill-token load (the
    acceptance rows; pinned by tests/golden/kvtiers_session.json).  Always
    runs the event engine — swap completions are exact events there, which
    is the fidelity this bench exists to measure."""
    for variant in KVTIERS_VARIANTS:
        rep = run_kvtiers_variant(variant)
        ks = rep.kv_summary()
        pre = f"{KVTIERS_TRACE},{variant}"
        emit("kvtiers", f"{pre},preemptions", len(rep.preemptions))
        emit("kvtiers", f"{pre},preempted_ttft_p99_ms",
             1e3 * ks["preempted_ttft_p99"])
        emit("kvtiers", f"{pre},preempted_tpot_p99_ms",
             1e3 * ks["preempted_tpot_p99"])
        emit("kvtiers", f"{pre},slo_pct", 100 * rep.slo_attainment())
        emit("kvtiers", f"{pre},prefill_tokens",
             sum(r.src.in_len - r.kv_hit_tokens for r in rep.requests))
        emit("kvtiers", f"{pre},prefix_hit_rate_pct",
             100 * ks["prefix_hit_rate"])
        emit("kvtiers", f"{pre},offload_mb", ks["offload_bytes"] / 1e6)
        emit("kvtiers", f"{pre},swap_outs", ks["swap_outs"])
        emit("kvtiers", f"{pre},swap_fallbacks", ks["swap_fallbacks"])
        emit("kvtiers", f"{pre},swap_stall_ms", 1e3 * ks["swap_stall_s"])
        emit("kvtiers", f"{pre},peak_blocks_frac", ks["peak_blocks_frac"])


#: the deflection fleet: llama31-8B on A100-TP1 driven hard enough that
#: the prefill path saturates during bursts (the 6-instance cap keeps
#: scale-out from absorbing them before the 5 s startup) — the regime
#: where Alg. 1 rounds 1-2 fail and round 2b (chunked deflection onto
#: regular decoders) is the only rapid-response path left.
DEFLECT_CFG = dict(model="llama31_8b", chip="a100", tp=1, duration=60.0,
                   rps=40.0, seed=0, max_instances=6)
DEFLECT_TRACES = ["burstgpt1", "burstgpt2"]
#: variant -> PoolSpec.prefill_chunking (0 = legacy wholesale conversion)
DEFLECT_VARIANTS = {"wholesale": 0, "chunked": 2048}


def run_deflect_variant(variant: str, trace: str = "burstgpt1",
                        duration: float = None, engine: str = "events"):
    """One deflect bench cell (shared with the golden regenerator and the
    smoke row, so the fixture and the bench can never drift apart)."""
    cfg = dict(DEFLECT_CFG)
    if duration is not None:
        cfg["duration"] = duration
    return run_policy("tokenscale", trace, engine=engine,
                      prefill_chunking=DEFLECT_VARIANTS[variant], **cfg)


def deflect():
    """Whole-instance conversion vs chunked prefill deflection on the
    burst traces, at event fidelity (chunk boundaries are exact events
    there — the fluid engine smears exactly the burst-tail TTFTs this
    bench compares).  The acceptance gradient: chunked deflection beats
    wholesale conversion on p99 TTFT on both traces while resident p99
    TPOT stays inside the Eq. 5 budget (pinned by
    tests/golden/deflect_burst.json)."""
    for trace in DEFLECT_TRACES:
        for variant in DEFLECT_VARIANTS:
            rep = run_deflect_variant(variant, trace)
            pre = f"{trace},{variant}"
            emit("deflect", f"{pre},requests", len(rep.requests))
            emit("deflect", f"{pre},ttft_p99_ms",
                 1e3 * rep.percentile("ttft", 99))
            emit("deflect", f"{pre},ttft_p999_ms",
                 1e3 * rep.percentile("ttft", 99.9))
            emit("deflect", f"{pre},tpot_p99_ms",
                 1e3 * rep.percentile("tpot", 99))
            emit("deflect", f"{pre},slo_pct", 100 * rep.slo_attainment())
            emit("deflect", f"{pre},avg_gpus", rep.avg_gpus())
            emit("deflect", f"{pre},deflected", rep.n_deflected)


#: the gateway fleet: the kvtiers contention fleet (qwen25-32B TP2 on
#: A100-40G, 2-instance cap) driven by a hot-system-prompt session trace —
#: 70% of arrivals share one of two Zipf-popular 1K-token system prompts
#: across sessions.  The legacy owner-steering path only sees *session*
#: affinity, so the cross-session prompt reuse is invisible to it; the
#: locality gateway's block-granular hashtrie routes those arrivals to
#: whichever decoder already holds the shared blocks (and replicates the
#: hot prefix when one holder funnels), which is exactly the gap this
#: bench measures.
GATEWAY_CFG = dict(model="qwen25_32b", tp=2, duration=30.0, rps=7.0,
                   seed=0, max_instances=2)
GATEWAY_TRACE = "azure_code"
GATEWAY_BLOCK = 16
GATEWAY_SESSIONS = 0.4
GATEWAY_SHARED = dict(shared_prefix_prob=0.7, shared_prefix_len=1024,
                      shared_prefix_count=2)
#: variant -> (PoolSpec.gateway, PoolSpec.kv_alloc); both run the paged
#: allocator + prefix cache so the comparison isolates *routing* (and the
#: allocate-on-generate paging the gateway enables), not the accounting
GATEWAY_VARIANTS = {"owner": (False, "reserve"),
                    "gateway": (True, "lazy")}


def run_gateway_variant(variant: str, duration: float = None,
                        engine: str = "events"):
    """One gateway bench cell (shared with the golden regenerator and the
    smoke row, so the fixture and the bench can never drift apart)."""
    gw, alloc = GATEWAY_VARIANTS[variant]
    cfg = dict(GATEWAY_CFG)
    if duration is not None:
        cfg["duration"] = duration
    return run_policy("tokenscale", GATEWAY_TRACE, engine=engine,
                      preemption="pause-requeue",
                      session_prob=GATEWAY_SESSIONS,
                      block_size=GATEWAY_BLOCK, prefix_cache=True,
                      gateway=gw, kv_alloc=alloc, **GATEWAY_SHARED, **cfg)


def gateway():
    """KV-locality gateway ablation on the hot-system-prompt session
    trace: legacy owner-steering (session-affinity only, reserve-ahead KV)
    vs the prefix-hashtrie gateway (cross-session locality routing +
    hot-prefix replication + allocate-on-generate paging).  The acceptance
    gradient: the gateway strictly beats owner-steering on p99 TTFT at
    equal-or-lower GPU count, with a strictly higher prefix hit rate
    (pinned by tests/golden/gateway_locality.json).  Event engine by
    default — replication completions and mid-decode OOMs are exact
    events there."""
    for variant in GATEWAY_VARIANTS:
        rep = run_gateway_variant(variant, engine=ENGINE)
        ks = rep.kv_summary()
        pre = f"{GATEWAY_TRACE},{variant}"
        emit("gateway", f"{pre},requests", len(rep.requests))
        emit("gateway", f"{pre},ttft_p99_ms",
             1e3 * rep.percentile("ttft", 99))
        emit("gateway", f"{pre},tpot_p99_ms",
             1e3 * rep.percentile("tpot", 99))
        emit("gateway", f"{pre},slo_pct", 100 * rep.slo_attainment())
        emit("gateway", f"{pre},avg_gpus", rep.avg_gpus())
        emit("gateway", f"{pre},prefix_hit_rate_pct",
             100 * ks["prefix_hit_rate"])
        emit("gateway", f"{pre},peak_blocks_frac", ks["peak_blocks_frac"])
        gw = rep.gw_summary()
        if gw:
            # routing-decision breakdown + replication/paging counters
            emit("gateway", f"{pre},affinity_hits", gw["affinity_hits"])
            emit("gateway", f"{pre},replica_hits", gw["replica_hits"])
            emit("gateway", f"{pre},balanced_fallbacks", gw["balanced"])
            emit("gateway", f"{pre},steered_tokens", gw["steered_tokens"])
            emit("gateway", f"{pre},replications", gw["replications"])
            emit("gateway", f"{pre},replica_mb", gw["replica_bytes"] / 1e6)
            emit("gateway", f"{pre},block_grows", gw["block_grows"])
            emit("gateway", f"{pre},oom_preemptions",
                 gw["oom_preemptions"])


#: the pareto fleet: a two-model cluster on mixed chips.  llama31-8B runs
#: a bursty route on an a100 primary pair plus — for the coordinated
#: planner only — an elastic l40s decode pool (higher decode tokens/s/$
#: than the a100 primary: scale-out placement lands on the cheaper chip).
#: qwen25-32B is the steady background tenant on h100-TP2; both models'
#: convertible pools share (a100, TP2) so burst prefill can spill across
#: models.  The per-model baseline plans the identical initial hardware,
#: minus the elastic pool it cannot express (one pool per role).
PARETO_CFG = dict(duration=120.0, seed=2, max_instances=12,
                  llama_rps=28.0, qwen_rps=2.0, qwen_trace="azure_conv")
PARETO_TRACES = ["burstgpt1", "burstgpt2"]
#: variant -> (policy name, elastic l40s decode pool present)
PARETO_VARIANTS = {"permodel": ("tokenscale", False),
                   "coord": ("tokenscale-coord", True)}


def pareto_fleet_spec(variant: str, trace: str):
    """The shared fleet recipe for one pareto bench cell."""
    from repro.core import FleetSpec, PoolSpec, TraceRoute
    _, elastic = PARETO_VARIANTS[variant]
    pools = [
        PoolSpec("pre-ll", "prefill", "llama31_8b", "a100", 1, init=1),
        PoolSpec("dec-ll", "decode", "llama31_8b", "a100", 1, init=1),
        PoolSpec("conv-ll", "convertible", "llama31_8b", "a100", 2, init=1),
        PoolSpec("pre-qw", "prefill", "qwen25_32b", "h100", 2, init=1),
        PoolSpec("dec-qw", "decode", "qwen25_32b", "h100", 2, init=1),
        PoolSpec("conv-qw", "convertible", "qwen25_32b", "a100", 2, init=2),
    ]
    if elastic:
        pools.insert(2, PoolSpec("dec-ll-l40s", "decode", "llama31_8b",
                                 "l40s", 1, init=0, min=0, max=8))
    routes = (TraceRoute("llama31_8b", trace, rps=PARETO_CFG["llama_rps"]),
              TraceRoute("qwen25_32b", PARETO_CFG["qwen_trace"],
                         rps=PARETO_CFG["qwen_rps"]))
    return FleetSpec(tuple(pools), routes)


def run_pareto_variant(variant: str, trace: str = "burstgpt2",
                       duration: float = None, engine: str = "events",
                       dt: float = None):
    """One pareto bench cell (shared with the golden regenerator and the
    smoke row, so the fixture and the bench can never drift apart).
    ``dt`` overrides the fluid tick (the differential test halves it, as
    in tests/test_sim_differential.py)."""
    policy, _ = PARETO_VARIANTS[variant]
    kw = {"dt": dt} if dt is not None else {}
    spec = ExperimentSpec(
        fleet=pareto_fleet_spec(variant, trace), policy=policy,
        engine=engine, duration=duration or PARETO_CFG["duration"],
        seed=PARETO_CFG["seed"], max_instances=PARETO_CFG["max_instances"],
        **kw)
    return run_spec(spec)


def pareto():
    """Cost-vs-attainment frontier on the mixed-chip two-model fleet, at
    event fidelity: the per-model TokenScale baseline (one pool per role,
    planned independently per model) against the coordinated cross-pool
    planner (cost-ranked placement onto the elastic l40s pool, drain-based
    scale-down, cross-model convertible spill).  The acceptance gradient:
    on the burst traces the coordinated planner Pareto-dominates — SLO
    attainment at least as high at strictly lower ``cost_dollars``
    (pinned by tests/golden/pareto_coord.json)."""
    for trace in PARETO_TRACES:
        for variant in PARETO_VARIANTS:
            rep = run_pareto_variant(variant, trace)
            cs = rep.cost_summary()
            pre = f"{trace},{variant}"
            emit("pareto", f"{pre},requests", len(rep.requests))
            emit("pareto", f"{pre},slo_pct", 100 * rep.slo_attainment())
            emit("pareto", f"{pre},ttft_p99_ms",
                 1e3 * rep.percentile("ttft", 99))
            emit("pareto", f"{pre},cost_dollars", cs["cost_dollars"])
            emit("pareto", f"{pre},cost_per_hour", cs["cost_per_hour"])
            emit("pareto", f"{pre},avg_gpus", rep.avg_gpus())
            for m in rep.models():
                emit("pareto", f"{pre},{m},slo_pct",
                     100 * rep.slo_attainment(model=m))


#: the chaos fleet: the llama31-8B a100 pool under a bursty trace with a
#: full fault mix — two decode crashes (KV purge + resident re-entry),
#: one prefill crash, a prefill straggler window, a swap-bandwidth
#: degradation, and two KVC link outages (sim.faults; every injection
#: lands, none skipped).  The priority mix + evict-lowest preemption
#: compose the shedding path: when crashes cost more capacity than the
#: replacement latency hides, the lowest class absorbs the loss.
CHAOS_CFG = dict(model="llama31_8b", chip="a100", tp=1, duration=60.0,
                 rps=12.0, seed=0)
CHAOS_TRACE = "burstgpt1"
CHAOS_MIX = {0: 0.2, 1: 0.6, 2: 0.2}
CHAOS_FAULTS = dict(seed=0, crashes=3, stragglers=1, swap_degrades=1,
                    link_outages=2, t0=8.0)
#: variant -> FaultConfig.recovery: the same fault schedule with the
#: self-healing control plane on vs blind (husks keep billing + counting,
#: residents re-enter only on client timeout)
CHAOS_VARIANTS = {"recovery": True, "norecovery": False}


def run_chaos_variant(variant: str, duration: float = None,
                      engine: str = "events"):
    """One chaos bench cell (shared with the golden regenerator and the
    smoke row, so the fixture and the bench can never drift apart)."""
    cfg = dict(CHAOS_CFG)
    if duration is not None:
        cfg["duration"] = duration
    return run_policy("tokenscale", CHAOS_TRACE, engine=engine,
                      preemption="evict-lowest", priority_mix=CHAOS_MIX,
                      block_size=16, prefix_cache=True,
                      faults=dict(CHAOS_FAULTS,
                                  recovery=CHAOS_VARIANTS[variant]), **cfg)


def chaos():
    """Fault injection with vs without the self-healing control plane,
    on the identical seeded fault schedule, through both engines.  The
    acceptance gradient (pinned by tests/golden/chaos_recovery.json):
    recovery-on strictly beats recovery-off on class-0 SLO attainment
    AND p99 TTFT on both engines — detection + warm replacement + KVC
    retry/fallback + prefix-reuse re-entry together beat a control plane
    that only sees the damage through lagging queue signals."""
    for engine in ("events", "fluid"):
        for variant in CHAOS_VARIANTS:
            rep = run_chaos_variant(variant, engine=engine)
            fs = rep.fault_summary()
            c0 = rep.class_summary(0)
            pre = f"{CHAOS_TRACE},{engine},{variant}"
            emit("chaos", f"{pre},requests", len(rep.requests))
            emit("chaos", f"{pre},slo_pct", 100 * rep.slo_attainment())
            emit("chaos", f"{pre},class0_slo_pct",
                 100 * c0["slo_attainment"])
            emit("chaos", f"{pre},class0_ttft_p99_ms",
                 1e3 * c0["ttft_p99"])
            emit("chaos", f"{pre},ttft_p99_ms",
                 1e3 * rep.percentile("ttft", 99))
            emit("chaos", f"{pre},avg_gpus", rep.avg_gpus())
            for k in ("crashes", "restarts", "residents_requeued",
                      "prefill_requeued", "kvc_retries", "kvc_fallbacks",
                      "straggler_windows", "swap_degrade_windows",
                      "link_down_windows", "skipped"):
                emit("chaos", f"{pre},{k}", fs[k])


def hetero():
    """Heterogeneous fleet (a100-TP2 prefill + h100-TP1 decode pools) and
    a two-model cluster, each through both engines via the same
    ``run_spec`` entry point — the two scenario axes the pool-centric
    control plane opens."""
    from repro.core import FleetSpec, PoolSpec, TraceRoute
    for eng in ["fluid", "events"]:
        rep = run_spec(hetero_demo_spec(duration=30.0, rps=6.0, engine=eng))
        emit("hetero", f"mixed_chips,{eng},requests", len(rep.requests))
        emit("hetero", f"mixed_chips,{eng},slo_pct",
             100 * rep.slo_attainment())
        emit("hetero", f"mixed_chips,{eng},ttft_p99_ms",
             1e3 * rep.percentile("ttft", 99))
        emit("hetero", f"mixed_chips,{eng},avg_gpus", rep.avg_gpus())
    two_model = ExperimentSpec(
        fleet=FleetSpec(
            pools=(
                PoolSpec("llama-pre", "prefill", "llama31_8b", "a100"),
                PoolSpec("llama-dec", "decode", "llama31_8b", "a100"),
                PoolSpec("qwen-pre", "prefill", "qwen25_32b", "a100", tp=4),
                PoolSpec("qwen-dec", "decode", "qwen25_32b", "a100", tp=4),
            ),
            routes=(TraceRoute("llama31_8b", "azure_conv", rps=5.0),
                    TraceRoute("qwen25_32b", "azure_code", rps=3.0))),
        policy="tokenscale", engine=ENGINE, duration=30.0, seed=0)
    rep = run_spec(two_model)
    for m in rep.models():
        s = rep.model_summary(m)
        emit("hetero", f"two_model,{m},requests", s["n"])
        emit("hetero", f"two_model,{m},slo_pct", 100 * s["slo_attainment"])
        emit("hetero", f"two_model,{m},ttft_p99_ms", 1e3 * s["ttft_p99"])
    emit("hetero", "two_model,avg_gpus", rep.avg_gpus())


def smoke():
    """~15 s sanity pass for scripts/check.sh: one small config through
    both engines, a tails smoke row (priority classes + preemption
    through the event engine), a heterogeneous-fleet row (mixed chips/TP
    through run_spec), a kvtiers row (paged KV + host-DRAM swap + prefix
    reuse on the contended fleet), a gateway row (hashtrie locality
    routing + lazy paging on the hot-prompt trace), a deflect row
    (chunked prefill deflection on the saturated burst fleet), and a
    chaos row (seeded fault injection with the self-healing control
    plane)."""
    from repro.sim.traces import DEFAULT_PRIORITY_MIX
    for eng in ["fluid", "events"]:
        rep = run_policy("tokenscale", "azure_conv", duration=20.0, rps=6.0,
                         seed=0, engine=eng)
        emit("smoke", f"{eng},requests", len(rep.requests))
        emit("smoke", f"{eng},slo_pct", 100 * rep.slo_attainment())
        emit("smoke", f"{eng},avg_gpus", rep.avg_gpus())
    cfg = dict(TAILS_CFG)
    cfg["duration"] = 22.0
    rep = run_policy("tokenscale", "burstgpt2", engine="events",
                     preemption="evict-lowest",
                     priority_mix=DEFAULT_PRIORITY_MIX, **cfg)
    emit("smoke", "tails,preemptions", len(rep.preemptions))
    emit("smoke", "tails,class0_ttft_p99_ms",
         1e3 * rep.percentile("ttft", 99, priority=0))
    emit("smoke", "tails,class0_slo_pct", 100 * rep.slo_attainment(0))
    rep = run_spec(hetero_demo_spec(duration=20.0, rps=6.0,
                                    engine="events"))
    emit("smoke", "hetero,requests", len(rep.requests))
    emit("smoke", "hetero,slo_pct", 100 * rep.slo_attainment())
    emit("smoke", "hetero,avg_gpus", rep.avg_gpus())
    rep = run_kvtiers_variant("swap+prefix", duration=22.0)
    ks = rep.kv_summary()
    emit("smoke", "kvtiers,preemptions", len(rep.preemptions))
    emit("smoke", "kvtiers,swap_outs", ks["swap_outs"])
    emit("smoke", "kvtiers,prefix_hit_rate_pct",
         100 * ks["prefix_hit_rate"])
    emit("smoke", "kvtiers,peak_blocks_frac", ks["peak_blocks_frac"])
    rep = run_gateway_variant("gateway", duration=22.0)
    gw = rep.gw_summary()
    emit("smoke", "gateway,requests", len(rep.requests))
    emit("smoke", "gateway,affinity_hits", gw["affinity_hits"])
    emit("smoke", "gateway,balanced_fallbacks", gw["balanced"])
    emit("smoke", "gateway,block_grows", gw["block_grows"])
    emit("smoke", "gateway,ttft_p99_ms", 1e3 * rep.percentile("ttft", 99))
    rep = run_deflect_variant("chunked", duration=20.0)
    emit("smoke", "deflect,requests", len(rep.requests))
    emit("smoke", "deflect,deflected", rep.n_deflected)
    emit("smoke", "deflect,ttft_p99_ms", 1e3 * rep.percentile("ttft", 99))
    emit("smoke", "deflect,tpot_p99_ms", 1e3 * rep.percentile("tpot", 99))
    rep = run_pareto_variant("coord", duration=30.0)
    cs = rep.cost_summary()
    emit("smoke", "pareto,requests", len(rep.requests))
    emit("smoke", "pareto,slo_pct", 100 * rep.slo_attainment())
    emit("smoke", "pareto,cost_dollars", cs["cost_dollars"])
    emit("smoke", "pareto,avg_gpus", rep.avg_gpus())
    rep = run_chaos_variant("recovery", duration=35.0)
    fs = rep.fault_summary()
    emit("smoke", "chaos,requests", len(rep.requests))
    emit("smoke", "chaos,slo_pct", 100 * rep.slo_attainment())
    emit("smoke", "chaos,crashes", fs["crashes"])
    emit("smoke", "chaos,restarts", fs["restarts"])
    emit("smoke", "chaos,residents_requeued", fs["residents_requeued"])


def perfscale():
    """Simulator wall-clock trajectory rows (benchmarks/perf.py): the
    tails replay + a scaled-down slice of the million-request streaming
    scenario.  The full suite (1M requests, 64-instance fleet) and the
    BENCH_sim.json trajectory live in ``python -m benchmarks.perf``."""
    from benchmarks.perf import run_million, run_tails_replay
    row = run_tails_replay(duration=22.0)
    for k, v in row.items():
        emit("perfscale", f"tails_replay_smoke,{k}", v)
    row = run_million(duration=300.0)
    for k, v in row.items():
        emit("perfscale", f"stream_smoke,{k}", v)


def obs():
    """Flight-recorder end-to-end row (scripts/check.sh): replay the
    deflect burst cell with telemetry on through *both* engines, write
    JSONL + Chrome-trace JSON (to --trace-out, or the system temp dir),
    schema-validate the JSONL, and run the scaling-decision explainer —
    the full record -> export -> explain pipeline in one bench."""
    import tempfile
    from repro.obs.explain import explain
    from repro.obs.export import (load_jsonl, validate_trace_lines,
                                  write_chrome_trace, write_jsonl)
    global TRACE_OUT
    if TRACE_OUT is None:
        TRACE_OUT = os.path.join(tempfile.gettempdir(), "obs_trace.jsonl")
    cfg = dict(DEFLECT_CFG)
    cfg["duration"] = 20.0
    for eng in ["fluid", "events"]:
        rep = run_policy("tokenscale", "burstgpt1", engine=eng,
                         prefill_chunking=DEFLECT_VARIANTS["chunked"],
                         telemetry=True, **cfg)
        rec = rep.obs
        jsonl_path, chrome_path = _trace_paths(eng)
        n_lines = write_jsonl(rec, jsonl_path)
        write_chrome_trace(rec, chrome_path)
        records = load_jsonl(jsonl_path)
        errors = validate_trace_lines(records)
        report = explain(records)
        emit("obs", f"{eng},requests", len(rec.requests))
        emit("obs", f"{eng},trace_lines", n_lines)
        emit("obs", f"{eng},schema_errors", len(errors))
        emit("obs", f"{eng},decisions", report["n_decisions"])
        emit("obs", f"{eng},scale_ups", len(report["scale_ups"]))
        emit("obs", f"{eng},ttft_violations", len(report["violations"]))
        for stage, n in sorted(report["violations_by_stage"].items()):
            emit("obs", f"{eng},violations_{stage}", n)
        for e in errors:
            print(f"# obs schema error ({eng}): {e}", file=sys.stderr)
        if errors:
            sys.exit(f"obs bench: {len(errors)} schema errors in "
                     f"{jsonl_path}")


def run_spec_files(paths: list[str]):
    """Run declarative ExperimentSpec JSON files (--spec=...) and emit
    their summary + per-model rows.  With --trace-out, each spec runs
    with telemetry forced on and writes its flight-recorder trace."""
    import dataclasses
    for path in paths:
        spec = ExperimentSpec.load(path)
        if TRACE_OUT is not None:
            spec = dataclasses.replace(spec, telemetry=True)
        rep = run_spec(spec)
        tag = os.path.splitext(os.path.basename(path))[0]
        if TRACE_OUT is not None and rep.obs is not None:
            from repro.obs.export import write_chrome_trace, write_jsonl
            jsonl_path, chrome_path = _trace_paths(tag)
            emit("spec", f"{tag},trace_lines",
                 write_jsonl(rep.obs, jsonl_path))
            write_chrome_trace(rep.obs, chrome_path)
        for k, v in rep.summary().items():
            emit("spec", f"{tag},{k}", v)
        models = rep.models()
        if len(models) > 1:
            for m in models:
                for k, v in rep.model_summary(m).items():
                    emit("spec", f"{tag},{m},{k}", v)


BENCHES = {
    "fig3": fig3_overprovisioning,
    "table2": table2_velocities,
    "fig7": fig7_characterization,
    "fig9": fig9_end_to_end,
    "fig9b": fig9b_large_model,
    "fig10": fig10_burst_adaptation,
    "fig11": fig11_provision_correlation,
    "fig12": fig12_predictor_accuracy,
    "fig13": fig13_convertible_count,
    "fig14": fig14_ablation,
    "fig15": fig15_h100,
    "micro": engine_microbench,
    "simspeed": sim_throughput,
    "pd": pd_runtime,
    "kv8": kv8_velocity,
    "multipod": multipod_scaling,
    "diffval": diffval,
    "tails": tails,
    "kvtiers": kvtiers,
    "gateway": gateway,
    "deflect": deflect,
    "pareto": pareto,
    "chaos": chaos,
    "hetero": hetero,
    "perfscale": perfscale,
    "obs": obs,
    "smoke": smoke,
}


def parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("benches", nargs="*", metavar="bench",
                    help="bench names to run (default: all); "
                         f"one of {', '.join(sorted(BENCHES))}")
    ap.add_argument("--engine", default="fluid",
                    help="simulation engine for every sim-shaped bench "
                         "(fluid | events; DESIGN.md §1)")
    ap.add_argument("--bench", action="append", default=[],
                    metavar="NAME[,NAME...]",
                    help="comma-separated bench names (may repeat; "
                         "equivalent to positional args)")
    ap.add_argument("--spec", action="append", default=[], metavar="JSON",
                    help="run a declarative ExperimentSpec JSON file "
                         "(may repeat); skips the default all-bench run")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="flight-recorder output base path: the obs bench "
                         "and --spec runs record telemetry and write "
                         "PATH-<tag>.jsonl + PATH-<tag>.chrome.json "
                         "(repro.obs; default: telemetry off)")
    return ap.parse_args(argv)


def main(argv=None) -> None:
    global ENGINE, TRACE_OUT
    args = parse_args(argv)
    get_engine(args.engine)         # fail fast on unknown engine names
    ENGINE = args.engine
    TRACE_OUT = args.trace_out
    names = list(args.benches)
    for group in args.bench:
        names += [n for n in group.split(",") if n]
    for n in names:
        if n not in BENCHES:
            sys.exit(f"unknown bench {n!r}; expected one of "
                     f"{', '.join(sorted(BENCHES))}")
    if not names and not args.spec:
        names = list(BENCHES)
    print("bench,metric,value")
    run_spec_files(args.spec)
    for n in names:
        t0 = time.perf_counter()
        BENCHES[n]()
        print(f"# {n} done in {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
