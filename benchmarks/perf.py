"""Simulator performance benchmarks — the tracked perf trajectory.

    PYTHONPATH=src python -m benchmarks.perf                 # run + compare
    PYTHONPATH=src python -m benchmarks.perf --update        # refresh BENCH_sim.json
    PYTHONPATH=src python -m benchmarks.perf --scenario million
    PYTHONPATH=src python -m benchmarks.perf --smoke --budget 6.0   # CI row

Three scenarios, each emitting {wall-clock seconds, events/sec, peak RSS}:

  * ``tails_replay`` — 12 cells of the tails bench (2 contended traces x
    2 policies x 3 preemption modes on the memory-tight qwen25-32B TP2 /
    2-instance fleet): the preemption/backpressure hot path;
  * ``million``      — a ~1M-request, 2.5-hour azure_code burst trace
    streamed through the event engine (``sim.traces.stream_trace``; the
    heap holds only live events): the long-trace scale path;
  * ``hetero64``     — a 64-instance two-model mixed-chip fleet (a100 +
    h100 pools) for 60 s at event fidelity: the wide-fleet path.

``BENCH_sim.json`` at the repo root records the trajectory:

  * ``baseline_pre_pr`` — the seed code's numbers for the same scenarios,
    measured once before the O(1)-hot-path rework and kept for reference
    (the tails replay must stay >= 5x faster than it);
  * ``current``         — refreshed with ``--update`` whenever a PR
    changes simulator performance on purpose (the JSON diff is part of
    the review surface, like the golden fixtures).

The default (no ``--update``) run compares fresh numbers against the
committed ``current`` entry and the pre-PR baseline, flagging regressions
>25% without failing (wall clock is machine-dependent; the hard gate is
the --smoke budget row in scripts/check.sh).
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import sys
import time

REPO = os.path.join(os.path.dirname(__file__), "..")
BENCH_PATH = os.path.join(REPO, "BENCH_sim.json")

from repro.core import OutputPredictor, single_pool_fleet  # noqa: E402
from repro.core.autoscaler import build_policy  # noqa: E402
from repro.core.fleet import (ExperimentSpec, FleetSpec,  # noqa: E402
                              PerModelFleetPolicy, PoolSpec, TraceRoute)
from repro.sim.events import EventCluster  # noqa: E402
from repro.sim.runner import build_fleet, run_policy, run_spec  # noqa: E402
from repro.sim.traces import DEFAULT_PRIORITY_MIX, stream_trace  # noqa: E402


def _peak_rss_gb() -> float:
    """Process RSS watermark.  ru_maxrss is KiB on Linux but bytes on
    macOS.  Cumulative across the process, so in an all-scenario run it
    reflects the heaviest scenario executed so far."""
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return rss / (1e9 if sys.platform == "darwin" else 1e6)


# ---------------------------------------------------------------------------
# Scenarios
# ---------------------------------------------------------------------------

#: the tails-bench contention fleet (benchmarks.run.TAILS_CFG)
TAILS_CFG = dict(model="qwen25_32b", tp=2, duration=30.0, rps=8.0, seed=0,
                 max_instances=2)
TAILS_GRID = [(trace, pol, mode)
              for trace in ("burstgpt2", "azure_code")
              for pol in ("tokenscale", "distserve")
              for mode in ("none", "evict-lowest", "pause-requeue")]


def run_tails_replay(duration: float = None) -> dict:
    """Replay 12 tails-bench cells through the event engine (the
    preemption/backpressure hot path; ``duration`` shortens the cells for
    the CI smoke row)."""
    cfg = dict(TAILS_CFG)
    if duration is not None:
        cfg["duration"] = duration
    t0 = time.perf_counter()
    n_req = n_ev = 0
    for trace, pol, mode in TAILS_GRID:
        rep = run_policy(pol, trace, engine="events", preemption=mode,
                         priority_mix=DEFAULT_PRIORITY_MIX, **cfg)
        n_req += len(rep.requests)
        n_ev += rep.n_events
    wall = time.perf_counter() - t0
    return {"wall_s": round(wall, 2), "requests": n_req, "events": n_ev,
            "events_per_s": round(n_ev / wall), "peak_rss_gb":
            round(_peak_rss_gb(), 3)}


#: the million-request scenario: >1M azure_code requests over 2.5 h,
#: streamed (never fully materialized) through the event engine on an
#: autoscaled qwen2-0.5B fleet
MILLION = dict(model="qwen2_0_5b", trace="azure_code", rps=115.0,
               duration=9000.0, seed=0, max_instances=256)


def run_million(duration: float = None, rps: float = None) -> dict:
    m = dict(MILLION)
    if duration is not None:
        m["duration"] = duration
    if rps is not None:
        m["rps"] = rps
    fs = single_pool_fleet(m["model"], "a100", 1, trace=m["trace"],
                           rps=m["rps"], n_convertible=1)
    fleet = build_fleet(fs)
    g = fleet.groups[m["model"]]
    pol = build_policy("tokenscale", g.prefill.prof,
                       decode_prof=g.decode.prof,
                       mean_in=2048.0, mean_out=80.0, n_convertible=1)
    cl = EventCluster(fleet, policy=PerModelFleetPolicy({m["model"]: pol}),
                      predictor=OutputPredictor(0.85, m["seed"]),
                      max_instances=m["max_instances"])
    t0 = time.perf_counter()
    rep = cl.run(stream_trace(m["trace"], m["duration"], m["rps"],
                              seed=m["seed"]),
                 duration=m["duration"] + 30.0)
    wall = time.perf_counter() - t0
    return {"wall_s": round(wall, 2), "requests": len(rep.requests),
            "events": cl.n_events, "events_per_s": round(cl.n_events / wall),
            "peak_rss_gb": round(_peak_rss_gb(), 3),
            "slo_attainment": round(rep.slo_attainment(), 4)}


def hetero64_spec(duration: float = 60.0) -> ExperimentSpec:
    """A 64-instance two-model mixed-chip fleet (8+20+4 instances per
    model, a100 + h100 pools)."""
    return ExperimentSpec(
        fleet=FleetSpec(
            pools=(
                PoolSpec("llama-pre", "prefill", "llama31_8b", "a100",
                         init=8),
                PoolSpec("llama-dec", "decode", "llama31_8b", "a100",
                         init=20),
                PoolSpec("llama-conv", "convertible", "llama31_8b", "a100",
                         init=4),
                PoolSpec("qwen-pre", "prefill", "qwen25_32b", "a100", tp=2,
                         init=8),
                PoolSpec("qwen-dec", "decode", "qwen25_32b", "h100", tp=1,
                         init=20),
                PoolSpec("qwen-conv", "convertible", "qwen25_32b", "h100",
                         tp=1, init=4),
            ),
            routes=(TraceRoute("llama31_8b", "azure_conv", rps=30.0),
                    TraceRoute("qwen25_32b", "azure_code", rps=10.0))),
        policy="tokenscale", engine="events", duration=duration, seed=0,
        max_instances=96)


def run_hetero64(duration: float = 60.0) -> dict:
    t0 = time.perf_counter()
    rep = run_spec(hetero64_spec(duration))
    wall = time.perf_counter() - t0
    return {"wall_s": round(wall, 2), "requests": len(rep.requests),
            "peak_rss_gb": round(_peak_rss_gb(), 3)}


SCENARIOS = {
    "tails_replay": run_tails_replay,
    "million": run_million,
    "hetero64": run_hetero64,
}


# ---------------------------------------------------------------------------
# Trajectory file
# ---------------------------------------------------------------------------

def load_bench() -> dict:
    if os.path.exists(BENCH_PATH):
        with open(BENCH_PATH) as f:
            return json.load(f)
    return {}


def save_bench(data: dict):
    with open(BENCH_PATH, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.normpath(BENCH_PATH)}")


def compare(fresh: dict, recorded: dict, label: str):
    for name, row in fresh.items():
        old = (recorded or {}).get(name)
        if not old or not isinstance(old.get("wall_s"), (int, float)):
            continue
        ratio = row["wall_s"] / max(old["wall_s"], 1e-9)
        flag = "  <-- >25% slower than " + label if ratio > 1.25 else ""
        print(f"  vs {label} {name}: {old['wall_s']}s -> "
              f"{row['wall_s']}s ({ratio:.2f}x){flag}")


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def smoke(budget: float) -> int:
    """CI row (scripts/check.sh): one contended tails cell + a scaled-down
    streaming slice must finish inside ``budget`` wall-clock seconds —
    the hard regression gate for the O(1) hot-path rework (the reworked
    engines run this in ~2.5-4 s depending on machine load; the seed
    code's O(batch) hot paths took minutes on the streaming slice, so
    the default 12 s budget has wide machine-noise headroom while still
    catching any real complexity regression)."""
    t0 = time.perf_counter()
    rep = run_policy("tokenscale", "burstgpt2", engine="events",
                     preemption="evict-lowest",
                     priority_mix=DEFAULT_PRIORITY_MIX,
                     **{**TAILS_CFG, "duration": 22.0})
    row = run_million(duration=120.0)
    wall = time.perf_counter() - t0
    print(f"perfscale-smoke,wall_s,{wall:.2f}")
    print(f"perfscale-smoke,tails_requests,{len(rep.requests)}")
    print(f"perfscale-smoke,stream_requests,{row['requests']}")
    print(f"perfscale-smoke,budget_s,{budget}")
    if wall > budget:
        print(f"perfscale-smoke,FAIL,wall {wall:.2f}s exceeds the "
              f"{budget}s budget", file=sys.stderr)
        return 1
    print("perfscale-smoke,ok,within budget")
    return 0


def guard(tolerance: float = 0.03, runs: int = 3,
          update: bool = False) -> int:
    """Trace-off overhead gate (scripts/check.sh): rerun the tails-replay
    smoke cells (22 s, telemetry off — the flight-recorder hooks cost one
    ``obs is None`` test each) and fail if wall time regresses more than
    ``tolerance`` vs the ``tails_replay_smoke`` row in BENCH_sim.json.
    Best-of-``runs`` damps scheduler noise; ``--update`` records a fresh
    baseline instead of comparing."""
    best = None
    for _ in range(runs):
        row = run_tails_replay(duration=22.0)
        if best is None or row["wall_s"] < best["wall_s"]:
            best = row
    print(f"obs-guard,wall_s,{best['wall_s']}")
    print(f"obs-guard,requests,{best['requests']}")
    data = load_bench()
    if update:
        data.setdefault("current", {})["tails_replay_smoke"] = {
            "wall_s": best["wall_s"], "requests": best["requests"]}
        save_bench(data)
        return 0
    base = data.get("current", {}).get("tails_replay_smoke", {}) \
        .get("wall_s")
    if not isinstance(base, (int, float)):
        print("obs-guard,FAIL,no tails_replay_smoke baseline in "
              "BENCH_sim.json (record one with --guard --update)",
              file=sys.stderr)
        return 1
    ratio = best["wall_s"] / max(base, 1e-9)
    print(f"obs-guard,baseline_s,{base}")
    print(f"obs-guard,ratio,{ratio:.3f}")
    if ratio > 1.0 + tolerance:
        print(f"obs-guard,FAIL,wall {best['wall_s']}s is {ratio:.2f}x the "
              f"recorded {base}s (tolerance {tolerance:.0%})",
              file=sys.stderr)
        return 1
    print(f"obs-guard,ok,within {tolerance:.0%} of baseline")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.perf", description=__doc__,
                                 formatter_class=argparse
                                 .RawDescriptionHelpFormatter)
    ap.add_argument("--update", action="store_true",
                    help="write the fresh numbers to BENCH_sim.json's "
                         "'current' entry (review the diff like a golden)")
    ap.add_argument("--scenario", action="append", default=[],
                    choices=sorted(SCENARIOS),
                    help="scenario subset (may repeat; default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI budget row: a quick cell with a wall-clock "
                         "assertion; exits nonzero over budget")
    ap.add_argument("--budget", type=float, default=12.0,
                    help="--smoke wall-clock budget in seconds")
    ap.add_argument("--guard", action="store_true",
                    help="trace-off overhead gate: rerun the tails-replay "
                         "smoke and fail if wall time regresses >3% vs "
                         "BENCH_sim.json's tails_replay_smoke row "
                         "(with --update: record a fresh baseline)")
    ap.add_argument("--tolerance", type=float, default=0.03,
                    help="--guard regression tolerance (fraction)")
    args = ap.parse_args(argv)
    if args.guard:
        return guard(args.tolerance, update=args.update)
    if args.smoke:
        return smoke(args.budget)
    names = args.scenario or sorted(SCENARIOS)
    fresh = {}
    for name in names:
        print(f"== {name} ==", flush=True)
        row = fresh[name] = SCENARIOS[name]()
        for k, v in row.items():
            print(f"{name},{k},{v}")
    data = load_bench()
    compare(fresh, data.get("baseline_pre_pr"), "pre-PR baseline")
    compare(fresh, data.get("current"), "recorded current")
    if args.update:
        cur = data.setdefault("current", {})
        cur.update(fresh)
        save_bench(data)
    return 0


if __name__ == "__main__":
    sys.exit(main())
