"""Render the §Dry-run / §Roofline tables from results_dryrun.jsonl.

    PYTHONPATH=src python -m benchmarks.roofline_report [results_dryrun.jsonl]

Emits markdown: the full per-(arch x shape) roofline table (single-pod,
as prescribed), the multi-pod lowering check, and the three hillclimb
candidates (worst roofline fraction / most collective-bound / most
paper-representative).
"""
from __future__ import annotations

import json
import sys


def load(path: str) -> dict:
    recs = {}
    for line in open(path):
        r = json.loads(line)
        recs[(r["arch"], r["shape"], r["mesh"])] = r   # keep last
    return recs


def fmt_t(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def render(recs: dict) -> str:
    out = []
    out.append("### Single-pod (16x16 = 256 chips) roofline — all pairs\n")
    out.append("| arch | shape | compute | memory | collective |"
               " bottleneck | MODEL/HLO flops | coll GB/chip |")
    out.append("|---|---|---|---|---|---|---|---|")
    rows = []
    for (arch, shape, mesh), r in sorted(recs.items()):
        if mesh != "16x16":
            continue
        if r["status"] == "skipped":
            out.append(f"| {arch} | {shape} | — | — | — | *skipped:"
                       f" sub-quadratic required* | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {arch} | {shape} | ERROR | | | | | |")
            continue
        rows.append(r)
        out.append(
            f"| {arch} | {shape} | {fmt_t(r['t_compute_s'])} "
            f"| {fmt_t(r['t_memory_s'])} | {fmt_t(r['t_collective_s'])} "
            f"| **{r['bottleneck']}** | {r['useful_ratio']:.2f} "
            f"| {r['collective_bytes_per_chip'] / 1e9:.1f} |")

    out.append("\n### Multi-pod (2x16x16 = 512 chips) lowering check\n")
    ok = sum(1 for (a, s, m), r in recs.items()
             if m == "2x16x16" and r["status"] == "ok")
    sk = sum(1 for (a, s, m), r in recs.items()
             if m == "2x16x16" and r["status"] == "skipped")
    er = [(a, s) for (a, s, m), r in recs.items()
          if m == "2x16x16" and r["status"] == "error"]
    out.append(f"{ok} pairs compile, {sk} documented skips, "
               f"{len(er)} errors {er if er else ''}.")

    # hillclimb candidate selection
    out.append("\n### Hillclimb candidates\n")
    worst = min(rows, key=lambda r: r["useful_ratio"])
    coll = max(rows, key=lambda r: (r["t_collective_s"]
                                    / max(r["t_compute_s"]
                                          + r["t_memory_s"], 1e-12)))
    out.append(f"* worst useful-flops fraction: **{worst['arch']} x "
               f"{worst['shape']}** (ratio {worst['useful_ratio']:.3f})")
    out.append(f"* most collective-bound: **{coll['arch']} x "
               f"{coll['shape']}** (t_coll {fmt_t(coll['t_collective_s'])} "
               f"vs compute+mem {fmt_t(coll['t_compute_s'] + coll['t_memory_s'])})")
    out.append("* most paper-representative: **llama-3.1-8b x decode_32k** "
               "(the paper's small-model decode stage — V_D's roofline)")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results_dryrun.jsonl"
    print(render(load(path)))


if __name__ == "__main__":
    main()
