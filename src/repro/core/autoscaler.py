"""Autoscaling policies: TokenScale (§IV-C) and the three baselines (§V).

All policies consume the same ``Observation`` snapshot (what a metrics
plane would report each interval) and output desired instance counts; the
cluster simulator executes them with realistic startup latency.

  * TokenScale  — velocity-ratio scaling, Eq.(2)-(4)
  * DistServe   — RPS thresholds for both stages (Table I)
  * AIBrix      — concurrency-based prefiller + GPU-memory-utilization
                  (Knative KPA-style) decoder
  * BlitzScale  — request-count thresholds for both stages + "live" scaling
                  (scale-up start latency removed, §V Baselines)
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.core.velocity import BUCKETS, VelocityProfile


@dataclass
class Observation:
    """Rolling-window metrics snapshot handed to a policy every interval."""
    t: float
    # arrival-side (gateway measurements)
    token_rate_in: float                 # input tok/s (1 s window)
    token_rate_by_bucket: dict[str, float]  # in+predicted-out tok/s per bucket
    rps: float                           # requests/s (1 s window)
    # system-side
    prefill_queue: int                   # requests queued/being prefilled
    decode_inflight: int                 # requests in decode
    mem_util: float                      # mean decoder HBM utilization [0,1]
    ttft_p99: float = 0.0
    tpot_p99: float = 0.0
    cur_prefillers: int = 1
    cur_decoders: int = 1


@dataclass
class ScaleDecision:
    prefillers: int
    decoders: int
    live: bool = False    # BlitzScale: hide startup latency on scale-up


class Policy:
    name = "base"
    def decide(self, obs: Observation) -> ScaleDecision:  # pragma: no cover
        raise NotImplementedError


class _DownHysteresis:
    """Scale down only after the lower target persists for `delay` s."""
    def __init__(self, delay: float = 5.0):
        self.delay = delay
        self._since: dict[str, float] = {}
        self._pending: dict[str, int] = {}

    def apply(self, key: str, cur: int, target: int, t: float) -> int:
        if target >= cur:
            # scale-up (or hold): clear any stale countdown so the next
            # downscale starts a fresh timer
            self._since.pop(key, None)
            self._pending.pop(key, None)
            return target
        if self._pending.get(key) != target:
            # any *change* of the pending target — deeper or shallower —
            # restarts the countdown: a fleet may only drop to a target
            # that persisted for the full delay
            self._since[key] = t
            self._pending[key] = target
        if t - self._since[key] >= self.delay:
            return target
        return cur


# ---------------------------------------------------------------------------
# TokenScale (Eq. 2-4)
# ---------------------------------------------------------------------------

class TokenScalePolicy(Policy):
    name = "tokenscale"

    def __init__(self, profile: VelocityProfile, convertible: int = 1,
                 min_prefillers: int = 1, min_decoders: int = 1,
                 down_delay: float = 5.0):
        self.prof = profile
        self.convertible = convertible
        self.min_p, self.min_d = min_prefillers, min_decoders
        self.hyst = _DownHysteresis(down_delay)

    def decide(self, obs: Observation) -> ScaleDecision:
        # Eq. (2): prefillers from the input token arrival rate vs the
        # slower of prefill/network velocity
        v_eff = min(self.prof.v_prefill, self.prof.v_network)
        i_p = math.ceil(obs.token_rate_in / max(v_eff, 1e-9))
        # Eq. (3): decoders summed per bucket
        i_d_f = sum(rate / max(self.prof.v_decode.get(b, 1e9), 1e-9)
                    for b, rate in obs.token_rate_by_bucket.items())
        i_d = math.ceil(i_d_f)
        # Eq. (4): regular decoders net of the fixed convertible pool
        i_d_reg = max(i_d - self.convertible, 0)
        i_p = max(i_p, self.min_p)
        i_d_reg = max(i_d_reg, self.min_d)
        i_p = self.hyst.apply("p", obs.cur_prefillers, i_p, obs.t)
        i_d_reg = self.hyst.apply("d", obs.cur_decoders, i_d_reg, obs.t)
        return ScaleDecision(i_p, i_d_reg)


# ---------------------------------------------------------------------------
# DistServe: RPS thresholds (Table I)
# ---------------------------------------------------------------------------

class DistServePolicy(Policy):
    name = "distserve"

    def __init__(self, rps_per_prefiller: float = 14.0,
                 rps_per_decoder: float = 28.0, down_delay: float = 5.0):
        self.rp, self.rd = rps_per_prefiller, rps_per_decoder
        self.hyst = _DownHysteresis(down_delay)

    def decide(self, obs: Observation) -> ScaleDecision:
        i_p = max(math.ceil(obs.rps / self.rp), 1)
        i_d = max(math.ceil(obs.rps / self.rd), 1)
        i_p = self.hyst.apply("p", obs.cur_prefillers, i_p, obs.t)
        i_d = self.hyst.apply("d", obs.cur_decoders, i_d, obs.t)
        return ScaleDecision(i_p, i_d)


# ---------------------------------------------------------------------------
# AIBrix: concurrency prefiller + memory-utilization decoder (Table I)
# ---------------------------------------------------------------------------

class AIBrixPolicy(Policy):
    name = "aibrix"

    def __init__(self, conc_per_prefiller: float = 7.0,
                 mem_util_target: float = 0.7, window_s: float = 5.0,
                 down_delay: float = 10.0):
        self.cp = conc_per_prefiller
        self.target = mem_util_target
        self.window_s = window_s
        self._hist: list[tuple[float, float, float]] = []
        self.hyst = _DownHysteresis(down_delay)

    def decide(self, obs: Observation) -> ScaleDecision:
        # sliding-window average of concurrency and utilization — this is
        # precisely why AIBrix lags bursts (§II-D)
        self._hist.append((obs.t, float(obs.prefill_queue), obs.mem_util))
        self._hist = [h for h in self._hist if obs.t - h[0] <= self.window_s]
        conc = sum(h[1] for h in self._hist) / len(self._hist)
        util = sum(h[2] for h in self._hist) / len(self._hist)
        i_p = max(math.ceil(conc / self.cp), 1)
        # KPA: desired = ceil(current * util / target)
        i_d = max(math.ceil(obs.cur_decoders * util / self.target), 1)
        i_p = self.hyst.apply("p", obs.cur_prefillers, i_p, obs.t)
        i_d = self.hyst.apply("d", obs.cur_decoders, i_d, obs.t)
        return ScaleDecision(i_p, i_d)


# ---------------------------------------------------------------------------
# BlitzScale: request-count thresholds + live scaling (Table I)
# ---------------------------------------------------------------------------

class ComboPolicy(Policy):
    """Ablation helper (§VI-D): prefiller decisions from one policy,
    decoder decisions from another (B, B+P, B+P+D configurations)."""

    def __init__(self, p_policy: Policy, d_policy: Policy, name: str):
        self.p_policy = p_policy
        self.d_policy = d_policy
        self.name = name

    def decide(self, obs: Observation) -> ScaleDecision:
        p = self.p_policy.decide(obs)
        d = self.d_policy.decide(obs)
        return ScaleDecision(p.prefillers, d.decoders,
                             live=p.live or d.live)


class BlitzScalePolicy(Policy):
    name = "blitzscale"

    def __init__(self, req_per_prefiller: float = 7.0,
                 req_per_decoder: float = 45.0, window_s: float = 2.0,
                 down_delay: float = 10.0):
        self.rp, self.rd = req_per_prefiller, req_per_decoder
        self.window_s = window_s
        self._hist: list[tuple[float, float, float]] = []
        self.hyst = _DownHysteresis(down_delay)

    def decide(self, obs: Observation) -> ScaleDecision:
        self._hist.append((obs.t, float(obs.prefill_queue),
                           float(obs.decode_inflight)))
        self._hist = [h for h in self._hist if obs.t - h[0] <= self.window_s]
        conc_p = sum(h[1] for h in self._hist) / len(self._hist)
        conc_d = sum(h[2] for h in self._hist) / len(self._hist)
        i_p = max(math.ceil(conc_p / self.rp), 1)
        i_d = max(math.ceil(conc_d / self.rd), 1)
        i_p = self.hyst.apply("p", obs.cur_prefillers, i_p, obs.t)
        i_d = self.hyst.apply("d", obs.cur_decoders, i_d, obs.t)
        return ScaleDecision(i_p, i_d, live=True)
