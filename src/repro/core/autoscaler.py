"""Autoscaling policies: TokenScale (§IV-C) and the three baselines (§V).

All policies consume the same ``Observation`` snapshot (what a metrics
plane would report each interval) and output desired instance counts; the
cluster simulator executes them with realistic startup latency.

  * TokenScale  — velocity-ratio scaling, Eq.(2)-(4)
  * DistServe   — RPS thresholds for both stages (Table I)
  * AIBrix      — concurrency-based prefiller + GPU-memory-utilization
                  (Knative KPA-style) decoder
  * BlitzScale  — request-count thresholds for both stages + "live" scaling
                  (scale-up start latency removed, §V Baselines)

Policies are constructed uniformly through a string-keyed registry
(``@register_policy`` / ``build_policy``): every factory takes the
prefill pool's ``VelocityProfile``, the decode pool's (they differ on
heterogeneous fleets), and the trace's request-size statistics for the
baselines' Table I threshold derivations.  ``core.fleet`` adapts the
resulting per-model policies onto named pools.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.velocity import BUCKETS, VelocityProfile


@dataclass
class Observation:
    """Rolling-window metrics snapshot handed to a policy every interval."""
    t: float
    # arrival-side (gateway measurements)
    token_rate_in: float                 # input tok/s (1 s window)
    token_rate_by_bucket: dict[str, float]  # in+predicted-out tok/s per bucket
    rps: float                           # requests/s (1 s window)
    # system-side
    prefill_queue: int                   # requests queued/being prefilled
    decode_inflight: int                 # requests in decode
    mem_util: float                      # mean decoder HBM utilization [0,1]
    ttft_p99: float = 0.0
    tpot_p99: float = 0.0
    cur_prefillers: int = 1
    cur_decoders: int = 1
    # prefill tok/s the decode side is absorbing itself via chunked
    # deflection — the fraction of the arrival rate that partially-
    # prefilled requests no longer owe the prefill pool (0 with the
    # legacy wholesale-conversion path)
    deflected_rate: float = 0.0


@dataclass
class ScaleDecision:
    prefillers: int
    decoders: int
    live: bool = False    # BlitzScale: hide startup latency on scale-up


class Policy:
    name = "base"
    #: Eq. 2-4 intermediates of the most recent ``decide`` call, for the
    #: flight recorder's decision log (obs.explain).  Policies that don't
    #: expose their arithmetic leave it None; the recorder degrades to
    #: plan-only records.
    last_debug: Optional[dict] = None

    def decide(self, obs: Observation) -> ScaleDecision:  # pragma: no cover
        raise NotImplementedError


class _DownHysteresis:
    """Scale down only after the lower target persists for `delay` s."""
    def __init__(self, delay: float = 5.0):
        self.delay = delay
        self._since: dict[str, float] = {}
        self._pending: dict[str, int] = {}

    def apply(self, key: str, cur: int, target: int, t: float) -> int:
        if target >= cur:
            # scale-up (or hold): clear any stale countdown so the next
            # downscale starts a fresh timer
            self._since.pop(key, None)
            self._pending.pop(key, None)
            return target
        if self._pending.get(key) != target:
            # any *change* of the pending target — deeper or shallower —
            # restarts the countdown: a fleet may only drop to a target
            # that persisted for the full delay
            self._since[key] = t
            self._pending[key] = target
        if t - self._since[key] >= self.delay:
            return target
        return cur


# ---------------------------------------------------------------------------
# TokenScale (Eq. 2-4)
# ---------------------------------------------------------------------------

class TokenScalePolicy(Policy):
    name = "tokenscale"

    def __init__(self, profile: VelocityProfile, convertible: int = 1,
                 min_prefillers: int = 1, min_decoders: int = 1,
                 down_delay: float = 5.0,
                 decode_profile: Optional[VelocityProfile] = None):
        # `profile` is the prefill pool's velocity profile; on heterogeneous
        # fleets the decode pool runs a different (model, chip, tp) tuple
        # and supplies its own profile for Eq. (3)
        self.prof = profile
        self.dprof = decode_profile or profile
        self.convertible = convertible
        self.min_p, self.min_d = min_prefillers, min_decoders
        self.hyst = _DownHysteresis(down_delay)

    def decide(self, obs: Observation) -> ScaleDecision:
        # Eq. (2): prefillers from the input token arrival rate vs the
        # slower of prefill/network velocity.  Chunk-deflected work is
        # subtracted first: a partially-prefilled request contributes only
        # the tokens the prefill pool still owes, so the decode side's own
        # absorption never provisions phantom prefillers (with chunking
        # off deflected_rate is 0.0 and this is the historical expression)
        v_eff = min(self.prof.v_prefill, self.prof.v_network)
        rate = max(obs.token_rate_in - obs.deflected_rate, 0.0)
        i_p_raw = math.ceil(rate / max(v_eff, 1e-9))
        # Eq. (3): decoders summed per bucket, at the decode pool's velocity
        i_d_f = sum(rate / max(self.dprof.v_decode.get(b, 1e9), 1e-9)
                    for b, rate in obs.token_rate_by_bucket.items())
        i_d = math.ceil(i_d_f)
        # Eq. (4): regular decoders net of the fixed convertible pool
        i_d_reg_raw = max(i_d - self.convertible, 0)
        i_p = max(i_p_raw, self.min_p)
        i_d_reg = max(i_d_reg_raw, self.min_d)
        i_p = self.hyst.apply("p", obs.cur_prefillers, i_p, obs.t)
        i_d_reg = self.hyst.apply("d", obs.cur_decoders, i_d_reg, obs.t)
        # flight-recorder breadcrumb: the full Eq. 2-4 arithmetic of this
        # interval, read (never fed back) by obs.explain via
        # ``FlightRecorder.on_plan``
        self.last_debug = {
            "policy": self.name,
            "eq2": {"token_rate_in": obs.token_rate_in,
                    "deflected_rate": obs.deflected_rate, "rate": rate,
                    "v_prefill": self.prof.v_prefill,
                    "v_network": self.prof.v_network, "v_eff": v_eff,
                    "i_p": i_p_raw},
            "eq3": {"rate_by_bucket": dict(obs.token_rate_by_bucket),
                    "v_decode": dict(self.dprof.v_decode), "i_d": i_d},
            "eq4": {"convertible": self.convertible,
                    "i_d_regular": i_d_reg_raw},
            "final": {"prefillers": i_p, "decoders": i_d_reg,
                      "cur_prefillers": obs.cur_prefillers,
                      "cur_decoders": obs.cur_decoders},
        }
        return ScaleDecision(i_p, i_d_reg)


# ---------------------------------------------------------------------------
# DistServe: RPS thresholds (Table I)
# ---------------------------------------------------------------------------

class DistServePolicy(Policy):
    name = "distserve"

    def __init__(self, rps_per_prefiller: float = 14.0,
                 rps_per_decoder: float = 28.0, down_delay: float = 5.0):
        self.rp, self.rd = rps_per_prefiller, rps_per_decoder
        self.hyst = _DownHysteresis(down_delay)

    def decide(self, obs: Observation) -> ScaleDecision:
        i_p = max(math.ceil(obs.rps / self.rp), 1)
        i_d = max(math.ceil(obs.rps / self.rd), 1)
        i_p = self.hyst.apply("p", obs.cur_prefillers, i_p, obs.t)
        i_d = self.hyst.apply("d", obs.cur_decoders, i_d, obs.t)
        return ScaleDecision(i_p, i_d)


# ---------------------------------------------------------------------------
# AIBrix: concurrency prefiller + memory-utilization decoder (Table I)
# ---------------------------------------------------------------------------

class AIBrixPolicy(Policy):
    name = "aibrix"

    def __init__(self, conc_per_prefiller: float = 7.0,
                 mem_util_target: float = 0.7, window_s: float = 5.0,
                 down_delay: float = 10.0):
        self.cp = conc_per_prefiller
        self.target = mem_util_target
        self.window_s = window_s
        self._hist: list[tuple[float, float, float]] = []
        self.hyst = _DownHysteresis(down_delay)

    def decide(self, obs: Observation) -> ScaleDecision:
        # sliding-window average of concurrency and utilization — this is
        # precisely why AIBrix lags bursts (§II-D)
        self._hist.append((obs.t, float(obs.prefill_queue), obs.mem_util))
        self._hist = [h for h in self._hist if obs.t - h[0] <= self.window_s]
        conc = sum(h[1] for h in self._hist) / len(self._hist)
        util = sum(h[2] for h in self._hist) / len(self._hist)
        i_p = max(math.ceil(conc / self.cp), 1)
        # KPA: desired = ceil(current * util / target)
        i_d = max(math.ceil(obs.cur_decoders * util / self.target), 1)
        i_p = self.hyst.apply("p", obs.cur_prefillers, i_p, obs.t)
        i_d = self.hyst.apply("d", obs.cur_decoders, i_d, obs.t)
        return ScaleDecision(i_p, i_d)


# ---------------------------------------------------------------------------
# BlitzScale: request-count thresholds + live scaling (Table I)
# ---------------------------------------------------------------------------

class ComboPolicy(Policy):
    """Ablation helper (§VI-D): prefiller decisions from one policy,
    decoder decisions from another (B, B+P, B+P+D configurations)."""

    def __init__(self, p_policy: Policy, d_policy: Policy, name: str):
        self.p_policy = p_policy
        self.d_policy = d_policy
        self.name = name

    def decide(self, obs: Observation) -> ScaleDecision:
        p = self.p_policy.decide(obs)
        d = self.d_policy.decide(obs)
        return ScaleDecision(p.prefillers, d.decoders,
                             live=p.live or d.live)


class BlitzScalePolicy(Policy):
    name = "blitzscale"

    def __init__(self, req_per_prefiller: float = 7.0,
                 req_per_decoder: float = 45.0, window_s: float = 2.0,
                 down_delay: float = 10.0):
        self.rp, self.rd = req_per_prefiller, req_per_decoder
        self.window_s = window_s
        self._hist: list[tuple[float, float, float]] = []
        self.hyst = _DownHysteresis(down_delay)

    def decide(self, obs: Observation) -> ScaleDecision:
        self._hist.append((obs.t, float(obs.prefill_queue),
                           float(obs.decode_inflight)))
        self._hist = [h for h in self._hist if obs.t - h[0] <= self.window_s]
        conc_p = sum(h[1] for h in self._hist) / len(self._hist)
        conc_d = sum(h[2] for h in self._hist) / len(self._hist)
        i_p = max(math.ceil(conc_p / self.rp), 1)
        i_d = max(math.ceil(conc_d / self.rd), 1)
        i_p = self.hyst.apply("p", obs.cur_prefillers, i_p, obs.t)
        i_d = self.hyst.apply("d", obs.cur_decoders, i_d, obs.t)
        return ScaleDecision(i_p, i_d, live=True)


# ---------------------------------------------------------------------------
# Policy registry: uniform, string-keyed construction
# ---------------------------------------------------------------------------

#: name -> factory(prof, decode_prof, mean_in, mean_out, n_convertible, **kw)
POLICY_REGISTRY: dict[str, Callable[..., Policy]] = {}


def register_policy(name: str):
    """Register a policy factory under ``name`` so TokenScale, the §V
    baselines, and future policies are constructed uniformly from a
    declarative ``ExperimentSpec`` (``core.fleet``).  Factories receive
    the prefill pool's profile, the decode pool's profile (they differ on
    heterogeneous fleets), the trace's mean request sizes (Table I
    threshold derivations), and the convertible pool size."""
    def deco(factory):
        POLICY_REGISTRY[name] = factory
        factory.policy_name = name
        return factory
    return deco


def build_policy(name: str, prof: VelocityProfile,
                 decode_prof: Optional[VelocityProfile] = None,
                 mean_in: Optional[float] = None,
                 mean_out: Optional[float] = None,
                 n_convertible: int = 0, **options) -> Policy:
    """Construct a registered policy.  ``mean_in``/``mean_out`` are
    required and must be the *actual* trace's request-size statistics
    (``sim.traces.trace_stats``) — the baselines derive their Table I
    thresholds from them, and the historical hardcoded 1024/240 defaults
    mis-calibrated baselines on skewed traces."""
    try:
        factory = POLICY_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; registered policies: "
            f"{sorted(POLICY_REGISTRY)}")
    if mean_in is None or mean_out is None:
        raise ValueError(
            "build_policy needs the workload's request-size stats "
            "(mean_in/mean_out; see sim.traces.trace_stats) — hardcoded "
            "defaults mis-calibrate baseline thresholds on skewed traces")
    return factory(prof, decode_prof=decode_prof or prof,
                   mean_in=mean_in, mean_out=mean_out,
                   n_convertible=n_convertible, **options)


@register_policy("tokenscale")
def _build_tokenscale(prof, decode_prof, mean_in, mean_out,
                      n_convertible, **kw):
    del mean_in, mean_out     # velocity-native: no size-derived thresholds
    return TokenScalePolicy(prof, convertible=n_convertible,
                            decode_profile=decode_prof, **kw)


@register_policy("distserve")
def _build_distserve(prof, decode_prof, mean_in, mean_out,
                     n_convertible, **kw):
    # "uses a simulator to determine scaling thresholds" — capacity/size
    # with a 0.7 safety factor (which is exactly why it overprovisions
    # after bursts, §VI-A)
    del n_convertible
    return DistServePolicy(
        rps_per_prefiller=max(0.7 * prof.v_prefill / mean_in, 0.5),
        rps_per_decoder=max(
            0.5 * decode_prof.v_decode_mean() / (mean_in + mean_out), 0.5),
        **kw)


@register_policy("aibrix")
def _build_aibrix(prof, decode_prof, mean_in, mean_out,
                  n_convertible, **kw):
    # Table I: concurrency threshold = max prefill throughput / average
    # prefill length (in requests); decoder fixed at 70% memory util
    del decode_prof, mean_out, n_convertible
    return AIBrixPolicy(
        conc_per_prefiller=max(prof.v_prefill / mean_in * 0.5, 1.0),
        mem_util_target=0.7, **kw)


@register_policy("blitzscale")
def _build_blitzscale(prof, decode_prof, mean_in, mean_out,
                      n_convertible, **kw):
    # Table I: prefiller = avg prefill length / max prefill throughput;
    # decoder = available KVC memory / per-request footprint
    del mean_out, n_convertible
    return BlitzScalePolicy(
        req_per_prefiller=max(prof.v_prefill / mean_in * 0.5, 1.0),
        req_per_decoder=max(decode_prof.max_batch.get("M-M", 45) * 0.6, 4.0),
        **kw)
