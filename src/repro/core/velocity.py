"""Token Velocity (§III-B) and the Offline Profiler (§IV-B).

Token Velocity = the maximum number of tokens an instance can *release* per
second under its current resources.  Per stage:

  * V_P  prefill velocity   — GPU-compute bound, constant per (model, chip)
  * V_N  network velocity   — KVC transfer rate over the interconnect
  * V_D  decode velocity    — rate at which decoders free memory as requests
                              complete; Eq.(1): V_D = sum_r L_r / TPOT,
                              profiled per request bucket (Table II)

The profiler reproduces the paper's methodology: sweep the request rate
against an instance until the output rate saturates; the saturation point is
the stage velocity.  Our "instance" is the analytic step-latency model in
``core.hardware`` (same roofline the JAX dry-run reports), and optionally a
real ``serving.Engine`` on CPU for reduced models.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache

from repro.configs.base import ModelConfig
from repro.core import hardware as hw
from repro.core.hardware import InstanceSpec

# ---------------------------------------------------------------------------
# Request buckets (Table II): input x output length classes
# ---------------------------------------------------------------------------

BUCKET_INPUT = {"S": 256, "M": 1024, "L": 8192}
BUCKET_OUTPUT = {"S": 100, "M": 350, "L": 610}
BUCKETS = [f"{i}-{o}" for i in "SML" for o in "SML"]


def bucket_of(in_len: int, out_len: int) -> str:
    i = "S" if in_len <= 256 else ("M" if in_len <= 1024 else "L")
    o = "S" if out_len <= 100 else ("M" if out_len <= 350 else "L")
    return f"{i}-{o}"


def bucket_lengths(bucket: str) -> tuple[int, int]:
    i, o = bucket.split("-")
    return BUCKET_INPUT[i], BUCKET_OUTPUT[o]


@dataclass(frozen=True)
class VelocityProfile:
    """Offline-profiled Token Velocities for one (model, instance) pair."""
    model: str
    chip: str
    tp: int
    v_prefill: float                    # tok/s
    v_network: float                    # tok/s
    v_decode: dict[str, float]          # bucket -> tok/s (Eq. 1)
    max_batch: dict[str, int]           # bucket -> HBM-bound batch
    tpot: dict[str, float]              # bucket -> iteration time at peak

    def v_decode_mean(self) -> float:
        return sum(self.v_decode.values()) / len(self.v_decode)


# ---------------------------------------------------------------------------
# Offline profiler
# ---------------------------------------------------------------------------

def profile_prefill_velocity(cfg: ModelConfig, inst: InstanceSpec,
                             probe_tokens: int = 8192) -> float:
    """Saturation sweep: raise the offered token rate until the instance's
    completion rate stops following it; that plateau is V_P."""
    t = hw.prefill_time(cfg, inst, probe_tokens)
    peak = probe_tokens / t
    # sweep (paper methodology): offered rate doubles until completion
    # rate saturates at `peak`
    offered, completed = probe_tokens / 4.0, 0.0
    while True:
        completed = min(offered, peak)
        if completed < offered:
            return completed
        offered *= 2.0


def profile_network_velocity(cfg: ModelConfig, inst: InstanceSpec) -> float:
    """Max token transmission rate prefiller -> decoder (KVC bytes/s /
    bytes-per-token)."""
    per_tok = hw.kv_bytes_per_token(cfg)
    if per_tok <= 0.0:
        # attention-free (SSM): only the O(1) recurrent state crosses the
        # wire — network velocity is effectively unbounded; return the rate
        # at which whole-request states can stream assuming 1k-token reqs.
        st = hw.state_bytes_fixed(cfg)
        return inst.chip.net_bw / max(st, 1.0) * 1000.0
    return inst.chip.net_bw / per_tok


def profile_decode_velocity(cfg: ModelConfig, inst: InstanceSpec,
                            bucket: str, tpot_slo: float = 0.1,
                            hbm_frac: float = 0.9) -> tuple[float, int, float]:
    """Per-bucket V_D (Eq. 1) at the largest SLO-feasible batch.

    Sweeps batch (the request-rate sweep's steady-state equivalent) until
    either HBM is exhausted or TPOT crosses the SLO; returns
    (v_decode, batch, tpot).  L_r counts the tokens whose memory a
    completion releases (input + output).  ``hbm_frac`` is the pool's
    usable-HBM fraction — the profiled capacity bound must match what the
    pool's decoders actually enforce."""
    in_len, out_len = bucket_lengths(bucket)
    avg_ctx = in_len + out_len / 2.0
    b_mem = hw.max_batch(cfg, inst, in_len + out_len, hbm_frac=hbm_frac)
    best = (0.0, 0, 0.0)
    b = 1
    while b <= max(b_mem, 1):
        tpot = hw.decode_iter_time(cfg, inst, b, avg_ctx)
        if tpot > tpot_slo and best[1] > 0:
            break
        # steady state: b/out_len completions per iteration, each releasing
        # (in+out) tokens => V_D = b * (in+out) / (out * TPOT)
        v = b * (in_len + out_len) / (out_len * max(tpot, 1e-9))
        best = (v, b, tpot)
        b = b * 2 if b < 64 else b + 64
    return best


def profile(cfg: ModelConfig, inst: InstanceSpec,
            tpot_slo: float = 0.1, hbm_frac: float = 0.9) -> VelocityProfile:
    v_d, mb, tp = {}, {}, {}
    for b in BUCKETS:
        v, batch, tpot = profile_decode_velocity(cfg, inst, b, tpot_slo,
                                                 hbm_frac)
        v_d[b], mb[b], tp[b] = v, batch, tpot
    return VelocityProfile(
        model=cfg.name, chip=inst.chip.name, tp=inst.tp,
        v_prefill=profile_prefill_velocity(cfg, inst),
        v_network=profile_network_velocity(cfg, inst),
        v_decode=v_d, max_batch=mb, tpot=tp)


@lru_cache(maxsize=None)
def profile_for(model: str, chip: str, tp: int = 1,
                tpot_slo: float = 0.1,
                hbm_frac: float = 0.9) -> VelocityProfile:
    """Cached profiler entry by pool key — Token Velocity is defined per
    (model, chip, tp) tuple (§III-B), and a heterogeneous fleet profiles
    each of its pools once, not once per experiment.  ``hbm_frac`` joins
    the cache key so a pool with a non-default usable-HBM fraction gets a
    profile whose Eq. 1/Eq. 3 capacity bounds match its own decoders."""
    from repro.configs import get_config
    from repro.core.hardware import CHIPS
    return profile(get_config(model), InstanceSpec(CHIPS[chip], tp=tp),
                   tpot_slo, hbm_frac)


# ---------------------------------------------------------------------------
# Convertible-decoder quantities (§III-D, Eq. 5-6)
# ---------------------------------------------------------------------------

def convertible_chunk_size(cfg: ModelConfig, inst: InstanceSpec,
                           decode_batch: int, avg_ctx: float,
                           tpot_slo: float = 0.1,
                           align: int = 128) -> int:
    """Largest prefill chunk a Convertible Decoder can co-schedule while the
    mixed iteration stays within the TPOT SLO (profiled by growing the chunk
    until violation, as §III-D)."""
    lo = 0
    c = align
    while True:
        t = mixed_iter_time(cfg, inst, decode_batch, avg_ctx, c)
        if t > tpot_slo:
            return lo
        lo = c
        c += align
        if c > 65536:
            return lo


def mixed_iter_time(cfg: ModelConfig, inst: InstanceSpec, decode_batch: int,
                    avg_ctx: float, chunk: int) -> float:
    """One co-located iteration: decode batch + `chunk` prefill tokens."""
    f = (decode_batch * (hw.flops_per_token(cfg)
                         + hw.attn_flops_per_token(cfg, avg_ctx))
         + chunk * (hw.flops_per_token(cfg)
                    + hw.attn_flops_per_token(cfg, chunk / 2)))
    mem = (hw.active_weight_bytes(cfg)
           + decode_batch * (hw.kv_bytes_per_token(cfg) * avg_ctx
                             + hw.state_bytes_fixed(cfg))
           + chunk * hw.kv_bytes_per_token(cfg))
    return max(f / inst.flops, mem / inst.hbm_bw)


def convertible_prefill_velocity(chunk_size: int, decode_batch: int,
                                 tpot_slo: float = 0.1) -> float:
    """Eq. (5): V_D^{P'} = (chunk_size - batch_size) / TPOT_SLO."""
    return max(chunk_size - decode_batch, 0) / tpot_slo


def reserved_memory(v_dp: float, mem_per_token: float,
                    ttft_slo: float) -> float:
    """Eq. (6): Mem_reserved = V_D^{P'} * Mem_T * TTFT_SLO."""
    return v_dp * mem_per_token * ttft_slo


# ---------------------------------------------------------------------------
# Chunked prefill / deflection quantities (§III-D at iteration granularity)
# ---------------------------------------------------------------------------

def headroom_chunk_tokens(f_iter: float, mem_iter: float,
                          flops_tok: float, kv_tok: float,
                          flops: float, hbm_bw: float,
                          tpot_budget: float, cap: float) -> float:
    """Eq. 5's headroom evaluated *online* against the live batch: the
    largest prefill chunk (whole tokens) a decoder can co-schedule in its
    next iteration while the mixed iteration stays within ``tpot_budget``.

    ``f_iter``/``mem_iter`` are the decode-only iteration's roofline terms
    (FLOPs, bytes); each chunk token adds ``flops_tok`` FLOPs and
    ``kv_tok`` KV-write bytes, so the roofline bound
    ``max((mem_iter + c*kv_tok)/hbm_bw, (f_iter + c*flops_tok)/flops)`` is
    monotone in ``c`` and the budget inverts in closed form — no profiling
    sweep on the hot path."""
    c_fl = (tpot_budget * flops - f_iter) / max(flops_tok, 1e-12)
    if kv_tok > 0:
        c_mem = (tpot_budget * hbm_bw - mem_iter) / kv_tok
    else:                       # attention-free: no KV bytes per token
        c_mem = float("inf")
    return float(int(max(min(cap, c_fl, c_mem), 0.0)))


def chunked_prefill_velocity(chunk_tokens: float, mixed_iter_t: float
                             ) -> float:
    """Steady-state absorption rate (tok/s) of chunk-interleaved prefill:
    one chunk per mixed iteration.  This is the per-iteration analogue of
    Eq. 5's V_D^{P'} (which assumes the iteration takes exactly TPOT_SLO)."""
    if chunk_tokens <= 0 or mixed_iter_t <= 0:
        return 0.0
    return chunk_tokens / mixed_iter_t


# ---------------------------------------------------------------------------
# Cost-normalized velocity (tokens per dollar) — the placement metric the
# coordinated fleet planner ranks heterogeneous pools by: among pools that
# can serve the same demand, the one releasing the most tokens per dollar
# absorbs first (DistServe's goodput-per-GPU framing, priced per chip).
# ---------------------------------------------------------------------------

def instance_cost_rate(chip: str, tp: int) -> float:
    """$/s of one (chip, tp) instance — ``ChipSpec.cost_per_hour`` times
    the TP degree, the same weighting the billing integral applies."""
    from repro.core.hardware import CHIPS
    return CHIPS[chip].cost_per_hour * tp / 3600.0


def prefill_tokens_per_dollar(prof: VelocityProfile) -> float:
    """Cost-normalized effective prefill velocity (tokens per dollar):
    Eq. 2's min(V_P, V_N) divided by the instance's $/s rate."""
    rate = instance_cost_rate(prof.chip, prof.tp)
    return min(prof.v_prefill, prof.v_network) / max(rate, 1e-12)


def decode_tokens_per_dollar(prof: VelocityProfile,
                             bucket: str = None) -> float:
    """Cost-normalized decode velocity (tokens per dollar), per bucket or
    averaged across Table II's buckets when ``bucket`` is None."""
    rate = instance_cost_rate(prof.chip, prof.tp)
    v = prof.v_decode[bucket] if bucket else prof.v_decode_mean()
    return v / max(rate, 1e-12)


def deflected_prefill_rate(decoders, window_s: float = 1.0) -> float:
    """Aggregate prefill-token rate (tok/s) the decode side is absorbing
    through chunked deflection right now: for each decoder with queued
    chunk work, the smaller of its absorption velocity and the work it
    actually holds (a queue of 40 tokens cannot absorb 4000 tok/s for the
    whole window).  ``TokenScalePolicy.decide`` subtracts this from Eq. 2's
    arrival rate so partially-prefilled requests contribute only the
    fraction the prefill pool still owes."""
    total = 0.0
    for d in decoders:
        if not d.prefill_q:
            continue
        v = d.deflect_velocity()
        if v > 0:
            total += min(v, d.inflight_tokens() / max(window_s, 1e-9))
    return total
