"""Convertible Decoder management (§III-D, §IV-D).

A Convertible Decoder is a decoder whose gateway routing can flip to accept
prefill work in <1 ms (weights are shared).  The *restriction* that protects
the co-located decode pool:

  * chunk size     — largest chunk keeping mixed-iteration TPOT within SLO
                     (profiled offline; ``velocity.convertible_chunk_size``)
  * prefill speed  — Eq. (5): V_D^{P'} = (chunk - batch) / TPOT_SLO
  * reserved HBM   — Eq. (6): Mem_R = V_D^{P'} * Mem_T * TTFT_SLO
  * pool size      — offline: ceil(max decoders over the trace x burst
                     ratio); NOT dynamically scaled (§IV-C2)
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.core import hardware as hw
from repro.core.hardware import InstanceSpec
from repro.core.velocity import (convertible_chunk_size,
                                 convertible_prefill_velocity,
                                 reserved_memory)


@dataclass(frozen=True)
class ConvertibleConfig:
    chunk_size: int
    v_prefill: float          # Eq. (5)
    mem_reserved: float       # Eq. (6), bytes
    pool_size: int            # number of convertible decoders (fixed)


def plan_convertible(cfg: ModelConfig, inst: InstanceSpec,
                     expected_decode_batch: int, avg_ctx: float,
                     burst_ratio: float, max_decoders: int,
                     tpot_slo: float = 0.1,
                     ttft_slo: float = 0.4) -> ConvertibleConfig:
    """Offline planning for the convertible pool (§IV-C2 + §III-D)."""
    chunk = convertible_chunk_size(cfg, inst, expected_decode_batch,
                                   avg_ctx, tpot_slo)
    v_dp = convertible_prefill_velocity(chunk, expected_decode_batch,
                                        tpot_slo)
    mem_t = hw.kv_bytes_per_token(cfg)
    mem_r = reserved_memory(v_dp, mem_t, ttft_slo)
    pool = max(int(math.ceil(max_decoders * burst_ratio)), 1)
    return ConvertibleConfig(chunk_size=chunk, v_prefill=v_dp,
                             mem_reserved=mem_r, pool_size=pool)


def default_convertible_plan(cfg: ModelConfig, inst: InstanceSpec,
                             prof, max_decoders: int = 8
                             ) -> ConvertibleConfig:
    """The standard offline plan used by the experiment runner: expected
    decode batch = half the M-M SLO-feasible batch from the pool's own
    velocity profile, a mid-range context, and the §II-C burst-ratio
    constant the paper's evaluation uses.  Each convertible pool plans
    against *its own* (model, chip, tp) profile, so heterogeneous fleets
    restrict each pool correctly (Eq. 5-6).  ``max_decoders`` is the
    fleet's actual decode-pool ceiling (§IV-C2 sizes the pool as
    ceil(max decoders x burst ratio)); ``sim.runner.build_fleet`` plumbs
    the experiment's instance cap through, and the historical 8 remains
    the default for direct callers."""
    return plan_convertible(
        cfg, inst,
        expected_decode_batch=max(prof.max_batch.get("M-M", 16) // 2, 1),
        avg_ctx=1200.0, burst_ratio=0.2, max_decoders=max_decoders)


def spill_compatible(donor, recipient) -> bool:
    """Can idle boxes move between these two convertible pools?

    §IV-C2 sizes each convertible pool offline; a cross-model *loan*
    re-images a compatible box with the borrower's weights (paying the
    chip's startup latency) instead of provisioning a fresh instance.
    Compatibility is hardware identity — same chip and TP degree — so the
    borrower's offline Eq. 5-6 restriction plan applies to the borrowed
    box unchanged.  Duck-typed over ``chip``/``tp`` so both ``PoolSpec``
    and runtime pools qualify."""
    return (donor.chip == recipient.chip and donor.tp == recipient.tp
            and donor is not recipient)


def burst_ratio_of_trace(arrivals, window_s: float = 60.0,
                         factor: float = 1.0) -> float:
    """Fraction of tokens arriving above the running-average trendline
    (the §II-C burst definition, used to size the pool offline).

    The baseline for second *i* is the mean of the preceding ``window_s``
    seconds, *excluding* second i itself: a spike that joins its own
    trendline dampens the very signal it should trigger (a 10x second
    over a window of 10 raises its own baseline by ~2x).  Second 0 has no
    history and is never counted as burst.  Evaluated with cumulative
    sums — O(n) over the trace span instead of the historical
    O(n * window) Python loop (tests/test_bugfixes.py pins both the
    vectorization and the self-exclusion against a brute-force
    reference)."""
    import numpy as np
    arrivals = sorted(arrivals, key=lambda r: r[0])
    if not arrivals:
        return 0.0
    ts = np.array([a[0] for a in arrivals])
    toks = np.array([a[1] for a in arrivals], dtype=np.float64)
    t_end = ts.max() + 1e-9
    grid = np.arange(0.0, t_end + 1.0, 1.0)
    per_sec = np.zeros(len(grid))
    idx = np.clip(np.searchsorted(grid, ts, side="right") - 1, 0,
                  len(grid) - 1)
    np.add.at(per_sec, idx, toks)
    n = len(grid)
    i = np.arange(n)
    lo = np.maximum(0, i - int(window_s))
    # prefix[k] = per_sec[:k].sum(); baseline window is [lo, i) — strictly
    # before second i
    prefix = np.concatenate(([0.0], np.cumsum(per_sec)))
    count = (i - lo).astype(np.float64)
    avg = np.where(count > 0,
                   (prefix[i] - prefix[lo]) / np.maximum(count, 1.0),
                   np.inf)             # no history -> never above baseline
    burst_tok = float(np.maximum(per_sec - factor * avg, 0.0).sum())
    return float(burst_tok / max(toks.sum(), 1e-9))
