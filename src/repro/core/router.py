"""Routing & load balancing (§IV-E) + the burst detector (§IV-A).

Alg. 1 (prefill): two rounds — regular prefillers first, Convertible
Decoders second, else queue.  Feasibility = estimated waiting time
(in-flight tokens / stage velocity) within the request's TTFT SLO.

Decode: predict the request's bucket, route to the decoder with the fewest
in-flight requests *of that bucket*; Convertible Decoders are excluded once
their memory utilization crosses a threshold, and prioritize decode over
prefill on-box.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Protocol


def ttft_slo(in_len: int) -> float:
    """SLO standards from §V (DynamoLLM/MLPerf): 250/400/2000 ms."""
    if in_len < 256:
        return 0.25
    if in_len < 1024:
        return 0.40
    return 2.0


TPOT_SLO = 0.1


class PrefillTarget(Protocol):
    def inflight_tokens(self) -> float: ...
    def prefill_velocity(self) -> float: ...


@dataclass
class BurstDetector:
    """Short-window rate vs long-window running average (§II-C methodology:
    spikes above the running average are bursts)."""
    short_s: float = 1.0
    long_s: float = 60.0
    factor: float = 1.5
    _events: list[tuple[float, float]] = field(default_factory=list)

    def observe(self, t: float, tokens: float):
        self._events.append((t, tokens))
        self._events = [e for e in self._events if t - e[0] <= self.long_s]

    def rates(self, t: float) -> tuple[float, float]:
        short = sum(v for ts, v in self._events if t - ts <= self.short_s) \
            / self.short_s
        horizon = min(self.long_s, max(t, 1.0))
        long = sum(v for ts, v in self._events) / horizon
        return short, long

    def is_burst(self, t: float) -> bool:
        short, long = self.rates(t)
        return short > self.factor * max(long, 1e-9)


class Router:
    """Alg. 1 + decode load balancing."""

    def __init__(self, burst_detector: Optional[BurstDetector] = None):
        self.burst = burst_detector or BurstDetector()

    # ---- Alg. 1 ------------------------------------------------------
    def route_prefill(self, in_len: int, prefillers: list,
                      convertibles: list, now: float):
        """Returns (target, kind) with kind in {"prefiller", "convertible",
        None}; None means queue (line 15)."""
        slo = ttft_slo(in_len)
        for p in prefillers:                      # round 1 (lines 1-7)
            wait = p.inflight_tokens() / max(p.prefill_velocity(), 1e-9)
            if wait <= slo:
                return p, "prefiller"
        for d in convertibles:                    # round 2 (lines 8-14)
            wait = d.inflight_tokens() / max(d.prefill_velocity(), 1e-9)
            if wait <= slo:
                return d, "convertible"
        return None, None                         # line 15: enqueue

    # ---- decode load balancing ----------------------------------------
    def route_decode(self, bucket: str, decoders: list,
                     mem_threshold: float = 0.9):
        """Fewest in-flight requests of `bucket`; convertibles excluded
        above the memory threshold."""
        candidates = [d for d in decoders
                      if not (getattr(d, "is_convertible", False)
                              and d.mem_util() > mem_threshold)]
        if not candidates:
            candidates = decoders
        if not candidates:
            return None
        return min(candidates,
                   key=lambda d: (d.inflight_of_bucket(bucket),
                                  d.mem_util()))
