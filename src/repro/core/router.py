"""Routing & load balancing (§IV-E) + the burst detector (§IV-A).

Alg. 1 (prefill): two rounds — regular prefillers first, Convertible
Decoders second, else queue.  Feasibility = estimated waiting time
(in-flight tokens / stage velocity) within the request's TTFT SLO.

Decode: predict the request's bucket, route to the decoder with the fewest
in-flight requests *of that bucket*; Convertible Decoders are excluded once
their memory utilization crosses a threshold, and prioritize decode over
prefill on-box.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional, Protocol


#: request priority classes (lower value = more urgent).  Interactive and
#: standard traffic share the paper's SLO targets; batch traffic tolerates
#: a relaxed multiple of them (mixed-criticality serving, DynaServe-style).
PRIORITY_INTERACTIVE = 0
PRIORITY_STANDARD = 1
PRIORITY_BATCH = 2
PRIORITY_TTFT_SCALE = {PRIORITY_INTERACTIVE: 1.0, PRIORITY_STANDARD: 1.0,
                       PRIORITY_BATCH: 4.0}
PRIORITY_TPOT_SCALE = {PRIORITY_INTERACTIVE: 1.0, PRIORITY_STANDARD: 1.0,
                       PRIORITY_BATCH: 4.0}


def ttft_slo(in_len: int, priority: int = PRIORITY_STANDARD) -> float:
    """SLO standards from §V (DynamoLLM/MLPerf): 250/400/2000 ms, scaled
    per priority class."""
    if in_len < 256:
        base = 0.25
    elif in_len < 1024:
        base = 0.40
    else:
        base = 2.0
    return base * PRIORITY_TTFT_SCALE.get(priority, 1.0)


TPOT_SLO = 0.1


def tpot_slo(priority: int = PRIORITY_STANDARD) -> float:
    return TPOT_SLO * PRIORITY_TPOT_SCALE.get(priority, 1.0)


class PrefillTarget(Protocol):
    def inflight_tokens(self) -> float: ...
    def prefill_velocity(self) -> float: ...


@dataclass
class BurstDetector:
    """Short-window rate vs long-window running average (§II-C methodology:
    spikes above the running average are bursts).

    Both windows are maintained *incrementally* over deques: ``observe``
    and ``rates`` are O(1) amortized instead of rebuilding/re-summing the
    long window per arrival (which made the gateway O(window) per request
    — the first quadratic wall on million-request traces).  The running
    sums stay bit-for-bit equal to the historical from-scratch reductions
    because observed token counts are integers (prompt lengths): every
    partial sum is an exactly-representable integer, so float addition
    and subtraction are exact and order-independent here."""
    short_s: float = 1.0
    long_s: float = 60.0
    factor: float = 1.5
    min_events: int = 3        # no "burst" before any baseline exists
    _events: deque = field(default_factory=deque)
    _short: deque = field(default_factory=deque)
    _long_sum: float = 0.0
    _short_sum: float = 0.0

    def observe(self, t: float, tokens: float):
        e = (t, tokens)
        self._events.append(e)
        self._long_sum += tokens
        self._short.append(e)
        self._short_sum += tokens
        events = self._events
        while events and t - events[0][0] > self.long_s:
            self._long_sum -= events.popleft()[1]
        self._trim_short(t)

    def _short_h(self, t: float) -> float:
        # the short window never covers more than half the observed
        # horizon, so the short/long comparison always measures a rate
        # *contrast*: with both windows over the same elapsed interval the
        # ratio would be a pure normalization artifact (always-burst before
        # the fix's symmetric-elapsed variant, never-burst before PR 2)
        return min(self.short_s, max(t / 2.0, 1e-3))

    def _trim_short(self, t: float):
        # t - _short_h(t) is non-decreasing in t, so the short window's
        # left edge only ever moves right — expiry is monotone
        h = self._short_h(t)
        short = self._short
        while short and t - short[0][0] > h:
            self._short_sum -= short.popleft()[1]

    def rates(self, t: float) -> tuple[float, float]:
        """Both windows are normalized over their *observed* horizon, so an
        opening spike (t < short_s) is detectable against the brief
        baseline that preceded it; past 2x short_s this reduces to the
        nominal short_s/elapsed normalization."""
        self._trim_short(t)
        short = self._short_sum / self._short_h(t)
        long_h = min(self.long_s, max(t, 1e-3))
        long = self._long_sum / long_h
        return short, long

    def is_burst(self, t: float) -> bool:
        # a burst is a spike *above a baseline*: until a few observations
        # exist the ratio is a one-sample artifact, never a burst signal.
        # The count guard is on total history, not the short window — a
        # single huge request against an established baseline IS a burst
        # (the paper's few-requests/many-tokens case, Fig. 6 T2)
        if len(self._events) < self.min_events:
            return False
        short, long = self.rates(t)
        return short > self.factor * max(long, 1e-9)


def _decode_capacity(d, bucket: str) -> float:
    """SLO-feasible batch for ``bucket`` on this decoder's chip, from its
    pool's velocity profile (``VelocityProfile.max_batch``).  Bare
    decoders (unit tests, no pool backref) report 1.0 — with every
    candidate equal the capacity never matters."""
    prof = getattr(getattr(d, "pool", None), "prof", None)
    if prof is None:
        return 1.0
    mb = prof.max_batch
    return float(mb.get(bucket) or max(mb.values(), default=1) or 1)


def _by_velocity(targets: list) -> list:
    """Candidates in descending prefill-velocity order.  ``sorted`` is
    stable, so a homogeneous pool (all velocities equal) keeps its
    original order — single-pool routing is unchanged.  That common case
    is detected up front and skips the sort (and its key tuples)
    entirely: a stable sort on all-equal keys is the identity."""
    if len(targets) < 2:
        return targets
    v0 = targets[0].prefill_velocity()
    if all(x.prefill_velocity() == v0 for x in targets[1:]):
        return targets
    return sorted(targets, key=lambda x: -x.prefill_velocity())


class Router:
    """Alg. 1 + decode load balancing."""

    def __init__(self, burst_detector: Optional[BurstDetector] = None):
        self.burst = burst_detector or BurstDetector()
        # flight-recorder tap (repro.obs): when set, every route_prefill
        # outcome is reported as hook(t, kind, target, in_len, priority,
        # slo).  None (the default) keeps the hot path decision-free
        # beyond one attribute test — telemetry-off runs are byte- and
        # order-identical.
        self.trace_hook = None

    # ---- Alg. 1 ------------------------------------------------------
    def route_prefill(self, in_len: int, prefillers: list,
                      convertibles: list, now: float,
                      priority: int = PRIORITY_STANDARD,
                      deflectables: list = ()):
        """Returns (target, kind) with kind in {"prefiller", "convertible",
        "deflect", None}; None means queue (line 15).  Feasibility is
        judged against the request's per-class TTFT SLO, so batch traffic
        accepts busier targets instead of competing for the rapid-response
        path.

        Heterogeneous fleets: candidates may span pools of differing
        prefill velocity (mixed chips/TP).  Feasibility is per-target —
        estimated wait = that instance's in-flight tokens / *its own*
        velocity — and each round scans faster targets first (a stable
        sort, so homogeneous fleets keep the historical first-feasible
        order byte-for-byte).

        ``deflectables`` (round 2b, chunked-prefill pools only): regular
        decoders whose iterations can co-schedule prompt chunks.  Reached
        only when the prefill queue already threatens the per-class TTFT
        SLO (rounds 1-2 failed); the decision weighs that queue delay
        against each decoder's mixed-iteration slack — its Eq. 5 headroom
        expressed as an absorption velocity — and deflects to the decoder
        that finishes the prompt soonest, provided that still lands within
        the SLO.  Decoders with no TPOT headroom advertise zero velocity
        and are never chosen, so deflection cannot form on an overloaded
        decode pool."""
        out = self._route_prefill(in_len, prefillers, convertibles,
                                  priority, deflectables)
        hook = self.trace_hook
        if hook is not None:
            hook(now, out[1], out[0], in_len, priority,
                 ttft_slo(in_len, priority))
        return out

    def _route_prefill(self, in_len: int, prefillers: list,
                       convertibles: list, priority: int,
                       deflectables: list = ()):
        slo = ttft_slo(in_len, priority)
        for p in _by_velocity(prefillers):        # round 1 (lines 1-7)
            wait = p.inflight_tokens() / max(p.prefill_velocity(), 1e-9)
            if wait <= slo:
                return p, "prefiller"
        for d in _by_velocity(convertibles):      # round 2 (lines 8-14)
            wait = d.inflight_tokens() / max(d.prefill_velocity(), 1e-9)
            if wait <= slo:
                return d, "convertible"
        if deflectables:                          # round 2b: deflection
            best, best_eta = None, float("inf")
            for d in deflectables:
                v = d.deflect_velocity()
                if v <= 0.0:
                    continue
                eta = (d.inflight_tokens() + in_len) / v
                if eta < best_eta:
                    best, best_eta = d, eta
            if best is not None and best_eta <= slo:
                return best, "deflect"
        return None, None                         # line 15: enqueue

    # ---- decode load balancing ----------------------------------------
    def route_decode(self, bucket: str, decoders: list,
                     mem_threshold: float = 0.9):
        """Fewest in-flight requests of `bucket`; convertibles excluded
        above the memory threshold.

        Candidates spanning heterogeneous decode pools (same-role pool
        sets on mixed chips) are balanced by *share of capacity* —
        in-flight count over the pool profile's SLO-feasible batch for
        the bucket — so a small-batch chip (l40s) is not loaded to the
        same absolute residency as an h100.  The capacity divide is
        applied only when the candidates' capacities actually differ:
        with all capacities equal it is a constant positive rescaling of
        the integer count (order-preserving, no float collapse at sim
        batch sizes), so homogeneous fleets keep the historical key
        byte-for-byte — the same guarded-specialization idiom as
        ``_by_velocity``."""
        candidates = [d for d in decoders
                      if not (getattr(d, "is_convertible", False)
                              and d.mem_util() > mem_threshold)]
        if not candidates:
            candidates = decoders
        if not candidates:
            return None
        caps = [_decode_capacity(d, bucket) for d in candidates]
        if any(c != caps[0] for c in caps[1:]):
            return min(zip(candidates, caps),
                       key=lambda dc: (dc[0].inflight_of_bucket(bucket)
                                       / max(dc[1], 1.0),
                                       dc[0].mem_util()))[0]
        return min(candidates,
                   key=lambda d: (d.inflight_of_bucket(bucket),
                                  d.mem_util()))
