"""TokenScale core: the paper's contribution.

  velocity     — Token Velocity metric + offline profiler (§III-B, §IV-B)
  autoscaler   — TokenScale policy (Eq.2-4) + AIBrix/BlitzScale/DistServe
                 + the string-keyed policy registry (@register_policy)
  convertible  — Convertible Decoder planning (Eq.5-6, pool sizing)
  router       — Alg.1 prefill routing, decode balancing, burst detector
  predictor    — simulated output-length predictor (§IV-B1)
  hardware     — chip profiles + analytic step-latency model
  fleet        — pool-centric control plane: PoolSpec/FleetSpec/
                 ExperimentSpec, FleetObservation/FleetPlan, FleetPolicy
  gateway      — KV-locality placement: prefix hashtrie, locality score,
                 hot-prefix replication planning
"""
from repro.core.autoscaler import (  # noqa: F401
    POLICY_REGISTRY, AIBrixPolicy, BlitzScalePolicy, DistServePolicy,
    Observation, Policy, ScaleDecision, TokenScalePolicy, build_policy,
    register_policy,
)
from repro.core.convertible import (  # noqa: F401
    ConvertibleConfig, burst_ratio_of_trace, default_convertible_plan,
    plan_convertible,
)
from repro.core.fleet import (  # noqa: F401
    ExperimentSpec, FleetObservation, FleetPlan, FleetPolicy, FleetSpec,
    GatewayStats, PerModelFleetPolicy, PoolSnapshot, PoolSpec, TraceRoute,
    single_pool_fleet,
)
from repro.core.gateway import (  # noqa: F401
    Gateway, GatewayConfig, PrefixHashTrie, ReplicationJob, RoutingStats,
    prefix_chain,
)
from repro.core.hardware import CHIPS, ChipSpec, InstanceSpec  # noqa: F401
from repro.core.predictor import OutputPredictor  # noqa: F401
from repro.core.router import (  # noqa: F401
    PRIORITY_BATCH, PRIORITY_INTERACTIVE, PRIORITY_STANDARD, TPOT_SLO,
    BurstDetector, Router, tpot_slo, ttft_slo,
)
from repro.core.velocity import (  # noqa: F401
    BUCKETS, VelocityProfile, bucket_lengths, bucket_of, profile,
    profile_for,
)
