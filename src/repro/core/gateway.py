"""KV-locality-aware gateway: fleet-level prefix hashtrie + routing score.

The PR 4 prefix cache made reuse *possible* but placement stayed
owner-steered: an arrival only benefits from a cached prefix when
admission happens to land on the single decoder that owns its session
chain, and cross-session reuse (the hot system prompt every tenant
prepends) is invisible to the per-session lookup.  This module is the
control-plane half of locality-aware placement (DESIGN.md "Routing
fidelity"):

  * **Block-granular prefix hashtrie** — prompts are split into
    fixed-size token blocks and each block gets a deterministic content
    label (see ``prefix_chain``); the trie is a radix over those label
    chains, fleet-wide: every node records *which decoders* hold the
    prefix it spells, so one lookup maps an arrival to the set of
    decoders holding *any* prefix of it — per-session chains and
    cross-session shared system prompts alike.
  * **Locality-aware routing score** — candidates are ranked by
    ``cached_suffix_savings - alpha * queue_depth`` (DistServe's goodput
    trade stated as a placement rule): a deep cached prefix is worth
    routing to a busier box only while the prefill tokens it saves
    outweigh the queueing it buys.  Ties and misses fall back to the
    share-of-capacity balancer (``core.router.Router.route_decode``).
  * **Hot-prefix replication plan** — nodes whose hit rate over a
    sliding window crosses a threshold are flagged; the cluster copies
    them to additional decoders over the interconnect (charged at
    ``KVAllocator.migration_stall`` cost) so a hot prefix stops
    funneling traffic to one box.

The trie is *advisory*: allocators (``sim.kvcache.KVAllocator``) remain
the ground truth for what is actually resident.  Holder entries are
validated against the owner's allocator at routing time and dropped
lazily when stale, so eviction inside an allocator never needs a
callback into the gateway.

Determinism: children and holders are insertion-ordered dicts keyed by
label tuples / holder objects — iteration order is insertion order,
never hash order — so routing decisions are reproducible run-to-run
(the gateway golden pins this).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Optional


@dataclass(frozen=True)
class GatewayConfig:
    """Knobs for the locality gateway (defaults are the bench setting)."""
    #: queue-depth penalty: cached tokens a unit of queue depth is worth.
    #: The score is ``saved_tokens - alpha * len(active)``; alpha is in
    #: tokens-per-resident-request, so ~one decode iteration's worth of
    #: prefill savings must be on the table before the gateway prefers a
    #: busier box over the balancer's pick.
    alpha: float = 64.0
    #: window hits at which a prefix counts as hot (sliding window).
    replicate_threshold: int = 8
    #: total copies (including the origin) a hot prefix is grown to.
    replicate_copies: int = 2
    #: sliding window (seconds) for the hit-rate estimate.
    window_s: float = 10.0
    #: trie capacity in nodes; least-recently-hit chains age out beyond it.
    max_nodes: int = 4096
    #: prefixes shorter than this are never worth replicating.
    min_tokens: int = 64


@dataclass
class RoutingStats:
    """Gateway decision/replication counters — ``SimReport.gw``.

    Kept separate from ``sim.kvcache.KVStats`` on purpose: the kvtiers
    golden pins ``KVStats.summary()``'s schema, so gateway counters get
    their own sink (empty dict when no pool enables the gateway).
    """
    affinity_hits: int = 0        # routed to a decoder already holding KV
    replica_hits: int = 0         # ...where that holder was a replica copy
    balanced: int = 0             # no usable prefix: share-of-capacity path
    steered_tokens: int = 0       # prompt tokens served by gateway routing
    replications: int = 0         # completed hot-prefix copies
    replica_bytes: float = 0.0    # bytes shipped by those copies
    replica_stall_s: float = 0.0  # interconnect time charged for them
    block_grows: int = 0          # lazy-alloc per-token block extensions
    grow_failures: int = 0        # extensions that found no block free
    oom_preemptions: int = 0      # mid-decode evictions those triggered

    def summary(self) -> dict:
        return {
            "affinity_hits": self.affinity_hits,
            "replica_hits": self.replica_hits,
            "balanced": self.balanced,
            "steered_tokens": self.steered_tokens,
            "replications": self.replications,
            "replica_bytes": self.replica_bytes,
            "replica_stall_s": self.replica_stall_s,
            "block_grows": self.block_grows,
            "grow_failures": self.grow_failures,
            "oom_preemptions": self.oom_preemptions,
        }


def prefix_chain(shared_id: int, shared_len: int, session: int,
                 in_len: int, block_size: int) -> list[tuple]:
    """Deterministic content labels for a prompt's full blocks.

    In a real serving gateway each label would be the hash of the block's
    tokens chained onto its parent (vLLM/SGLang-style prefix hashing).
    The simulator has no token text, but it *does* know the two provable
    sources of content equality the trace encodes: a Zipf-shared system
    prompt (``shared_id`` covers the first ``shared_len`` tokens,
    identical across sessions) and same-session history (a session's
    follow-up extends its own previous context verbatim).  Labels encode
    exactly those equivalences:

      * block ``i`` fully inside the shared prompt -> ``("sys", shared_id,
        i)`` — equal across *all* requests sharing the prompt;
      * remaining full blocks of a sessionful request -> ``("sess",
        session, i)`` — equal across that session's turns;
      * sessionless tails produce no labels (no provable reuse).

    A block straddling the shared-prompt boundary is a session block: its
    content mixes shared and private tokens, so it is only equal within
    the session.  The chain is therefore a prefix-closed spelling of the
    request's reusable content, and two requests share cached state
    exactly when their chains share a prefix.
    """
    if block_size <= 0 or in_len < block_size:
        return []
    n_full = in_len // block_size
    n_sys = 0
    if shared_id >= 0 and shared_len > 0:
        n_sys = min(shared_len // block_size, n_full)
    chain: list[tuple] = [("sys", shared_id, i) for i in range(n_sys)]
    if session >= 0:
        chain += [("sess", session, i) for i in range(n_sys, n_full)]
    return chain


class _Node:
    """One trie node: the prefix spelled by the path from the root."""

    __slots__ = ("label", "depth", "children", "holders", "hits",
                 "last_use", "pending")

    def __init__(self, label: Optional[tuple], depth: int):
        self.label = label
        self.depth = depth                      # tokens covered by the path
        self.children: dict[tuple, _Node] = {}
        # holder -> [last_use, is_replica]; insertion-ordered (determinism)
        self.holders: dict[object, list] = {}
        self.hits: list[float] = []             # hit timestamps (window)
        self.last_use = 0.0
        self.pending = False                    # replication in flight

    def hit_rate(self, t: float, window: float) -> int:
        """Hits inside the sliding window ending at ``t``."""
        h = self.hits
        cut = t - window
        while h and h[0] < cut:
            h.pop(0)
        return len(h)


class PrefixHashTrie:
    """Fleet-level radix over block-label chains (see module docstring).

    ``insert`` marks ``holder`` on every node along the chain (holding a
    prefix implies holding all its prefixes); ``lookup`` walks the chain
    and reports, per holder, the deepest node it appears on.  Both are
    O(chain length).  The trie never exceeds ``max_nodes``: beyond it the
    least-recently-used leaf chains age out (holders are advisory, so
    aging out a node only costs future routing opportunities, never
    correctness).
    """

    def __init__(self, max_nodes: int = 4096):
        self.root = _Node(None, 0)
        self.max_nodes = max_nodes
        self.n_nodes = 0

    # ---- mutation ----------------------------------------------------
    def insert(self, chain: Iterable[tuple], holder: object, t: float,
               block_size: int, replica: bool = False):
        """Record that ``holder`` caches the prefix spelled by ``chain``."""
        node = self.root
        for label in chain:
            child = node.children.get(label)
            if child is None:
                child = _Node(label, node.depth + block_size)
                node.children[label] = child
                self.n_nodes += 1
            node = child
            node.last_use = t
            ent = node.holders.get(holder)
            if ent is None:
                node.holders[holder] = [t, replica]
            else:
                ent[0] = t
                # an origin insert upgrades a replica marking, never the
                # reverse (a replica copy of something already held adds
                # no information)
                if not replica:
                    ent[1] = False
        if self.n_nodes > self.max_nodes:
            self._prune(t)

    def remove_holder(self, holder: object):
        """Forget every marking of ``holder`` (decoder torn down)."""
        stack = [self.root]
        while stack:
            node = stack.pop()
            node.holders.pop(holder, None)
            stack.extend(node.children.values())

    def _prune(self, t: float):
        """Age out least-recently-used subtrees until under capacity.

        Candidates are collected deterministically (preorder, insertion
        order) and dropped oldest-first; a dropped node takes its whole
        subtree (children are by construction no younger in ``last_use``
        than the ancestors that led to them only on the hit path, so
        subtree drops may discard fresher grandchildren — acceptable for
        an advisory cache, and it keeps pruning O(nodes))."""
        # (last_use, seq, parent, label) per depth-1..n node
        cands: list[tuple[float, int, _Node, tuple]] = []
        seq = 0
        stack = [self.root]
        while stack:
            node = stack.pop()
            for label, child in node.children.items():
                cands.append((child.last_use, seq, node, label))
                seq += 1
                stack.append(child)
        cands.sort(key=lambda c: (c[0], c[1]))
        target = int(self.max_nodes * 0.75)
        dead: set[int] = set()      # nodes gone with an ancestor's subtree
        for _, _, parent, label in cands:
            if self.n_nodes <= target:
                break
            if id(parent) in dead:  # detached parents still hold children;
                continue            # popping there would double-count
            child = parent.children.pop(label, None)
            if child is None:
                continue
            sub = [child]
            while sub:
                node = sub.pop()
                dead.add(id(node))
                self.n_nodes -= 1
                sub.extend(node.children.values())

    # ---- queries -----------------------------------------------------
    def lookup(self, chain: list[tuple], t: float
               ) -> dict[object, tuple[int, "_Node"]]:
        """Deepest marked node per holder along ``chain``.

        Returns ``{holder: (depth_tokens, node)}`` in first-seen holder
        order.  Records a window hit on the deepest node reached (the
        replication signal counts *prefix* popularity, so the hit lands
        on the longest matched path, not every ancestor)."""
        out: dict[object, tuple[int, _Node]] = {}
        node = self.root
        for label in chain:
            child = node.children.get(label)
            if child is None:
                break
            node = child
            node.last_use = t
            for holder in node.holders:
                out[holder] = (node.depth, node)
        if node is not self.root:
            node.hits.append(t)
        return out

    def walk(self, chain: list[tuple]) -> Optional[_Node]:
        """The node spelling ``chain`` exactly, or None."""
        node = self.root
        for label in chain:
            node = node.children.get(label)
            if node is None:
                return None
        return node

    def holders_of(self, chain: list[tuple]) -> list:
        node = self.walk(chain)
        return list(node.holders) if node is not None else []

    def check(self, block_size: int):
        """Structural audit (test hook): depths are consistent, node
        count matches the tree, no empty labels."""
        n = 0
        stack = [self.root]
        while stack:
            node = stack.pop()
            for label, child in node.children.items():
                if child.label != label:
                    raise AssertionError("child label drift")
                if child.depth != node.depth + block_size:
                    raise AssertionError("depth drift")
                n += 1
                stack.append(child)
        if n != self.n_nodes:
            raise AssertionError(
                f"node count drift: counted {n}, tracked {self.n_nodes}")


@dataclass
class ReplicationJob:
    """One planned hot-prefix copy, serviced by the cluster."""
    chain: tuple                   # label chain of the replicated prefix
    key: tuple                     # allocator cache key on the target
    tokens: int
    source: object                 # origin decoder (owns the blocks)
    target: object                 # destination decoder
    t_done: float = 0.0            # completion time (stamped by cluster)
    node: object = None            # trie node (pending flag cleared there)
    gw: object = None              # owning Gateway (stamped by cluster)


class Gateway:
    """Per-model-group locality gateway: trie + score + replication plan.

    The cluster calls ``route`` per arrival and ``observe_release`` when
    a finished request's blocks become a cache entry; ``plan_replication``
    turns window-hot trie nodes into ``ReplicationJob``s the cluster
    executes with real interconnect cost.
    """

    def __init__(self, cfg: GatewayConfig, block_size: int,
                 stats: Optional[RoutingStats] = None):
        self.cfg = cfg
        self.block_size = block_size
        self.stats = stats or RoutingStats()
        self.trie = PrefixHashTrie(cfg.max_nodes)
        # flight-recorder tap (repro.obs): hook(t, kind, **fields) for
        # replication-lifecycle events; None (default) = telemetry off,
        # one attribute test on the replication-planning path only.
        self.trace_hook = None

    # ---- chain plumbing ----------------------------------------------
    def chain_of(self, src) -> list[tuple]:
        """Label chain for a trace request (``sim.traces.TraceRequest``)."""
        return prefix_chain(
            getattr(src, "shared_id", -1), getattr(src, "shared_len", 0),
            getattr(src, "session", -1), src.in_len, self.block_size)

    @staticmethod
    def cache_key(node_label: tuple, session: int):
        """Allocator cache key for the entry backing a trie path ending
        at ``node_label``: session chains live under the session id (the
        legacy key, so session follow-ups and the gateway see one entry);
        shared-prompt chains live under ``("sys", shared_id)``."""
        if node_label[0] == "sys":
            return ("sys", node_label[1])
        return session

    # ---- routing -----------------------------------------------------
    def best_holder(self, chain: list[tuple], t: float,
                    live: "callable") -> Optional[tuple]:
        """Highest-scoring holder of any prefix of ``chain``.

        ``live(holder)`` filters candidates (ready, not draining, has an
        allocator); stale holders — marked in the trie but no longer
        backing the entry in their allocator — are dropped lazily here.
        Returns ``(holder, node, depth_tokens, is_replica, score)`` or
        None when no live holder scores above the balanced fallback
        (score <= -alpha * min queue depth is still returned; the caller
        compares against its own fallback)."""
        found = self.trie.lookup(chain, t)
        best = None
        for holder, (depth, node) in found.items():
            if not live(holder):
                node.holders.pop(holder, None)
                continue
            score = float(depth) \
                - self.cfg.alpha * len(getattr(holder, "active", ()))
            ent = node.holders.get(holder)
            replica = bool(ent and ent[1])
            if best is None or score > best[4]:
                best = (holder, node, depth, replica, score)
        return best

    # ---- replication -------------------------------------------------
    def plan_replication(self, chain: list[tuple], t: float,
                         decoders: list) -> list[ReplicationJob]:
        """Hot-prefix check for the deepest *shared* node of ``chain``:
        when its window hit count crosses the threshold and it has fewer
        than ``replicate_copies`` holders, plan copies to the
        least-loaded non-holders.  Session-private chains never
        replicate (their reuse is single-stream by construction)."""
        cfg = self.cfg
        n_sys = 0
        for label in chain:
            if label[0] != "sys":
                break
            n_sys += 1
        if n_sys == 0:
            return []
        node = self.trie.walk(chain[:n_sys])
        if node is None or node.pending or node.depth < cfg.min_tokens:
            return []
        if node.hit_rate(t, cfg.window_s) < cfg.replicate_threshold:
            return []
        holders = [h for h in node.holders]
        if not holders or len(holders) >= cfg.replicate_copies:
            return []
        src = holders[0]
        targets = [d for d in decoders
                   if d not in node.holders and d.kv is not None]
        targets.sort(key=lambda d: (len(d.active), d.iid))
        jobs = []
        key = self.cache_key(node.label, -1)
        for tgt in targets[:cfg.replicate_copies - len(holders)]:
            jobs.append(ReplicationJob(
                chain=tuple(chain[:n_sys]), key=key, tokens=node.depth,
                source=src, target=tgt, node=node))
        if jobs:
            node.pending = True
            if self.trace_hook is not None:
                self.trace_hook(
                    t, "planned", tokens=node.depth, copies=len(jobs),
                    source=getattr(src, "iid", None),
                    targets=[getattr(j.target, "iid", None) for j in jobs])
        return jobs
