"""Hardware profiles + analytic step-latency model.

The paper profiles Token Velocity per (model, GPU) pair on real clusters
(A100/H100).  We reproduce the same *methodology* with an analytic roofline
cost model over published chip constants — the offline profiler sweeps
request rates against this model exactly as §IV-B sweeps them against real
engines — and add the TPU v5e profile that the JAX/Pallas substrate targets.

Efficiency factors are calibrated so Llama-3.1-8B/A100 decode velocities
land inside the paper's Table II band (see tests/test_velocity.py).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class ChipSpec:
    name: str
    flops_bf16: float          # FLOP/s per chip
    hbm_bw: float              # bytes/s
    hbm_cap: float             # bytes
    net_bw: float              # bytes/s inter-instance (KVC transfer)
    mfu: float = 0.55          # achievable fraction of peak compute
    mbu: float = 0.70          # achievable fraction of peak HBM bandwidth
    startup_s: float = 5.0     # instance boot (weights load + runtime init)
    cost_per_hour: float = 1.0
    # host-DRAM KV offload tier (sim.kvcache): bytes of pinned host memory
    # available per chip, and the effective HBM<->host swap bandwidth
    # (PCIe/DMA sustained, not the link peak)
    host_dram_cap: float = 0.0
    swap_bw: float = 0.0


CHIPS: dict[str, ChipSpec] = {
    # 4xA100-40G nodes, NVLink3 600GB/s agg, 2x200Gb IB (paper §V).
    # mfu calibrated so V_P(llama-3.1-8b) ~ Table I's 14K tok/s threshold.
    # Host tier: PCIe4 x16 (~20 GB/s sustained DMA), 64 GB pinned per chip.
    "a100": ChipSpec("a100", 312e12, 1.555e12, 40e9, 25e9,
                     mfu=0.72, mbu=0.60, startup_s=5.0, cost_per_hour=4.0,
                     host_dram_cap=64e9, swap_bw=20e9),
    # 8xH100-80G nodes, NVLink 1200GB/s (paper uses "3.0" loosely), 2880Gb
    # Host tier: PCIe5 x16 (~45 GB/s sustained), 128 GB pinned per chip.
    "h100": ChipSpec("h100", 989e12, 3.35e12, 80e9, 360e9,
                     mfu=0.50, mbu=0.65, startup_s=5.0, cost_per_hour=8.0,
                     host_dram_cap=128e9, swap_bw=45e9),
    # TPU v5e — the JAX substrate's target (roofline constants used by
    # launch/roofline.py as well); host tier over PCIe3-class DMA.
    "v5e": ChipSpec("v5e", 197e12, 8.19e11, 16e9, 50e9,
                    mfu=0.55, mbu=0.70, startup_s=4.0, cost_per_hour=1.2,
                    host_dram_cap=48e9, swap_bw=12e9),
    # L40S-48G (Ada): dense-BF16 compute near A100 but GDDR6 bandwidth
    # (864 GB/s) and PCIe-only interconnect — low absolute decode velocity,
    # yet the best decode tokens/s/$ of the menu at ~1.8 $/hr.  The chip
    # the cost-aware planner should prefer for decode when SLOs allow.
    "l40s": ChipSpec("l40s", 181e12, 8.64e11, 48e9, 25e9,
                     mfu=0.60, mbu=0.70, startup_s=5.0, cost_per_hour=1.8,
                     host_dram_cap=64e9, swap_bw=20e9),
}

V5E = CHIPS["v5e"]


@dataclass(frozen=True)
class InstanceSpec:
    """One inference instance = `tp` chips running `model`."""
    chip: ChipSpec
    tp: int = 1

    @property
    def flops(self) -> float:
        return self.chip.flops_bf16 * self.tp * self.chip.mfu

    @property
    def hbm_bw(self) -> float:
        return self.chip.hbm_bw * self.tp * self.chip.mbu

    @property
    def hbm_cap(self) -> float:
        return self.chip.hbm_cap * self.tp

    @property
    def gpus(self) -> int:
        return self.tp

    @property
    def host_dram_cap(self) -> float:
        """Host-DRAM offload bytes: each chip brings its own pinned pool."""
        return self.chip.host_dram_cap * self.tp

    @property
    def swap_bw(self) -> float:
        """HBM<->host swap bandwidth: each chip swaps over its own lanes."""
        return self.chip.swap_bw * self.tp

    @property
    def cost_rate(self) -> float:
        return self.chip.cost_per_hour * self.tp / 3600.0


# ---------------------------------------------------------------------------
# Model byte/flop accounting
# ---------------------------------------------------------------------------

def weight_bytes(cfg: ModelConfig, bytes_per_param: int = 2) -> float:
    return cfg.param_counts()["total"] * bytes_per_param


def active_weight_bytes(cfg: ModelConfig, bytes_per_param: int = 2) -> float:
    return cfg.param_counts()["active"] * bytes_per_param


def kv_bytes_per_token(cfg: ModelConfig, bytes_per_el: int = 2) -> float:
    """Per-token recurrent/cache footprint across all layers.

    Respects ``kv_cache_dtype="int8"`` (1 byte/element + one f32 scale per
    (token, head)): the quantized cache roughly halves the footprint —
    and therefore roughly DOUBLES the memory-capacity-bound decode batch
    and the decode Token Velocity (Eq. 1) the profiler reports."""
    if cfg.kv_cache_dtype == "int8":
        per_el: float = 1.0
        scale_overhead = 4.0  # f32 scale per (token, head)
    else:
        per_el = float(bytes_per_el)
        scale_overhead = 0.0
    total = 0.0
    for spec in cfg.layer_specs:
        if spec.mixer in ("attn", "local_attn"):
            if cfg.kv_lora_rank:
                # MLA latent cache is kept at full precision
                total += (cfg.kv_lora_rank + cfg.qk_rope_dim) * bytes_per_el
            else:
                total += 2 * cfg.num_kv_heads * (cfg.head_dim_ * per_el
                                                 + scale_overhead)
        # mamba/rwkv state is O(1) in sequence — amortized to ~0 per token
    return total


def state_bytes_fixed(cfg: ModelConfig, bytes_per_el: int = 2) -> float:
    """Sequence-independent recurrent state (SSM/RWKV) per request."""
    total = 0.0
    for spec in cfg.layer_specs:
        if spec.mixer == "mamba":
            mc = cfg.mamba
            di = mc.expand * cfg.d_model
            total += di * mc.d_state * 4 + (mc.d_conv - 1) * di * bytes_per_el
        elif spec.mixer == "rwkv":
            h = cfg.d_model // cfg.rwkv_head_dim
            total += h * cfg.rwkv_head_dim ** 2 * 4 + 2 * cfg.d_model * 2
    return total


def flops_per_token(cfg: ModelConfig) -> float:
    """Dense-equivalent forward FLOPs per token: 2 * N_active."""
    return 2.0 * cfg.param_counts()["active"]


def attn_flops_per_token(cfg: ModelConfig, context: float) -> float:
    """Attention score/value FLOPs per token at a given context length."""
    total = 0.0
    for spec in cfg.layer_specs:
        if spec.mixer in ("attn", "cross_attn"):
            eff = cfg.num_vision_tokens if spec.mixer == "cross_attn" else context
            total += 4.0 * cfg.num_heads * cfg.head_dim_ * eff
        elif spec.mixer == "local_attn":
            total += 4.0 * cfg.num_heads * cfg.head_dim_ * min(
                context, cfg.sliding_window or context)
    return total


# ---------------------------------------------------------------------------
# Step-latency model (drives both the profiler and the cluster simulator)
# ---------------------------------------------------------------------------

def prefill_time(cfg: ModelConfig, inst: InstanceSpec, n_tokens: int,
                 context: float = 0.0) -> float:
    """Seconds to prefill `n_tokens` (compute-bound stage)."""
    f = n_tokens * (flops_per_token(cfg)
                    + attn_flops_per_token(cfg, context + n_tokens / 2))
    t_compute = f / inst.flops
    t_memory = active_weight_bytes(cfg) / inst.hbm_bw
    return max(t_compute, t_memory)


def decode_iter_time(cfg: ModelConfig, inst: InstanceSpec, batch: int,
                     avg_context: float) -> float:
    """Seconds per decode iteration for `batch` concurrent requests."""
    if batch <= 0:
        return 0.0
    mem = (active_weight_bytes(cfg)
           + batch * (kv_bytes_per_token(cfg) * avg_context
                      + state_bytes_fixed(cfg)))
    t_mem = mem / inst.hbm_bw
    f = batch * (flops_per_token(cfg)
                 + attn_flops_per_token(cfg, avg_context))
    t_compute = f / inst.flops
    return max(t_mem, t_compute)


def max_batch(cfg: ModelConfig, inst: InstanceSpec, avg_tokens: float,
              reserve_bytes: float = 0.0, hbm_frac: float = 0.9) -> int:
    """Max concurrent decode requests that fit in HBM.  ``hbm_frac`` is the
    usable fraction of HBM after allocator/runtime overheads (the same knob
    ``PoolSpec.hbm_frac`` threads into the simulated decoders)."""
    per_req = kv_bytes_per_token(cfg) * avg_tokens + state_bytes_fixed(cfg)
    free = inst.hbm_cap * hbm_frac - weight_bytes(cfg) - reserve_bytes
    return max(int(free / max(per_req, 1.0)), 0)


#: id(cfg) -> (cfg, kv_bytes_per_token, state_bytes_fixed); the strong cfg
#: reference both guards against id reuse and keeps the entry valid.  The
#: constants are pure functions of the config, but the layer-spec walk
#: behind them is ~30 us — too hot for the simulators' per-transfer path.
_KVC_CONSTS: dict[int, tuple] = {}


def kvc_transfer_time(cfg: ModelConfig, inst: InstanceSpec,
                      n_tokens: int) -> float:
    """Prefiller -> decoder KVC (or SSM state) transfer seconds."""
    ent = _KVC_CONSTS.get(id(cfg))
    if ent is None or ent[0] is not cfg:
        ent = _KVC_CONSTS[id(cfg)] = (
            cfg, kv_bytes_per_token(cfg), state_bytes_fixed(cfg))
    payload = ent[1] * n_tokens + ent[2]
    return payload / inst.chip.net_bw
