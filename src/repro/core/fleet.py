"""Pool-centric control-plane API (heterogeneous fleets, multi-model).

TokenScale's velocity metric is defined per (model, chip, tp) instance
tuple, but the original control plane baked in exactly one: flat
``prefillers``/``decoders`` counts in ``Observation``/``ScaleDecision``
and a single ``VelocityProfile`` threaded through everything.  This
module redesigns that surface around **pools**:

  * ``PoolSpec``        — one named pool of identical instances: a role
                          (prefill | decode | convertible), a model, a
                          chip, a TP degree, and an initial size;
  * ``FleetSpec``       — the declarative fleet: a list of pools plus
                          per-model trace routing (``TraceRoute``);
  * ``ExperimentSpec``  — a full experiment (fleet + policy + engine +
                          preemption + horizon), JSON-round-trippable so
                          scenarios are files, not kwarg soup;
  * ``FleetObservation``— per-pool ``PoolSnapshot``s plus per-model
                          gateway aggregates (``GatewayStats``);
  * ``FleetPlan``       — pool name -> target instance count (the pool-
                          centric successor of ``ScaleDecision``);
  * ``FleetPolicy``     — consumes a ``FleetObservation``, emits a
                          ``FleetPlan``; ``PerModelFleetPolicy`` adapts
                          the existing per-model ``Policy`` classes
                          (TokenScale Eq. 2-4 and the §V baselines)
                          unchanged onto heterogeneous pools.

The sim engines execute ``FleetPlan``s against mixed pools (e.g.
a100-TP2 prefillers + h100-TP1 decoders, or two models sharing a
cluster); the old single-pool entry points survive as thin shims over
one-pool specs (``sim.runner.run_policy``).  See DESIGN.md §1b.
"""
from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field
from typing import Callable, Optional

from repro.core.autoscaler import (Observation, Policy, ScaleDecision,
                                   TokenScalePolicy, _DownHysteresis)
from repro.core.convertible import spill_compatible
from repro.core.velocity import (VelocityProfile, decode_tokens_per_dollar,
                                 prefill_tokens_per_dollar)

#: valid pool roles
ROLES = ("prefill", "decode", "convertible")


# ---------------------------------------------------------------------------
# Declarative specs (JSON-round-trippable)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PoolSpec:
    """One named pool of identical (model, chip, tp) instances."""
    name: str
    role: str                      # prefill | decode | convertible
    model: str = "llama31_8b"
    chip: str = "a100"
    tp: int = 1
    init: int = 1                  # initial (convertible: fixed) size
    min: int = 1                   # scale-down floor (non-convertible)
    # scale-up ceiling for fleet-native planners (0 = uncapped).  Per-model
    # adapted policies ignore it (they predate pool sets); the coordinated
    # planner apportions demand across same-role pools up to this cap, so
    # an elastic overflow pool is expressed as ``min=0, max=N``.
    max: int = 0
    # ---- KV-cache tiering (sim.kvcache; decode/convertible roles) ----
    # block_size > 0 switches the pool's decoders from the legacy flat
    # byte counter to the paged two-tier allocator (tokens per block);
    # 0 keeps the pre-KV-subsystem accounting byte-for-byte.
    block_size: int = 0
    # usable fraction of HBM after allocator/runtime overheads (the
    # historical hardcoded 0.9, now a knob)
    hbm_frac: float = 0.9
    # host-DRAM offload tier capacity in GB per instance; None = the
    # chip's own host_dram_cap, 0 = tier disabled (swap falls back to
    # recompute)
    offload_gb: Optional[float] = None
    # retain finished requests' prompt+output blocks in a per-decoder
    # prefix tree for copy-on-write reuse by same-session follow-ups
    prefix_cache: bool = False
    # ---- chunked prefill / deflection (decode/convertible roles) ----
    # > 0 switches the pool's decoders from whole-instance conversion to
    # per-iteration chunked prefill: prompts split into chunks of at most
    # this many tokens, each co-scheduled inside a decode iteration and
    # re-capped online against Eq. 5's TPOT headroom.  On decode pools it
    # additionally makes the instances deflection targets (Alg. 1 round
    # 2b).  0 keeps the legacy wholesale-conversion path byte-for-byte.
    prefill_chunking: int = 0
    # ---- KV-locality gateway (core.gateway; decode/convertible roles) ----
    # route this pool's decode placements through the fleet-level prefix
    # hashtrie gateway: block-granular cross-session prefix matching, a
    # cached_suffix_savings - alpha*queue_depth locality score, and
    # hot-prefix replication across decoders.  Requires the paged
    # allocator with prefix_cache.  False keeps the PR 4 owner-steering
    # lookup byte-for-byte.
    gateway: bool = False
    # KV allocation mode for the pool's decoders: "reserve" books the
    # full predicted output length at admission (legacy, byte-identical);
    # "lazy" allocates-on-generate — admission books prompt + one output
    # block and owned blocks grow per generated token, with mid-decode
    # OOM preemption through the existing PreemptionPolicy on exhaustion.
    kv_alloc: str = "reserve"

    def __post_init__(self):
        if self.role not in ROLES:
            raise ValueError(
                f"pool {self.name!r}: unknown role {self.role!r}; "
                f"expected one of {ROLES}")
        if self.max < 0:
            raise ValueError(
                f"pool {self.name!r}: max must be >= 0 (0 = uncapped)")
        if self.max > 0 and self.max < max(self.min, self.init):
            raise ValueError(
                f"pool {self.name!r}: max={self.max} below min={self.min}/"
                f"init={self.init}")
        if self.block_size < 0:
            raise ValueError(
                f"pool {self.name!r}: block_size must be >= 0")
        if not 0.0 < self.hbm_frac <= 1.0:
            raise ValueError(
                f"pool {self.name!r}: hbm_frac must be in (0, 1]")
        if self.prefill_chunking < 0:
            raise ValueError(
                f"pool {self.name!r}: prefill_chunking must be >= 0")
        if self.prefill_chunking > 0 and self.role == "prefill":
            raise ValueError(
                f"pool {self.name!r}: prefill_chunking applies to decode-"
                "side pools (prefillers always run whole prompts)")
        if self.kv_alloc not in ("reserve", "lazy"):
            raise ValueError(
                f"pool {self.name!r}: kv_alloc must be 'reserve' or "
                f"'lazy' (got {self.kv_alloc!r})")
        if self.kv_alloc == "lazy" and self.block_size <= 0:
            raise ValueError(
                f"pool {self.name!r}: kv_alloc='lazy' needs the paged "
                "allocator (block_size > 0)")
        if self.gateway:
            if self.role == "prefill":
                raise ValueError(
                    f"pool {self.name!r}: gateway applies to decode-side "
                    "pools (placement of decode admissions)")
            if self.block_size <= 0 or not self.prefix_cache:
                raise ValueError(
                    f"pool {self.name!r}: gateway needs the paged prefix "
                    "cache (block_size > 0, prefix_cache=True)")

    @property
    def key(self) -> tuple[str, str, int]:
        """The velocity-profile identity (§III-B: per model, chip, tp)."""
        return (self.model, self.chip, self.tp)


@dataclass(frozen=True)
class TraceRoute:
    """Per-model trace routing: which workload a model's pools serve.

    ``session_prob`` turns the workload conversational: each arrival is a
    same-session follow-up with this probability, its prompt extending the
    session's shared prefix (``sim.traces.assign_sessions``; the draw uses
    an independent RNG stream, so arrivals stay byte-identical).

    ``shared_prefix_prob`` adds Zipf-popular system prompts shared
    *across* sessions (``sim.traces.assign_shared_prefixes``): each
    conversation opener starts from one of ``shared_prefix_count``
    catalog prompts with this probability, and follow-ups inherit the
    opener's prompt.  Again an independent RNG stream — arrivals (and
    the session draw) stay byte-identical."""
    model: str
    trace: str = "mixed"
    rps: float = 8.0
    priority_mix: Optional[dict[int, float]] = None
    session_prob: float = 0.0
    shared_prefix_prob: float = 0.0
    shared_prefix_len: int = 512
    shared_prefix_count: int = 8


@dataclass(frozen=True)
class FleetSpec:
    """A list of pools + per-model trace routing.

    Constraints (validated here, relied on by the engines): every model
    has at least one prefill and one decode pool (possibly several of
    each — same-role pool *sets*, planned jointly by fleet-native
    policies) and at most one convertible pool; pool names are unique;
    every route names a model that has pools.  The first-declared pool of
    each role is the model's *primary* pool: per-model adapted policies
    and legacy single-pool shims see exactly that one.
    """
    pools: tuple[PoolSpec, ...]
    routes: tuple[TraceRoute, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "pools", tuple(self.pools))
        object.__setattr__(self, "routes", tuple(self.routes))
        names = [p.name for p in self.pools]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate pool names: {names}")
        for m in self.models():
            roles = [p.role for p in self.pools_of(m)]
            if roles.count("prefill") < 1 or roles.count("decode") < 1:
                raise ValueError(
                    f"model {m!r} needs at least one prefill and one decode "
                    f"pool (got roles {roles})")
            if roles.count("convertible") > 1:
                raise ValueError(
                    f"model {m!r} has {roles.count('convertible')} "
                    "convertible pools; at most one is supported (§IV-C2: "
                    "the pool is sized offline, not scaled)")
        for r in self.routes:
            if r.model not in self.models():
                raise ValueError(f"route for unknown model {r.model!r}")

    def models(self) -> list[str]:
        seen: list[str] = []
        for p in self.pools:
            if p.model not in seen:
                seen.append(p.model)
        return seen

    def pools_of(self, model: str) -> list[PoolSpec]:
        return [p for p in self.pools if p.model == model]


def single_pool_fleet(model: str = "llama31_8b", chip: str = "a100",
                      tp: int = 1, trace: str = "mixed", rps: float = 8.0,
                      n_convertible: int = 0,
                      priority_mix: Optional[dict[int, float]] = None,
                      init_prefillers: int = 1,
                      init_decoders: int = 1,
                      session_prob: float = 0.0,
                      block_size: int = 0,
                      hbm_frac: float = 0.9,
                      offload_gb: Optional[float] = None,
                      prefix_cache: bool = False,
                      prefill_chunking: int = 0,
                      gateway: bool = False,
                      kv_alloc: str = "reserve",
                      shared_prefix_prob: float = 0.0,
                      shared_prefix_len: int = 512,
                      shared_prefix_count: int = 8) -> FleetSpec:
    """The classic homogeneous PD fleet as a one-model spec — what the
    legacy ``run_policy(policy, trace, model, chip, tp, ...)`` signature
    desugars to.  The KV-tier, ``prefill_chunking``, and gateway knobs
    apply to the decode-side pools; the defaults keep the legacy
    flat-byte-counter, wholesale-conversion, owner-steering behavior."""
    kv = dict(block_size=block_size, hbm_frac=hbm_frac,
              offload_gb=offload_gb, prefix_cache=prefix_cache,
              prefill_chunking=prefill_chunking, gateway=gateway,
              kv_alloc=kv_alloc)
    pools = [
        PoolSpec("prefill", "prefill", model, chip, tp, init=init_prefillers,
                 hbm_frac=hbm_frac),
        PoolSpec("decode", "decode", model, chip, tp, init=init_decoders,
                 **kv),
        PoolSpec("convertible", "convertible", model, chip, tp,
                 init=n_convertible, **kv),
    ]
    return FleetSpec(tuple(pools),
                     (TraceRoute(model, trace, rps, priority_mix,
                                 session_prob=session_prob,
                                 shared_prefix_prob=shared_prefix_prob,
                                 shared_prefix_len=shared_prefix_len,
                                 shared_prefix_count=shared_prefix_count),))


@dataclass(frozen=True)
class ExperimentSpec:
    """A declarative, JSON-round-trippable experiment: fleet + policy +
    engine + preemption + horizon.  ``sim.runner.run_spec`` executes it
    end-to-end on either engine."""
    fleet: FleetSpec
    policy: str = "tokenscale"
    engine: str = "fluid"
    preemption: str = "none"
    duration: float = 120.0
    seed: int = 0
    dt: float = 0.025
    predictor_accuracy: float = 0.85
    max_instances: int = 64
    extra_horizon: float = 30.0    # drain time past the last arrival
    # timeline snapshot cadence in seconds; None = adaptive (the engines'
    # historical 0.2 s, stretched on multi-hour horizons so the timeline
    # length stays bounded — see ClusterBase._snapshot_every)
    snapshot_interval: Optional[float] = None
    policy_options: dict = field(default_factory=dict)
    # attach a flight recorder (repro.obs) to the run: per-request span
    # tracing + metrics registry + scaling-decision log on the resulting
    # SimReport.obs.  Off by default — the engines' telemetry hooks are
    # no-ops and the run is byte-identical to a pre-telemetry build.
    telemetry: bool = False
    # chaos engine (sim.faults.FaultConfig as a dict): a seeded,
    # deterministic fault schedule — instance crashes with warm restart,
    # straggler chips, degraded swap bandwidth, KVC link outages — plus
    # the self-healing control plane gated by its ``recovery`` key.  None
    # (default) builds no schedule and the run is byte-identical to a
    # pre-chaos build.
    faults: Optional[dict] = None

    # ---- JSON round trip -------------------------------------------------
    def to_dict(self) -> dict:
        d = asdict(self)
        if d.get("snapshot_interval") is None:
            # keep the serialized form of specs that don't set the knob
            # identical to the pre-knob schema (the hetero golden records
            # a spec dict and must reproduce byte-for-byte)
            d.pop("snapshot_interval")
        if not d.get("telemetry"):
            # same schema-stability rule for the telemetry knob
            d.pop("telemetry", None)
        if not d.get("faults"):
            # ...and for the chaos knob
            d.pop("faults", None)
        for p in d["fleet"]["pools"]:
            # same schema-stability rule for the chunking knob: pools that
            # keep the legacy wholesale-conversion default serialize
            # exactly as they did before the knob existed
            if not p.get("prefill_chunking"):
                p.pop("prefill_chunking", None)
            # ...and for the pool-set scale-up cap (0 = uncapped = the
            # pre-cap schema)
            if not p.get("max"):
                p.pop("max", None)
            # ...and for the gateway knobs (off/reserve = the pre-gateway
            # schema)
            if not p.get("gateway"):
                p.pop("gateway", None)
            if p.get("kv_alloc") == "reserve":
                p.pop("kv_alloc", None)
        for r in d["fleet"]["routes"]:
            # shared-prefix knobs off -> the pre-knob route schema
            if not r.get("shared_prefix_prob"):
                r.pop("shared_prefix_prob", None)
                r.pop("shared_prefix_len", None)
                r.pop("shared_prefix_count", None)
        return d

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        d = dict(d)
        f = d.pop("fleet")
        pools = tuple(PoolSpec(**p) for p in f.get("pools", ()))
        routes = []
        for r in f.get("routes", ()):
            r = dict(r)
            mix = r.get("priority_mix")
            if mix is not None:
                # JSON stringifies int keys; undo that on the way back in
                r["priority_mix"] = {int(k): float(v) for k, v in mix.items()}
            routes.append(TraceRoute(**r))
        return cls(fleet=FleetSpec(pools, tuple(routes)), **d)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    @classmethod
    def load(cls, path: str) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_dict(json.load(f))


# ---------------------------------------------------------------------------
# Runtime observation / plan types
# ---------------------------------------------------------------------------

@dataclass
class PoolSnapshot:
    """What the metrics plane reports for one pool each interval."""
    name: str
    role: str
    model: str
    count: int                     # provisioned instances (booting included)
    ready: int                     # past their startup latency
    queue_requests: int = 0        # queued/in-progress prefill requests
    inflight_tokens: float = 0.0   # prefill tokens not yet processed
    inflight: int = 0              # resident decode requests
    mem_util: float = 0.0          # mean HBM utilization of ready instances
    # prefill tok/s this decode-side pool absorbs via chunked deflection
    # (0 with chunking off or no queued chunk work)
    deflected_rate: float = 0.0
    # ready instances with no resident work (spill donors / drain-reapable)
    idle: int = 0
    # instances marked draining: finishing residents, billed, no new work
    draining: int = 0
    # measured effective velocity of the pool's serving instances as a
    # fraction of nominal (mean per-instance multiplier; < 1.0 under
    # straggler windows).  Filled only by the chaos engine's self-healing
    # path — stays 1.0 otherwise, and planners treat 1.0 as "no signal".
    eff_perf: float = 1.0


@dataclass
class GatewayStats:
    """Per-model gateway aggregates over the rolling 1 s window."""
    token_rate_in: float = 0.0
    token_rate_by_bucket: dict[str, float] = field(default_factory=dict)
    rps: float = 0.0
    queued: int = 0                # centrally queued requests (Alg.1 line 15)
    burst: bool = False            # §IV-A detector state at observation time


@dataclass
class FleetObservation:
    """Per-pool snapshots + per-model gateway aggregates: the pool-centric
    successor of the flat ``Observation``."""
    t: float
    pools: dict[str, PoolSnapshot]
    gateway: dict[str, GatewayStats]

    def pools_of(self, model: str, role: Optional[str] = None
                 ) -> list[PoolSnapshot]:
        return [s for s in self.pools.values()
                if s.model == model and (role is None or s.role == role)]


@dataclass
class FleetPlan:
    """Pool name -> target instance count.  Pools absent from ``targets``
    are left alone (convertible pools are fixed, §IV-C2).  ``live`` pools
    skip startup latency on scale-up (BlitzScale's ideal live scaling).

    Drain semantics (fleet-native planners only): pools named in
    ``drain`` scale down by *draining* — victims stop taking new work,
    finish their residents (billed the whole time), and are reaped only
    once idle — instead of the legacy idle-only immediate eviction.
    Plans that leave ``drain`` empty execute byte-identically to the
    pre-drain control plane.

    ``spills`` are cross-model convertible loans: ``(src, dst, n)`` moves
    up to ``n`` idle instances from convertible pool ``src`` to ``dst``
    (same chip/TP — ``core.convertible.spill_compatible``), paying the
    destination chip's startup for the weight swap."""
    targets: dict[str, int] = field(default_factory=dict)
    live: set[str] = field(default_factory=set)
    drain: set[str] = field(default_factory=set)
    spills: list[tuple[str, str, int]] = field(default_factory=list)


# ---------------------------------------------------------------------------
# Fleet policies
# ---------------------------------------------------------------------------

def flat_observation(model: str, obs: FleetObservation) -> Observation:
    """The legacy flat view of one model's pools — byte-identical to the
    pre-pool ``ClusterBase._observation`` when the fleet has a single
    model group."""
    pres = obs.pools_of(model, "prefill")
    decs = obs.pools_of(model, "decode")
    if len(pres) != 1 or len(decs) != 1:
        raise ValueError(
            f"model {model!r} has {len(pres)} prefill / {len(decs)} decode "
            "pools; the flat per-model view needs exactly one of each — "
            "multi-pool fleets need a fleet-native policy (e.g. "
            "'tokenscale-coord')")
    (pre,), (dec,) = pres, decs
    conv = obs.pools_of(model, "convertible")
    gw = obs.gateway.get(model, GatewayStats())
    return Observation(
        t=obs.t, token_rate_in=gw.token_rate_in,
        token_rate_by_bucket=gw.token_rate_by_bucket, rps=gw.rps,
        prefill_queue=pre.queue_requests + gw.queued,
        decode_inflight=dec.inflight + sum(c.inflight for c in conv),
        mem_util=dec.mem_util,
        cur_prefillers=pre.count, cur_decoders=dec.count,
        deflected_rate=dec.deflected_rate
        + sum(c.deflected_rate for c in conv))


class FleetPolicy:
    """Pool-centric policy interface: one ``FleetPlan`` per interval."""
    name = "fleet-base"
    #: per-model Eq. 2-4 intermediates of the most recent ``plan`` call
    #: ({"models": {model: {...}}}), read by the flight recorder's
    #: decision log (obs.explain); None when the planner doesn't expose
    #: its arithmetic.
    last_debug: Optional[dict] = None

    def plan(self, obs: FleetObservation) -> FleetPlan:  # pragma: no cover
        raise NotImplementedError

    def model_policy(self, model: str) -> Optional[Policy]:
        """The per-model legacy ``Policy`` driving this model's pools, if
        any — the engines use it to keep policy-conditional routing
        (burst traffic to Convertible Decoders for TokenScale only)
        byte-identical with the pre-pool control plane."""
        return None


class PerModelFleetPolicy(FleetPolicy):
    """Adapts per-model ``Policy`` objects (TokenScale Eq. 2-4 and the §V
    baselines, unmodified) onto named pools: each model's policy sees a
    flat ``Observation`` reconstructed from its own pools' snapshots and
    gateway aggregates, and its ``ScaleDecision`` maps onto that model's
    prefill/decode pool targets."""

    def __init__(self, policies: dict[str, Policy]):
        if not policies:
            raise ValueError("need at least one per-model policy")
        self.policies = policies
        names = sorted({p.name for p in policies.values()})
        self.name = names[0] if len(names) == 1 else "+".join(names)

    def model_policy(self, model: str) -> Optional[Policy]:
        return self.policies.get(model)

    def plan(self, obs: FleetObservation) -> FleetPlan:
        plan = FleetPlan()
        debug: dict = {}
        for model, pol in self.policies.items():
            dec: ScaleDecision = pol.decide(flat_observation(model, obs))
            (pre_pool,) = obs.pools_of(model, "prefill")
            (dec_pool,) = obs.pools_of(model, "decode")
            tp, td = dec.prefillers, dec.decoders
            # measured effective velocity (chaos self-healing path):
            # straggling boxes deliver eff_perf * nominal tokens/s, so
            # Eq. 2-4's instance counts are inflated to restore the
            # provisioned token velocity.  eff_perf is 1.0 outside fault
            # windows — these branches never fire on a healthy fleet.
            if pre_pool.eff_perf < 1.0:
                tp = math.ceil(tp / max(pre_pool.eff_perf, 0.1))
            if dec_pool.eff_perf < 1.0:
                td = math.ceil(td / max(dec_pool.eff_perf, 0.1))
            plan.targets[pre_pool.name] = tp
            plan.targets[dec_pool.name] = td
            if dec.live:
                plan.live |= {pre_pool.name, dec_pool.name}
            if pol.last_debug is not None:
                gw = obs.gateway.get(model)
                debug[model] = dict(pol.last_debug,
                                    burst=gw.burst if gw else False)
        self.last_debug = {"models": debug} if debug else None
        return plan


class CoordinatedTokenScalePolicy(FleetPolicy):
    """Fleet-native TokenScale: Eq. 2-4 generalized over same-role pool
    *sets*, planned globally across models.

    Apportionment (the pool-set generalization of Eq. 2-3): each model's
    residual prefill token rate (Eq. 2's ``token_rate_in - deflected``)
    and per-bucket decode rate vector (Eq. 3) are walked down that
    model's same-role pools ranked by *cost-normalized velocity*
    (tokens/s/$, ``core.velocity``) — the DistServe goodput-per-GPU axis.
    Each pool absorbs demand at its own profiled velocity up to its
    ``PoolSpec.max`` cap; only the last pool touched ceils, so the pool
    set provisions no more than a single merged pool would.  The fixed
    convertible pool absorbs decode demand first at its *current* size
    (Eq. 4 net of borrowed/lent boxes), then floors and per-pool
    down-hysteresis apply exactly as in the per-model policy.

    Scale-down is drain-based (every planned pool is named in
    ``FleetPlan.drain``): victims finish residents before leaving, so a
    lower target never evicts KV state mid-decode.

    Cross-model spill: when a model's gateway is in burst and its
    convertible pool has no idle box, idle convertibles are borrowed from
    non-bursting models' ``spill_compatible`` pools (same chip/TP — the
    loan is a weight swap, paying startup).  Loans are inferred from pool
    sizes relative to ``PoolSpec.init`` — no planner-side ledger — and
    reverse automatically once the borrower's burst subsides and the
    borrowed boxes idle."""

    name = "tokenscale-coord"

    def __init__(self, fleet: FleetSpec, profiles: dict[str, VelocityProfile],
                 down_delay: float = 5.0, spill: bool = True,
                 headroom: float = 0.9):
        missing = [p.name for p in fleet.pools if p.name not in profiles]
        if missing:
            raise ValueError(f"no velocity profile for pools {missing}")
        if not 0.0 < headroom <= 1.0:
            raise ValueError(f"headroom must be in (0, 1], got {headroom}")
        self.fleet = fleet
        self.profiles = profiles
        self.spill = spill
        # utilization guard-band: demand is apportioned against
        # headroom * velocity, so pools run below saturation.  The per-
        # instance first-iteration time grows with resident batch, so a
        # pool planned to 100% of its TPOT-capped batch serves marginal
        # TTFTs late even when aggregate throughput keeps up.
        self.headroom = headroom
        self.hyst = _DownHysteresis(down_delay)
        # per-model TokenScale instances: never asked to decide() — they
        # exist so the engines' policy-conditional routing (burst traffic
        # to Convertible Decoders for TokenScale only) sees this planner
        # as TokenScale for every model it serves
        self._model_pols: dict[str, Policy] = {}
        for m in fleet.models():
            by_role = {r: [p for p in fleet.pools_of(m) if p.role == r]
                       for r in ROLES}
            conv = by_role["convertible"]
            self._model_pols[m] = TokenScalePolicy(
                profiles[by_role["prefill"][0].name],
                convertible=conv[0].init if conv else 0,
                decode_profile=profiles[by_role["decode"][0].name],
                down_delay=down_delay)

    def model_policy(self, model: str) -> Optional[Policy]:
        return self._model_pols.get(model)

    # ---- pool-set apportionment (Eq. 2-3 over ranked pools) -----------
    def _rank(self, pools: list[PoolSpec], dollar_velocity) -> list[PoolSpec]:
        """Descending tokens/s/$; ``sorted`` is stable, so equal-cost pools
        keep declaration order (the primary pool wins ties)."""
        return sorted(pools,
                      key=lambda p: -dollar_velocity(self.profiles[p.name]))

    def _settle(self, plan: FleetPlan, obs: FleetObservation,
                spec: PoolSpec, take: int, burst: bool = False):
        snap = obs.pools[spec.name]
        tgt = max(take, spec.min)
        if snap.eff_perf < 1.0:
            # stragglers deliver eff_perf * nominal velocity: inflate the
            # pool's target so provisioned token velocity is restored
            # (chaos self-healing path; 1.0 — i.e. never — otherwise)
            tgt = math.ceil(tgt / max(snap.eff_perf, 0.1))
        active = snap.count - snap.draining
        if burst:
            # §IV-A gate: while the model's burst detector is hot, never
            # drain below the active size — the inter-sub-burst lull that
            # momentarily shrinks the token rate is exactly when released
            # capacity would have to be bought back at startup latency
            tgt = max(tgt, active)
        plan.targets[spec.name] = self.hyst.apply(spec.name, active, tgt,
                                                  obs.t)

    def _apportion_prefill(self, plan: FleetPlan, obs: FleetObservation,
                           pools: list[PoolSpec], rate: float,
                           burst: bool = False):
        remaining = rate
        for spec in self._rank(pools, prefill_tokens_per_dollar):
            prof = self.profiles[spec.name]
            v = max(min(prof.v_prefill, prof.v_network) * self.headroom,
                    1e-9)                                        # Eq. 2
            cap = spec.max if spec.max > 0 else float("inf")
            frac = remaining / v
            if frac > cap:
                take = int(cap)
                remaining -= cap * v
            else:
                take = min(math.ceil(frac), int(min(cap, 1 << 30)))
                remaining = 0.0
            self._settle(plan, obs, spec, take, burst)

    def _decode_need(self, prof: VelocityProfile,
                     rem: dict[str, float]) -> float:
        return sum(r / max(prof.v_decode.get(b, 1e9) * self.headroom, 1e-9)
                   for b, r in rem.items())                       # Eq. 3

    def _apportion_decode(self, plan: FleetPlan, obs: FleetObservation,
                          pools: list[PoolSpec], rem: dict[str, float],
                          burst: bool = False):
        for spec in self._rank(pools, decode_tokens_per_dollar):
            prof = self.profiles[spec.name]
            need = self._decode_need(prof, rem)
            cap = spec.max if spec.max > 0 else float("inf")
            if need > cap:
                take = int(cap)
                f = cap / need
                for b in rem:
                    rem[b] *= (1.0 - f)
            else:
                take = min(math.ceil(need), int(min(cap, 1 << 30)))
                for b in rem:
                    rem[b] = 0.0
            self._settle(plan, obs, spec, take, burst)

    # ---- cross-model convertible spill --------------------------------
    def _plan_spills(self, plan: FleetPlan, obs: FleetObservation):
        convs = {m: next((p for p in self.fleet.pools_of(m)
                          if p.role == "convertible"), None)
                 for m in self.fleet.models()}
        lent: dict[str, int] = {}      # boxes committed within this plan
        for m, cp in convs.items():
            if cp is None:
                continue
            snap = obs.pools.get(cp.name)
            if snap is None:
                continue
            gw = obs.gateway.get(m, GatewayStats())
            if gw.burst and snap.idle == 0:
                # saturated convertibles under a detected burst: borrow
                for m2, dp in convs.items():
                    if m2 == m or dp is None or not spill_compatible(dp, cp):
                        continue
                    if obs.gateway.get(m2, GatewayStats()).burst:
                        continue
                    ds = obs.pools.get(dp.name)
                    if ds is None:
                        continue
                    out = lent.get(dp.name, 0)
                    # lend idle boxes only, never the donor's last one
                    n = min(ds.idle - out, ds.count - out - 1)
                    if n <= 0:
                        continue
                    plan.spills.append((dp.name, cp.name, n))
                    lent[dp.name] = out + n
            elif not gw.burst and snap.count > cp.init and snap.idle > 0:
                # burst over: return borrowed boxes to shrunken donors
                idle = snap.idle
                for m2, dp in convs.items():
                    if idle <= 0:
                        break
                    if m2 == m or dp is None or not spill_compatible(cp, dp):
                        continue
                    ds = obs.pools.get(dp.name)
                    if ds is None or ds.count >= dp.init:
                        continue
                    n = min(idle, snap.count - cp.init, dp.init - ds.count)
                    if n <= 0:
                        continue
                    plan.spills.append((cp.name, dp.name, n))
                    idle -= n

    # ---- the plan -----------------------------------------------------
    def plan(self, obs: FleetObservation) -> FleetPlan:
        plan = FleetPlan()
        debug: dict = {}
        for m in self.fleet.models():
            by_role = {r: [p for p in self.fleet.pools_of(m) if p.role == r]
                       for r in ROLES}
            gw = obs.gateway.get(m, GatewayStats())
            # Eq. 2 residual: chunk-deflected work is owed by the decode
            # side, never double-provisioned (summed across the pool set)
            deflected = sum(
                obs.pools[p.name].deflected_rate
                for p in by_role["decode"] + by_role["convertible"]
                if p.name in obs.pools)
            rate = max(gw.token_rate_in - deflected, 0.0)
            self._apportion_prefill(plan, obs, by_role["prefill"], rate,
                                    gw.burst)
            # Eq. 4 first: the convertible pool absorbs decode demand at
            # its *current* size (loans included) before regular pools
            rem = dict(gw.token_rate_by_bucket)
            conv = by_role["convertible"]
            conv_dbg = {"convertible": 0, "absorbed_frac": 0.0}
            if conv and rem:
                snap = obs.pools.get(conv[0].name)
                n_conv = snap.count if snap is not None else conv[0].init
                cprof = self.profiles[conv[0].name]
                need = self._decode_need(cprof, rem)
                conv_dbg["convertible"] = n_conv
                if need > 0.0:
                    f = min(n_conv / need, 1.0)
                    conv_dbg["absorbed_frac"] = f
                    for b in rem:
                        rem[b] *= (1.0 - f)
            self._apportion_decode(plan, obs, by_role["decode"], rem,
                                   gw.burst)
            # flight-recorder breadcrumb (pool-set Eq. 2-4 inputs + the
            # cost ranking that ordered the apportionment), read by
            # obs.explain via ``FlightRecorder.on_plan``
            debug[m] = {
                "policy": self.name, "burst": gw.burst,
                "eq2": {"token_rate_in": gw.token_rate_in,
                        "deflected_rate": deflected, "rate": rate,
                        "headroom": self.headroom},
                "eq3": {"rate_by_bucket": dict(gw.token_rate_by_bucket)},
                "eq4": conv_dbg,
                "prefill_rank": [
                    (p.name, prefill_tokens_per_dollar(self.profiles[p.name]))
                    for p in self._rank(by_role["prefill"],
                                        prefill_tokens_per_dollar)],
                "decode_rank": [
                    (p.name, decode_tokens_per_dollar(self.profiles[p.name]))
                    for p in self._rank(by_role["decode"],
                                        decode_tokens_per_dollar)],
            }
        self.last_debug = {"models": debug}
        # drain-based scale-down for every pool this planner owns
        plan.drain = set(plan.targets)
        if self.spill:
            self._plan_spills(plan, obs)
        return plan


# ---------------------------------------------------------------------------
# Fleet-policy registry: string-keyed construction of fleet-native planners
# ---------------------------------------------------------------------------

#: name -> factory(fleet_spec, {pool name -> VelocityProfile}, **options)
FLEET_POLICY_REGISTRY: dict[str, Callable[..., FleetPolicy]] = {}


def register_fleet_policy(name: str):
    """Register a fleet-native policy factory.  Unlike ``@register_policy``
    (per-model, adapted through ``PerModelFleetPolicy``), these factories
    see the whole ``FleetSpec`` and one profile per pool, and plan all
    pools jointly.  ``sim.runner.run_spec`` checks this registry first, so
    an ``ExperimentSpec.policy`` string resolves to a fleet-native planner
    when one exists under that name."""
    def deco(factory):
        FLEET_POLICY_REGISTRY[name] = factory
        factory.policy_name = name
        return factory
    return deco


def build_fleet_policy(name: str, fleet: FleetSpec,
                       profiles: dict[str, VelocityProfile],
                       **options) -> FleetPolicy:
    try:
        factory = FLEET_POLICY_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown fleet policy {name!r}; registered: "
            f"{sorted(FLEET_POLICY_REGISTRY)}")
    return factory(fleet, profiles, **options)


@register_fleet_policy("tokenscale-coord")
def _build_tokenscale_coord(fleet, profiles, **kw):
    return CoordinatedTokenScalePolicy(fleet, profiles, **kw)


def __getattr__(name: str):
    # Lazy re-export of the chaos-engine control-plane pieces (the health
    # monitor conceptually belongs to the fleet layer, but the
    # implementation lives with the fault machinery).  Lazy because an
    # eager ``core.fleet -> sim.faults`` import would cycle through
    # ``repro.sim.__init__`` back into this module.
    if name in ("FaultConfig", "FaultStats", "HealthMonitor"):
        from repro.sim import faults
        return getattr(faults, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
