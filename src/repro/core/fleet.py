"""Pool-centric control-plane API (heterogeneous fleets, multi-model).

TokenScale's velocity metric is defined per (model, chip, tp) instance
tuple, but the original control plane baked in exactly one: flat
``prefillers``/``decoders`` counts in ``Observation``/``ScaleDecision``
and a single ``VelocityProfile`` threaded through everything.  This
module redesigns that surface around **pools**:

  * ``PoolSpec``        — one named pool of identical instances: a role
                          (prefill | decode | convertible), a model, a
                          chip, a TP degree, and an initial size;
  * ``FleetSpec``       — the declarative fleet: a list of pools plus
                          per-model trace routing (``TraceRoute``);
  * ``ExperimentSpec``  — a full experiment (fleet + policy + engine +
                          preemption + horizon), JSON-round-trippable so
                          scenarios are files, not kwarg soup;
  * ``FleetObservation``— per-pool ``PoolSnapshot``s plus per-model
                          gateway aggregates (``GatewayStats``);
  * ``FleetPlan``       — pool name -> target instance count (the pool-
                          centric successor of ``ScaleDecision``);
  * ``FleetPolicy``     — consumes a ``FleetObservation``, emits a
                          ``FleetPlan``; ``PerModelFleetPolicy`` adapts
                          the existing per-model ``Policy`` classes
                          (TokenScale Eq. 2-4 and the §V baselines)
                          unchanged onto heterogeneous pools.

The sim engines execute ``FleetPlan``s against mixed pools (e.g.
a100-TP2 prefillers + h100-TP1 decoders, or two models sharing a
cluster); the old single-pool entry points survive as thin shims over
one-pool specs (``sim.runner.run_policy``).  See DESIGN.md §1b.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Optional

from repro.core.autoscaler import Observation, Policy, ScaleDecision

#: valid pool roles
ROLES = ("prefill", "decode", "convertible")


# ---------------------------------------------------------------------------
# Declarative specs (JSON-round-trippable)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PoolSpec:
    """One named pool of identical (model, chip, tp) instances."""
    name: str
    role: str                      # prefill | decode | convertible
    model: str = "llama31_8b"
    chip: str = "a100"
    tp: int = 1
    init: int = 1                  # initial (convertible: fixed) size
    min: int = 1                   # scale-down floor (non-convertible)
    # ---- KV-cache tiering (sim.kvcache; decode/convertible roles) ----
    # block_size > 0 switches the pool's decoders from the legacy flat
    # byte counter to the paged two-tier allocator (tokens per block);
    # 0 keeps the pre-KV-subsystem accounting byte-for-byte.
    block_size: int = 0
    # usable fraction of HBM after allocator/runtime overheads (the
    # historical hardcoded 0.9, now a knob)
    hbm_frac: float = 0.9
    # host-DRAM offload tier capacity in GB per instance; None = the
    # chip's own host_dram_cap, 0 = tier disabled (swap falls back to
    # recompute)
    offload_gb: Optional[float] = None
    # retain finished requests' prompt+output blocks in a per-decoder
    # prefix tree for copy-on-write reuse by same-session follow-ups
    prefix_cache: bool = False
    # ---- chunked prefill / deflection (decode/convertible roles) ----
    # > 0 switches the pool's decoders from whole-instance conversion to
    # per-iteration chunked prefill: prompts split into chunks of at most
    # this many tokens, each co-scheduled inside a decode iteration and
    # re-capped online against Eq. 5's TPOT headroom.  On decode pools it
    # additionally makes the instances deflection targets (Alg. 1 round
    # 2b).  0 keeps the legacy wholesale-conversion path byte-for-byte.
    prefill_chunking: int = 0

    def __post_init__(self):
        if self.role not in ROLES:
            raise ValueError(
                f"pool {self.name!r}: unknown role {self.role!r}; "
                f"expected one of {ROLES}")
        if self.block_size < 0:
            raise ValueError(
                f"pool {self.name!r}: block_size must be >= 0")
        if not 0.0 < self.hbm_frac <= 1.0:
            raise ValueError(
                f"pool {self.name!r}: hbm_frac must be in (0, 1]")
        if self.prefill_chunking < 0:
            raise ValueError(
                f"pool {self.name!r}: prefill_chunking must be >= 0")
        if self.prefill_chunking > 0 and self.role == "prefill":
            raise ValueError(
                f"pool {self.name!r}: prefill_chunking applies to decode-"
                "side pools (prefillers always run whole prompts)")

    @property
    def key(self) -> tuple[str, str, int]:
        """The velocity-profile identity (§III-B: per model, chip, tp)."""
        return (self.model, self.chip, self.tp)


@dataclass(frozen=True)
class TraceRoute:
    """Per-model trace routing: which workload a model's pools serve.

    ``session_prob`` turns the workload conversational: each arrival is a
    same-session follow-up with this probability, its prompt extending the
    session's shared prefix (``sim.traces.assign_sessions``; the draw uses
    an independent RNG stream, so arrivals stay byte-identical)."""
    model: str
    trace: str = "mixed"
    rps: float = 8.0
    priority_mix: Optional[dict[int, float]] = None
    session_prob: float = 0.0


@dataclass(frozen=True)
class FleetSpec:
    """A list of pools + per-model trace routing.

    Constraints (validated here, relied on by the engines): every model
    has exactly one prefill and one decode pool and at most one
    convertible pool; pool names are unique; every route names a model
    that has pools.
    """
    pools: tuple[PoolSpec, ...]
    routes: tuple[TraceRoute, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "pools", tuple(self.pools))
        object.__setattr__(self, "routes", tuple(self.routes))
        names = [p.name for p in self.pools]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate pool names: {names}")
        for m in self.models():
            roles = [p.role for p in self.pools_of(m)]
            if roles.count("prefill") != 1 or roles.count("decode") != 1:
                raise ValueError(
                    f"model {m!r} needs exactly one prefill and one decode "
                    f"pool (got roles {roles})")
            if roles.count("convertible") > 1:
                raise ValueError(
                    f"model {m!r} has {roles.count('convertible')} "
                    "convertible pools; at most one is supported (§IV-C2: "
                    "the pool is sized offline, not scaled)")
        for r in self.routes:
            if r.model not in self.models():
                raise ValueError(f"route for unknown model {r.model!r}")

    def models(self) -> list[str]:
        seen: list[str] = []
        for p in self.pools:
            if p.model not in seen:
                seen.append(p.model)
        return seen

    def pools_of(self, model: str) -> list[PoolSpec]:
        return [p for p in self.pools if p.model == model]


def single_pool_fleet(model: str = "llama31_8b", chip: str = "a100",
                      tp: int = 1, trace: str = "mixed", rps: float = 8.0,
                      n_convertible: int = 0,
                      priority_mix: Optional[dict[int, float]] = None,
                      init_prefillers: int = 1,
                      init_decoders: int = 1,
                      session_prob: float = 0.0,
                      block_size: int = 0,
                      hbm_frac: float = 0.9,
                      offload_gb: Optional[float] = None,
                      prefix_cache: bool = False,
                      prefill_chunking: int = 0) -> FleetSpec:
    """The classic homogeneous PD fleet as a one-model spec — what the
    legacy ``run_policy(policy, trace, model, chip, tp, ...)`` signature
    desugars to.  The KV-tier knobs and ``prefill_chunking`` apply to the
    decode-side pools; the defaults keep the legacy flat-byte-counter,
    wholesale-conversion behavior."""
    kv = dict(block_size=block_size, hbm_frac=hbm_frac,
              offload_gb=offload_gb, prefix_cache=prefix_cache,
              prefill_chunking=prefill_chunking)
    pools = [
        PoolSpec("prefill", "prefill", model, chip, tp, init=init_prefillers,
                 hbm_frac=hbm_frac),
        PoolSpec("decode", "decode", model, chip, tp, init=init_decoders,
                 **kv),
        PoolSpec("convertible", "convertible", model, chip, tp,
                 init=n_convertible, **kv),
    ]
    return FleetSpec(tuple(pools),
                     (TraceRoute(model, trace, rps, priority_mix,
                                 session_prob=session_prob),))


@dataclass(frozen=True)
class ExperimentSpec:
    """A declarative, JSON-round-trippable experiment: fleet + policy +
    engine + preemption + horizon.  ``sim.runner.run_spec`` executes it
    end-to-end on either engine."""
    fleet: FleetSpec
    policy: str = "tokenscale"
    engine: str = "fluid"
    preemption: str = "none"
    duration: float = 120.0
    seed: int = 0
    dt: float = 0.025
    predictor_accuracy: float = 0.85
    max_instances: int = 64
    extra_horizon: float = 30.0    # drain time past the last arrival
    # timeline snapshot cadence in seconds; None = adaptive (the engines'
    # historical 0.2 s, stretched on multi-hour horizons so the timeline
    # length stays bounded — see ClusterBase._snapshot_every)
    snapshot_interval: Optional[float] = None
    policy_options: dict = field(default_factory=dict)

    # ---- JSON round trip -------------------------------------------------
    def to_dict(self) -> dict:
        d = asdict(self)
        if d.get("snapshot_interval") is None:
            # keep the serialized form of specs that don't set the knob
            # identical to the pre-knob schema (the hetero golden records
            # a spec dict and must reproduce byte-for-byte)
            d.pop("snapshot_interval")
        for p in d["fleet"]["pools"]:
            # same schema-stability rule for the chunking knob: pools that
            # keep the legacy wholesale-conversion default serialize
            # exactly as they did before the knob existed
            if not p.get("prefill_chunking"):
                p.pop("prefill_chunking", None)
        return d

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        d = dict(d)
        f = d.pop("fleet")
        pools = tuple(PoolSpec(**p) for p in f.get("pools", ()))
        routes = []
        for r in f.get("routes", ()):
            r = dict(r)
            mix = r.get("priority_mix")
            if mix is not None:
                # JSON stringifies int keys; undo that on the way back in
                r["priority_mix"] = {int(k): float(v) for k, v in mix.items()}
            routes.append(TraceRoute(**r))
        return cls(fleet=FleetSpec(pools, tuple(routes)), **d)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    @classmethod
    def load(cls, path: str) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_dict(json.load(f))


# ---------------------------------------------------------------------------
# Runtime observation / plan types
# ---------------------------------------------------------------------------

@dataclass
class PoolSnapshot:
    """What the metrics plane reports for one pool each interval."""
    name: str
    role: str
    model: str
    count: int                     # provisioned instances (booting included)
    ready: int                     # past their startup latency
    queue_requests: int = 0        # queued/in-progress prefill requests
    inflight_tokens: float = 0.0   # prefill tokens not yet processed
    inflight: int = 0              # resident decode requests
    mem_util: float = 0.0          # mean HBM utilization of ready instances
    # prefill tok/s this decode-side pool absorbs via chunked deflection
    # (0 with chunking off or no queued chunk work)
    deflected_rate: float = 0.0


@dataclass
class GatewayStats:
    """Per-model gateway aggregates over the rolling 1 s window."""
    token_rate_in: float = 0.0
    token_rate_by_bucket: dict[str, float] = field(default_factory=dict)
    rps: float = 0.0
    queued: int = 0                # centrally queued requests (Alg.1 line 15)


@dataclass
class FleetObservation:
    """Per-pool snapshots + per-model gateway aggregates: the pool-centric
    successor of the flat ``Observation``."""
    t: float
    pools: dict[str, PoolSnapshot]
    gateway: dict[str, GatewayStats]

    def pools_of(self, model: str, role: Optional[str] = None
                 ) -> list[PoolSnapshot]:
        return [s for s in self.pools.values()
                if s.model == model and (role is None or s.role == role)]


@dataclass
class FleetPlan:
    """Pool name -> target instance count.  Pools absent from ``targets``
    are left alone (convertible pools are fixed, §IV-C2).  ``live`` pools
    skip startup latency on scale-up (BlitzScale's ideal live scaling)."""
    targets: dict[str, int] = field(default_factory=dict)
    live: set[str] = field(default_factory=set)


# ---------------------------------------------------------------------------
# Fleet policies
# ---------------------------------------------------------------------------

def flat_observation(model: str, obs: FleetObservation) -> Observation:
    """The legacy flat view of one model's pools — byte-identical to the
    pre-pool ``ClusterBase._observation`` when the fleet has a single
    model group."""
    (pre,) = obs.pools_of(model, "prefill")
    (dec,) = obs.pools_of(model, "decode")
    conv = obs.pools_of(model, "convertible")
    gw = obs.gateway.get(model, GatewayStats())
    return Observation(
        t=obs.t, token_rate_in=gw.token_rate_in,
        token_rate_by_bucket=gw.token_rate_by_bucket, rps=gw.rps,
        prefill_queue=pre.queue_requests + gw.queued,
        decode_inflight=dec.inflight + sum(c.inflight for c in conv),
        mem_util=dec.mem_util,
        cur_prefillers=pre.count, cur_decoders=dec.count,
        deflected_rate=dec.deflected_rate
        + sum(c.deflected_rate for c in conv))


class FleetPolicy:
    """Pool-centric policy interface: one ``FleetPlan`` per interval."""
    name = "fleet-base"

    def plan(self, obs: FleetObservation) -> FleetPlan:  # pragma: no cover
        raise NotImplementedError

    def model_policy(self, model: str) -> Optional[Policy]:
        """The per-model legacy ``Policy`` driving this model's pools, if
        any — the engines use it to keep policy-conditional routing
        (burst traffic to Convertible Decoders for TokenScale only)
        byte-identical with the pre-pool control plane."""
        return None


class PerModelFleetPolicy(FleetPolicy):
    """Adapts per-model ``Policy`` objects (TokenScale Eq. 2-4 and the §V
    baselines, unmodified) onto named pools: each model's policy sees a
    flat ``Observation`` reconstructed from its own pools' snapshots and
    gateway aggregates, and its ``ScaleDecision`` maps onto that model's
    prefill/decode pool targets."""

    def __init__(self, policies: dict[str, Policy]):
        if not policies:
            raise ValueError("need at least one per-model policy")
        self.policies = policies
        names = sorted({p.name for p in policies.values()})
        self.name = names[0] if len(names) == 1 else "+".join(names)

    def model_policy(self, model: str) -> Optional[Policy]:
        return self.policies.get(model)

    def plan(self, obs: FleetObservation) -> FleetPlan:
        plan = FleetPlan()
        for model, pol in self.policies.items():
            dec: ScaleDecision = pol.decide(flat_observation(model, obs))
            (pre_pool,) = obs.pools_of(model, "prefill")
            (dec_pool,) = obs.pools_of(model, "decode")
            plan.targets[pre_pool.name] = dec.prefillers
            plan.targets[dec_pool.name] = dec.decoders
            if dec.live:
                plan.live |= {pre_pool.name, dec_pool.name}
        return plan
