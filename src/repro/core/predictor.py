"""Output-length predictor (§IV-B1).

Production traces carry length statistics but not prompt content, so —
exactly like the paper (§V, "we simulate an output predictor used in a prior
work, setting its accuracy to 85%") — the predictor is simulated at a
configurable accuracy: with prob `accuracy` it returns the true bucket,
otherwise a *uniformly chosen different* output class for the same input
class (S can mispredict as L: the paper specifies only the accuracy, not
an error taxonomy, and the uniform-error model is the adversarial choice —
an ordinal neighbor-biased model would understate the cost of
mispredictions for the decode load balancer).  The bucket taxonomy is
Table II's 3x3 input-output grid.
"""
from __future__ import annotations

import numpy as np

from repro.core.velocity import BUCKETS, bucket_of


class OutputPredictor:
    def __init__(self, accuracy: float = 0.85, seed: int = 0):
        assert 0.0 <= accuracy <= 1.0
        self.accuracy = accuracy
        self.rng = np.random.RandomState(seed)
        self.n_total = 0
        self.n_correct = 0

    def predict_bucket(self, in_len: int, true_out_len: int) -> str:
        """Returns the predicted bucket for a request (input length is
        observable; the output class is what the model predicts)."""
        true = bucket_of(in_len, true_out_len)
        self.n_total += 1
        if self.rng.rand() < self.accuracy:
            self.n_correct += 1
            return true
        # mispredict: a different output class for the same input class
        i_cls, o_cls = true.split("-")
        wrong = [o for o in "SML" if o != o_cls]
        return f"{i_cls}-{self.rng.choice(wrong)}"

    def predict_out_len(self, in_len: int, true_out_len: int) -> int:
        from repro.core.velocity import BUCKET_OUTPUT
        b = self.predict_bucket(in_len, true_out_len)
        return BUCKET_OUTPUT[b.split("-")[1]]

    @property
    def measured_accuracy(self) -> float:
        return self.n_correct / max(self.n_total, 1)
