"""Sharded .npz checkpointing for arbitrary pytrees (no orbax offline).

Leaves are flattened to path-keyed arrays; large trees are split across
multiple .npz shards so no single file exceeds `shard_bytes`.  Restore
rebuilds the pytree onto host memory (device placement is the caller's
job — launch/train.py re-device_puts with the mesh shardings).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}

    def rec(prefix, t):
        if isinstance(t, dict):
            for k in sorted(t):
                rec(f"{prefix}/{k}" if prefix else k, t[k])
        elif isinstance(t, (list, tuple)):
            for i, x in enumerate(t):
                rec(f"{prefix}/{i}", x)
        else:
            flat[prefix] = np.asarray(t)
    rec("", tree)
    return flat


def save(path: str, tree: Any, step: int = 0,
         shard_bytes: int = 1 << 30) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    shards: list[dict[str, np.ndarray]] = [{}]
    sizes = [0]
    for k, v in flat.items():
        if sizes[-1] + v.nbytes > shard_bytes and shards[-1]:
            shards.append({})
            sizes.append(0)
        shards[-1][k] = v
        sizes[-1] += v.nbytes
    index = {"step": step, "n_shards": len(shards),
             "keys": {k: i for i, sh in enumerate(shards) for k in sh}}
    for i, sh in enumerate(shards):
        np.savez(os.path.join(path, f"shard_{i:04d}.npz"), **sh)
    with open(os.path.join(path, "index.json"), "w") as f:
        json.dump(index, f)


def restore(path: str, like: Any = None) -> tuple[Any, int]:
    """Returns (tree, step). With `like`, re-nests into its structure and
    casts to its dtypes; otherwise returns the flat {path: array} dict."""
    with open(os.path.join(path, "index.json")) as f:
        index = json.load(f)
    flat: dict[str, np.ndarray] = {}
    for i in range(index["n_shards"]):
        with np.load(os.path.join(path, f"shard_{i:04d}.npz")) as z:
            flat.update({k: z[k] for k in z.files})
    if like is None:
        return flat, index["step"]

    paths_like = _flatten(like)
    assert set(paths_like) == set(flat), (
        "checkpoint/param structure mismatch: "
        f"{set(paths_like) ^ set(flat)}")

    def rebuild(prefix, t):
        if isinstance(t, dict):
            return {k: rebuild(f"{prefix}/{k}" if prefix else k, v)
                    for k, v in t.items()}
        if isinstance(t, (list, tuple)):
            out = [rebuild(f"{prefix}/{i}", x) for i, x in enumerate(t)]
            return type(t)(out)
        return flat[prefix].astype(np.asarray(t).dtype)

    return rebuild("", like), index["step"]
