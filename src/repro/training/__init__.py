from repro.training.checkpoint import restore, save  # noqa: F401
from repro.training.data import DataConfig, PackedDataset  # noqa: F401
from repro.training.optimizer import (  # noqa: F401
    AdamWConfig, AdamWState, adamw_init, adamw_update, global_norm, lr_at,
)
from repro.training.train_loop import (  # noqa: F401
    TrainResult, lm_loss, make_train_step, train,
)
