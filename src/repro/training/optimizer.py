"""Pure-JAX AdamW + LR schedules (no optax in this environment).

Optimizer state mirrors the parameter pytree (so it inherits parameter
sharding under pjit: m/v shard exactly like their weights — the ZeRO-ish
"optimizer state sharded with params" layout for free).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # memory options for frontier-scale models (see DESIGN.md):
    moment_dtype: str = "float32"   # "bfloat16" halves m/v residency
    factored: bool = False          # adafactor-style factored 2nd moment
                                    # (row/col means for >=2D leaves)


def lr_at(cfg: AdamWConfig, step) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    mdt = jnp.dtype(cfg.moment_dtype)

    def zeros_m(x):
        return jnp.zeros(x.shape, mdt)

    def zeros_v(x):
        if cfg.factored and x.ndim >= 2:
            # factored second moment: row means + col means over the last
            # two dims (leading stacking dims kept whole)
            return (jnp.zeros(x.shape[:-1], mdt),
                    jnp.zeros(x.shape[:-2] + x.shape[-1:], mdt))
        return jnp.zeros(x.shape, mdt)

    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros_m, params),
                      v=jax.tree.map(zeros_v, params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.ones(())
    step = state.step + 1
    lr = lr_at(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = (cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g)
        if isinstance(v, tuple):   # factored second moment
            vr, vc = v
            g2 = g * g
            vr = cfg.b2 * vr.astype(jnp.float32) \
                + (1 - cfg.b2) * g2.mean(axis=-1)
            vc = cfg.b2 * vc.astype(jnp.float32) \
                + (1 - cfg.b2) * g2.mean(axis=-2)
            vh = (vr[..., None] * vc[..., None, :]
                  / jnp.maximum(vr.mean(-1)[..., None, None], 1e-30)) / bc2
            new_v = (vr.astype(mdt), vc.astype(mdt))
        else:
            vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
            vh = vf / bc2
            new_v = vf.astype(mdt)
        mh = m / bc1
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m.astype(mdt), new_v)

    is_v_leaf = lambda x: isinstance(x, tuple)  # noqa: E731
    flat = jax.tree.map(upd, params, grads, state.m, state.v,
                        is_leaf=lambda x: False)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
