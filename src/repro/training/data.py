"""Synthetic-corpus data pipeline: deterministic, packed, shardable.

No external datasets ship with this container, so the pipeline synthesizes a
structured corpus (a zipf-distributed token stream with local n-gram
correlations — enough signal for loss to drop measurably during the e2e
training example) and packs it into fixed-length training windows with
next-token labels.  The iterator is stateless-resumable: batch i is a pure
function of (seed, i), so checkpoint-resume needs only the step counter.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    ngram: int = 3            # order of the synthetic correlations


class PackedDataset:
    """Deterministic packed LM batches: (tokens, labels) int32."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        v = cfg.vocab_size
        # fixed n-gram transition structure: each context class prefers a
        # small set of successor tokens (gives the model something to learn)
        self._succ = rng.randint(0, v, size=(997, 8)).astype(np.int32)

    def batch(self, index: int) -> tuple[np.ndarray, np.ndarray]:
        cfg = self.cfg
        rng = np.random.RandomState((cfg.seed * 1_000_003 + index)
                                    % (2 ** 31 - 1))
        B, S, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        # zipf base stream (clipped into vocab)
        toks = rng.zipf(cfg.zipf_a, size=(B, S + 1)).astype(np.int64)
        toks = (toks - 1) % v
        # overlay n-gram correlations on 50% of positions
        ctx = np.zeros((B,), np.int64)
        for t in range(1, S + 1):
            ctx = (ctx * 31 + toks[:, t - 1]) % 997
            use = rng.rand(B) < 0.5
            pick = self._succ[ctx, rng.randint(0, 8, size=B)]
            toks[:, t] = np.where(use, pick, toks[:, t])
        toks = toks.astype(np.int32)
        return toks[:, :-1], toks[:, 1:]

    def __iter__(self) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1
