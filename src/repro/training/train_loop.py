"""pjit train step + host loop.

``make_train_step`` builds the jitted (params, opt, batch) -> (params, opt,
metrics) function used by both the CPU examples (tiny models) and the
multi-pod dry-run (full configs, abstract lowering).  Loss = causal LM
cross-entropy + MoE router aux.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward_train, init_params
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_init,
                                      adamw_update)


def lm_loss(cfg: ModelConfig, params, tokens, labels,
            image_embeds=None, remat: bool = False) -> tuple[jax.Array, dict]:
    logits, aux = forward_train(cfg, params, tokens,
                                image_embeds=image_embeds, remat=remat)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    ce = -ll.mean()
    return ce + aux, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    donate: bool = True, remat: bool = False) -> Callable:
    def step(params, opt: AdamWState, tokens, labels, image_embeds=None):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, tokens, labels, image_embeds,
                              remat=remat),
            has_aux=True)(params)
        params, opt, om = adamw_update(opt_cfg, grads, opt, params)
        metrics = {"loss": loss, **parts, **om}
        return params, opt, metrics

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


@dataclass
class TrainResult:
    losses: list
    steps: int
    wall_s: float


def train(cfg: ModelConfig, *, steps: int, batch: int, seq_len: int,
          opt_cfg: Optional[AdamWConfig] = None, seed: int = 0,
          log_every: int = 10, params=None,
          log_fn=print) -> tuple[dict, TrainResult]:
    """Single-host training loop over the synthetic packed dataset."""
    from repro.training.data import DataConfig, PackedDataset
    opt_cfg = opt_cfg or AdamWConfig(total_steps=steps,
                                     warmup_steps=max(steps // 20, 5))
    params = params if params is not None else init_params(
        cfg, jax.random.PRNGKey(seed))
    opt = adamw_init(params, opt_cfg)
    ds = PackedDataset(DataConfig(cfg.vocab_size, seq_len, batch, seed))
    step_fn = make_train_step(cfg, opt_cfg)
    losses = []
    t0 = time.time()
    for i in range(steps):
        tokens, labels = ds.batch(i)
        params, opt, m = step_fn(params, opt, jnp.asarray(tokens),
                                 jnp.asarray(labels))
        losses.append(float(m["loss"]))
        if log_every and (i % log_every == 0 or i == steps - 1):
            log_fn(f"step {i:5d} loss={losses[-1]:.4f} "
                   f"lr={float(m['lr']):.2e} gnorm={float(m['grad_norm']):.3f}")
    return params, TrainResult(losses, steps, time.time() - t0)
