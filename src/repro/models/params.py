"""Declarative parameter/state system.

Each layer kind declares its parameters once as a tree of ``Leaf`` records
(shape + logical sharding axes + init tag).  From that single declaration we
derive:

  * ``init_params``  — real arrays (seeded, per-path RNG folding)
  * ``abstract_params`` — ShapeDtypeStructs (dry-run: no allocation)
  * ``param_axes``   — logical-axis tuples per leaf (-> PartitionSpecs)

and the same for decode/prefill state.  Per-layer weights inside the repeated
block pattern are STACKED with a leading ``num_blocks`` dim so the forward
pass can ``jax.lax.scan`` over depth (keeps HLO O(1) in num_layers — required
for the 61-layer / 1T-param dry-runs).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig

Tree = dict


@dataclass(frozen=True)
class Leaf:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "fanin"          # fanin | zeros | ones | embed | const:<v> | alog | decay
    dtype: Optional[str] = None  # None -> cfg.param_dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


# ---------------------------------------------------------------------------
# Layer declarations
# ---------------------------------------------------------------------------

def _attn_leaves(cfg: ModelConfig, cross: bool = False) -> Tree:
    d, dh = cfg.d_model, cfg.head_dim_
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    t: Tree = {"ln1": Leaf((d,), (None,), "ones")}
    if cfg.kv_lora_rank and not cross:
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        t["wq"] = Leaf((d, nq * qk), ("embed", "heads"))
        t["w_dkv"] = Leaf((d, cfg.kv_lora_rank + cfg.qk_rope_dim),
                          ("embed", "kv_lora"))
        t["kv_norm"] = Leaf((cfg.kv_lora_rank,), ("kv_lora",), "ones")
        t["w_uk"] = Leaf((cfg.kv_lora_rank, nq * cfg.qk_nope_dim),
                         ("kv_lora", "heads"))
        t["w_uv"] = Leaf((cfg.kv_lora_rank, nq * cfg.v_head_dim),
                         ("kv_lora", "heads"))
        t["wo"] = Leaf((nq * cfg.v_head_dim, d), ("heads", "embed"))
    else:
        t["wq"] = Leaf((d, nq * dh), ("embed", "heads"))
        t["wk"] = Leaf((d, nkv * dh), ("embed", "kv_heads"))
        t["wv"] = Leaf((d, nkv * dh), ("embed", "kv_heads"))
        t["wo"] = Leaf((nq * dh, d), ("heads", "embed"))
        if cfg.qkv_bias:
            t["bq"] = Leaf((nq * dh,), ("heads",), "zeros")
            t["bk"] = Leaf((nkv * dh,), ("kv_heads",), "zeros")
            t["bv"] = Leaf((nkv * dh,), ("kv_heads",), "zeros")
    if cross:
        t["gate"] = Leaf((), (), "zeros")
        t["q_norm"] = Leaf((dh,), (None,), "ones")
        t["k_norm"] = Leaf((dh,), (None,), "ones")
    if cfg.post_norms:
        t["ln1_post"] = Leaf((d,), (None,), "ones")
    return t


def _dense_ffn_leaves(cfg: ModelConfig) -> Tree:
    d, f = cfg.d_model, cfg.d_ff
    t: Tree = {
        "ln2": Leaf((d,), (None,), "ones"),
        "w_gate": Leaf((d, f), ("embed", "ff")),
        "w_up": Leaf((d, f), ("embed", "ff")),
        "w_down": Leaf((f, d), ("ff", "embed")),
    }
    if cfg.post_norms:
        t["ln2_post"] = Leaf((d,), (None,), "ones")
    return t


def _moe_ffn_leaves(cfg: ModelConfig) -> Tree:
    d, m = cfg.d_model, cfg.moe
    e, f = m.num_experts, m.d_ff_expert
    # expert weights FSDP over their d_ff dim ("expert_ff"), NOT d_model:
    # resharding then gathers one layer's experts at the shard_map boundary
    # instead of the whole scanned stack (EXPERIMENTS.md §Perf, kimi-k2).
    t: Tree = {
        "ln2": Leaf((d,), (None,), "ones"),
        "router": Leaf((d, e), ("embed", None)),
        "we_gate": Leaf((e, d, f), ("experts", None, "expert_ff")),
        "we_up": Leaf((e, d, f), ("experts", None, "expert_ff")),
        "we_down": Leaf((e, f, d), ("experts", "expert_ff", None)),
    }
    if m.num_shared:
        fs = m.num_shared * f
        t["ws_gate"] = Leaf((d, fs), ("embed", "ff"))
        t["ws_up"] = Leaf((d, fs), ("embed", "ff"))
        t["ws_down"] = Leaf((fs, d), ("ff", "embed"))
    if cfg.post_norms:
        t["ln2_post"] = Leaf((d,), (None,), "ones")
    return t


def _mamba_leaves(cfg: ModelConfig) -> Tree:
    d, mc = cfg.d_model, cfg.mamba
    di = mc.expand * d
    dtr = mc.dt_rank or -(-d // 16)
    return {
        "ln1": Leaf((d,), (None,), "ones"),
        "in_proj": Leaf((d, 2 * di), ("embed", "ff")),
        "conv_w": Leaf((mc.d_conv, di), (None, "ff")),
        "conv_b": Leaf((di,), ("ff",), "zeros"),
        "x_proj": Leaf((di, dtr + 2 * mc.d_state), ("ff", None)),
        "dt_w": Leaf((dtr, di), (None, "ff")),
        "dt_b": Leaf((di,), ("ff",), "const:-4.6", "float32"),
        "A_log": Leaf((di, mc.d_state), ("ff", None), "alog", "float32"),
        "D": Leaf((di,), ("ff",), "ones", "float32"),
        "out_proj": Leaf((di, d), ("ff", "embed")),
    }


_RWKV_LORA = 32
_RWKV_DECAY_LORA = 64


def _rwkv_tm_leaves(cfg: ModelConfig) -> Tree:
    d = cfg.d_model
    t: Tree = {"ln1": Leaf((d,), (None,), "ones")}
    for n in ("x", "w", "k", "v", "r", "g"):
        t[f"mu_{n}"] = Leaf((d,), (None,), "const:0.5")
    t["lora_A"] = Leaf((d, 5 * _RWKV_LORA), ("embed", None))
    t["lora_B"] = Leaf((5, _RWKV_LORA, d), (None, None, "embed"), "zeros")
    t["w0"] = Leaf((d,), (None,), "decay", "float32")
    t["decay_A"] = Leaf((d, _RWKV_DECAY_LORA), ("embed", None))
    t["decay_B"] = Leaf((_RWKV_DECAY_LORA, d), (None, "embed"), "zeros")
    t["u"] = Leaf((d,), (None,), "const:0.5", "float32")
    for n in ("wr", "wk", "wv", "wg"):
        t[n] = Leaf((d, d), ("embed", "heads"))
    t["wo"] = Leaf((d, d), ("heads", "embed"))
    t["lnx_g"] = Leaf((d,), (None,), "ones")
    t["lnx_b"] = Leaf((d,), (None,), "zeros")
    return t


def _rwkv_cm_leaves(cfg: ModelConfig) -> Tree:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "ln2": Leaf((d,), (None,), "ones"),
        "mu_ck": Leaf((d,), (None,), "const:0.5"),
        "mu_cr": Leaf((d,), (None,), "const:0.5"),
        "wk_cm": Leaf((d, f), ("embed", "ff")),
        "wv_cm": Leaf((f, d), ("ff", "embed")),
        "wr_cm": Leaf((d, d), ("embed", None)),
    }


def layer_leaves(cfg: ModelConfig, spec: LayerSpec) -> Tree:
    mixer = {
        "attn": lambda: _attn_leaves(cfg),
        "local_attn": lambda: _attn_leaves(cfg),
        "cross_attn": lambda: _attn_leaves(cfg, cross=True),
        "mamba": lambda: _mamba_leaves(cfg),
        "rwkv": lambda: _rwkv_tm_leaves(cfg),
    }[spec.mixer]()
    ffn = {
        "dense": lambda: _dense_ffn_leaves(cfg),
        "moe": lambda: _moe_ffn_leaves(cfg),
        "rwkv_cm": lambda: _rwkv_cm_leaves(cfg),
    }[spec.ffn]()
    return {**mixer, **ffn}


def model_leaves(cfg: ModelConfig) -> Tree:
    d, v = cfg.d_model, cfg.vocab_size
    t: Tree = {
        "embed": Leaf((v, d), ("vocab", "embed"), "embed"),
        "final_norm": Leaf((d,), (None,), "ones"),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = Leaf((d, v), ("embed", "vocab"))
    t["prefix"] = {
        f"l{i}": layer_leaves(cfg, LayerSpec())
        for i in range(cfg.first_k_dense)
    }
    # block leaves get a leading (num_blocks,) stacking dim
    block = {f"p{i}": layer_leaves(cfg, s)
             for i, s in enumerate(cfg.block_pattern)}
    t["blocks"] = jax.tree.map(
        lambda lf: Leaf((cfg.num_blocks, *lf.shape), (None, *lf.axes),
                        lf.init, lf.dtype),
        block, is_leaf=lambda x: isinstance(x, Leaf))
    return t


# ---------------------------------------------------------------------------
# Decode/prefill state declarations
# ---------------------------------------------------------------------------

def layer_state_leaves(cfg: ModelConfig, spec: LayerSpec, batch: int,
                       max_len: int) -> Tree:
    dh = cfg.head_dim_
    nkv = cfg.num_kv_heads
    cdt = cfg.dtype
    if spec.mixer in ("attn", "local_attn"):
        if cfg.kv_lora_rank:
            return {
                "c_kv": Leaf((batch, max_len, cfg.kv_lora_rank),
                             ("batch", "ctx", "kv_lora"), "zeros", cdt),
                "k_rope": Leaf((batch, max_len, cfg.qk_rope_dim),
                               ("batch", "ctx", None), "zeros", cdt),
            }
        kv_dt = cfg.kv_cache_dtype or cdt
        t = {
            "k": Leaf((batch, max_len, nkv, dh),
                      ("batch", "ctx", "kv_heads", None), "zeros", kv_dt),
            "v": Leaf((batch, max_len, nkv, dh),
                      ("batch", "ctx", "kv_heads", None), "zeros", kv_dt),
        }
        if kv_dt == "int8":
            t["k_scale"] = Leaf((batch, max_len, nkv),
                                ("batch", "ctx", "kv_heads"), "zeros",
                                "float32")
            t["v_scale"] = Leaf((batch, max_len, nkv),
                                ("batch", "ctx", "kv_heads"), "zeros",
                                "float32")
        return t
    if spec.mixer == "cross_attn":
        n = cfg.num_vision_tokens
        return {
            "xk": Leaf((batch, n, nkv, dh),
                       ("batch", None, "kv_heads", None), "zeros", cdt),
            "xv": Leaf((batch, n, nkv, dh),
                       ("batch", None, "kv_heads", None), "zeros", cdt),
        }
    if spec.mixer == "mamba":
        mc = cfg.mamba
        di = mc.expand * cfg.d_model
        return {
            "ssm": Leaf((batch, di, mc.d_state),
                        ("batch", "ff", None), "zeros", "float32"),
            "conv": Leaf((batch, mc.d_conv - 1, di),
                         ("batch", None, "ff"), "zeros", cdt),
        }
    if spec.mixer == "rwkv":
        h = cfg.d_model // cfg.rwkv_head_dim
        return {
            "wkv": Leaf((batch, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                        ("batch", "heads", None, None), "zeros", "float32"),
            "shift_t": Leaf((batch, cfg.d_model),
                            ("batch", "embed"), "zeros", cdt),
            "shift_c": Leaf((batch, cfg.d_model),
                            ("batch", "embed"), "zeros", cdt),
        }
    raise ValueError(spec.mixer)


def state_leaves(cfg: ModelConfig, batch: int, max_len: int) -> Tree:
    t: Tree = {
        "prefix": {f"l{i}": layer_state_leaves(cfg, LayerSpec(), batch, max_len)
                   for i in range(cfg.first_k_dense)},
    }
    block = {f"p{i}": layer_state_leaves(cfg, s, batch, max_len)
             for i, s in enumerate(cfg.block_pattern)}
    t["blocks"] = jax.tree.map(
        lambda lf: Leaf((cfg.num_blocks, *lf.shape), (None, *lf.axes),
                        lf.init, lf.dtype),
        block, is_leaf=lambda x: isinstance(x, Leaf))
    return t


# ---------------------------------------------------------------------------
# Materializers
# ---------------------------------------------------------------------------

def _is_leaf(x):
    return isinstance(x, Leaf)


def _init_array(leaf: Leaf, key, dtype) -> jax.Array:
    shape = leaf.shape
    if leaf.init == "zeros":
        return jnp.zeros(shape, dtype)
    if leaf.init == "ones":
        return jnp.ones(shape, dtype)
    if leaf.init.startswith("const:"):
        return jnp.full(shape, float(leaf.init[6:]), dtype)
    if leaf.init == "alog":
        ds = shape[-1]
        a = jnp.log(jnp.arange(1, ds + 1, dtype=jnp.float32))
        return jnp.broadcast_to(a, shape).astype(dtype)
    if leaf.init == "decay":
        d = shape[-1]
        w0 = -6.0 + 5.0 * (jnp.arange(d, dtype=jnp.float32) / max(d - 1, 1))
        return jnp.broadcast_to(w0, shape).astype(dtype)
    if leaf.init == "embed":
        return (0.02 * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    # fanin
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (std * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def _fold_path(key, path) -> jax.Array:
    import zlib
    h = 0
    for p in path:
        name = getattr(p, "key", getattr(p, "idx", str(p)))
        # zlib.crc32 is process-stable (python hash() is salted per run!)
        h = (h * 1000003 + zlib.crc32(str(name).encode())) % (2 ** 31 - 1)
    return jax.random.fold_in(key, h)


def materialize(tree: Tree, cfg: ModelConfig, key=None, abstract=False):
    """Leaf tree -> arrays (key given) or ShapeDtypeStructs (abstract)."""
    def mk(path, leaf: Leaf):
        dtype = jnp.dtype(leaf.dtype or cfg.param_dtype)
        if abstract:
            return jax.ShapeDtypeStruct(leaf.shape, dtype)
        return _init_array(leaf, _fold_path(key, path), dtype)

    return jax.tree_util.tree_map_with_path(mk, tree, is_leaf=_is_leaf)


def axes_of(tree: Tree):
    """Leaf tree -> logical-axis tuples (same structure)."""
    return jax.tree.map(lambda lf: lf.axes, tree, is_leaf=_is_leaf)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Tree:
    return materialize(model_leaves(cfg), cfg, key=key)


def abstract_params(cfg: ModelConfig) -> Tree:
    return materialize(model_leaves(cfg), cfg, abstract=True)


def param_axes(cfg: ModelConfig) -> Tree:
    return axes_of(model_leaves(cfg))


def init_state(cfg: ModelConfig, batch: int, max_len: int) -> Tree:
    return materialize(state_leaves(cfg, batch, max_len), cfg,
                       key=jax.random.PRNGKey(0))


def abstract_state(cfg: ModelConfig, batch: int, max_len: int) -> Tree:
    return materialize(state_leaves(cfg, batch, max_len), cfg, abstract=True)


def state_axes(cfg: ModelConfig, batch: int = 1, max_len: int = 8) -> Tree:
    return axes_of(state_leaves(cfg, batch, max_len))


def count_params(params: Tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
