"""Composable decoder-only transformer over the layer zoo.

Depth is executed as ``first_k_dense`` unrolled prefix layers followed by a
``jax.lax.scan`` over ``num_blocks`` repeats of the block pattern (HLO stays
O(block) in size — required for 61-layer/1T-param abstract lowering).

Public API:
    init_params / abstract_params / param_axes        (re-exported)
    init_state / abstract_state / state_axes          (re-exported)
    forward_train(cfg, params, tokens, ...) -> (logits, aux_loss)
    prefill(cfg, params, state, tokens, lengths, ...) -> (last_logits, state)
    decode_step(cfg, params, state, last_tokens, cur_lens, ...) -> (logits, state)
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import ops
from repro.models.ops import ApplyCtx
from repro.models.params import (  # noqa: F401  (re-exports)
    abstract_params, abstract_state, count_params, init_params, init_state,
    param_axes, state_axes,
)
from repro.sharding import shard


def _apply_layer(cfg: ModelConfig, spec: LayerSpec, p, x, state,
                 ctx: ApplyCtx):
    """Residual layer = mixer + ffn. Returns (x, new_state, aux)."""
    window = cfg.sliding_window if spec.mixer == "local_attn" else 0
    lctx = ApplyCtx(mode=ctx.mode, positions=ctx.positions,
                    lengths=ctx.lengths, image_embeds=ctx.image_embeds,
                    window=window)
    if spec.mixer in ("attn", "local_attn"):
        out, state = ops.apply_attn(cfg, p, x, state, lctx)
    elif spec.mixer == "cross_attn":
        out, state = ops.apply_cross_attn(cfg, p, x, state, lctx)
    elif spec.mixer == "mamba":
        out, state = ops.apply_mamba(cfg, p, x, state, lctx)
    elif spec.mixer == "rwkv":
        out, state = ops.apply_rwkv_tm(cfg, p, x, state, lctx)
    else:
        raise ValueError(spec.mixer)
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn == "dense":
        out, aux = ops.apply_dense_ffn(cfg, p, x)
    elif spec.ffn == "moe":
        out, aux = ops.apply_moe_ffn(cfg, p, x)
    elif spec.ffn == "rwkv_cm":
        out, state = ops.apply_rwkv_cm(cfg, p, x, state, lctx)
    else:
        raise ValueError(spec.ffn)
    x = shard(x + out, "batch", None, "embed")
    return x, state, aux


def _embed(cfg: ModelConfig, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embeds:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return shard(x.astype(jnp.dtype(cfg.dtype)), "batch", None, "embed")


def _unembed(cfg: ModelConfig, params, x):
    x = ops.rmsnorm(x, params["final_norm"], cfg.norm_plus_one)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    logits = ops.softcap(logits, cfg.logit_softcap)
    return shard(logits, "batch", None, "vocab")


def _backbone(cfg: ModelConfig, params, x, state, ctx: ApplyCtx):
    """Prefix layers + scanned blocks. Returns (x, new_state, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_prefix = {}
    for i in range(cfg.first_k_dense):
        st = None if state is None else state["prefix"][f"l{i}"]
        x, st, a = _apply_layer(cfg, LayerSpec(), params["prefix"][f"l{i}"],
                                x, st, ctx)
        new_prefix[f"l{i}"] = st
        aux = aux + a

    pattern = cfg.block_pattern

    if state is None:
        def body(carry, bp):
            h, acc = carry
            for i, spec in enumerate(pattern):
                h, _, a = _apply_layer(cfg, spec, bp[f"p{i}"], h, None, ctx)
                acc = acc + a
            return (h, acc), None

        if ctx.remat:
            # activation checkpointing: recompute each block in the bwd
            # pass instead of saving attention/FFN intermediates — required
            # for the full configs to fit HBM (see EXPERIMENTS.md §Perf)
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"])
        return x, None, aux

    def body_s(carry, xs):
        h, acc = carry
        bp, bs = xs
        new_bs = {}
        for i, spec in enumerate(pattern):
            h, st, a = _apply_layer(cfg, spec, bp[f"p{i}"], h,
                                    bs[f"p{i}"], ctx)
            new_bs[f"p{i}"] = st
            acc = acc + a
        return (h, acc), new_bs

    (x, aux), new_blocks = jax.lax.scan(
        body_s, (x, aux), (params["blocks"], state["blocks"]))
    return x, {"prefix": new_prefix, "blocks": new_blocks}, aux


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def forward_train(cfg: ModelConfig, params, tokens,
                  image_embeds: Optional[jax.Array] = None,
                  lengths: Optional[jax.Array] = None,
                  remat: bool = False):
    """Full-sequence causal forward. Returns (logits (B,S,V) f32, aux)."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    ctx = ApplyCtx(mode="train", positions=positions, lengths=lengths,
                   image_embeds=image_embeds, remat=remat)
    x = _embed(cfg, params, tokens)
    x, _, aux = _backbone(cfg, params, x, None, ctx)
    return _unembed(cfg, params, x), aux


def prefill(cfg: ModelConfig, params, state, tokens, lengths,
            image_embeds: Optional[jax.Array] = None,
            start: Optional[jax.Array] = None):
    """Prompt processing; fills `state` at offset `start` (default 0).

    `lengths` is the ABSOLUTE valid length (start + valid tokens in this
    chunk) — chunked prefill passes consecutive windows with increasing
    `start`.  Returns (last_token_logits (B,V), new_state)."""
    B, S = tokens.shape
    if start is None:
        start = jnp.zeros((B,), jnp.int32)
    positions = start[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
    ctx = ApplyCtx(mode="prefill", positions=positions, lengths=lengths,
                   image_embeds=image_embeds)
    x = _embed(cfg, params, tokens)
    x, new_state, _ = _backbone(cfg, params, x, state, ctx)
    # unembed ONLY the last valid position: the (B,S,V) logits tensor for a
    # 32k prompt x 256k vocab would dwarf the rest of the step
    # (EXPERIMENTS.md §Perf, gemma2 prefill)
    idx = jnp.clip(lengths - start - 1, 0, S - 1)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    return _unembed(cfg, params, x_last)[:, 0], new_state


def decode_step(cfg: ModelConfig, params, state, last_tokens, cur_lens):
    """One autoregressive step against the cache.

    last_tokens: (B,) int32; cur_lens: (B,) tokens already cached.
    Returns (logits (B,V), new_state)."""
    B = last_tokens.shape[0]
    positions = cur_lens.astype(jnp.int32)[:, None]        # (B,1)
    ctx = ApplyCtx(mode="decode", positions=positions)
    x = _embed(cfg, params, last_tokens[:, None])
    x, new_state, _ = _backbone(cfg, params, x, state, ctx)
    return _unembed(cfg, params, x)[:, 0], new_state


def greedy_generate(cfg: ModelConfig, params, tokens, lengths, max_new: int,
                    image_embeds: Optional[jax.Array] = None):
    """Reference generation loop (tests / examples)."""
    B, S = tokens.shape
    state = init_state(cfg, B, S + max_new)
    logits, state = prefill(cfg, params, state, tokens, lengths, image_embeds)
    out = []
    cur = lengths.astype(jnp.int32)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(max_new):
        out.append(tok)
        logits, state = decode_step(cfg, params, state, tok, cur)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        cur = cur + 1
    return jnp.stack(out, axis=1)
