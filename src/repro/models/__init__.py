from repro.models.transformer import (  # noqa: F401
    abstract_params, abstract_state, count_params, decode_step, forward_train,
    greedy_generate, init_params, init_state, param_axes, prefill, state_axes,
)
