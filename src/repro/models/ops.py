"""Layer math for every mixer / FFN kind.

All mixers share one signature::

    apply_<kind>(cfg, p, x, state, ctx) -> (out, new_state)

with ``x: (B, S, d)`` (S=1 for decode), ``state`` a dict (or None in train
mode) and ``ctx`` carrying positions / lengths / mode.  FFNs return
``(out, aux_loss)``.  Accumulations are f32; activations run in cfg.dtype.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.params import _RWKV_LORA  # lora width shared with decls
from repro.sharding import (compat_shard_map, current_mesh, current_rules,
                            shard)

NEG_INF = -2.0 ** 30


@dataclass
class ApplyCtx:
    mode: str                      # "train" | "prefill" | "decode"
    positions: jax.Array           # (B, S) int32 — absolute token positions
    lengths: Optional[jax.Array] = None    # (B,) valid prompt lengths
    image_embeds: Optional[jax.Array] = None
    window: int = 0                # sliding window for local_attn layers
    remat: bool = False            # checkpoint each scanned block (train)


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------

def rmsnorm(x, w, plus_one: bool = False, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (xf * scale).astype(dt)


def _rope_tables(positions, dim, theta):
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta):
    """x: (B, S, H, Dh) — llama-style rotate-half RoPE."""
    cos, sin = _rope_tables(positions, x.shape[-1], theta)
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap) if cap else x


def _act(x, kind: str):
    return jax.nn.gelu(x) if kind == "gelu" else jax.nn.silu(x)


def _quant_kv(x):
    """(B,S,H,D) -> (int8 values, f32 per-(token,head) scales)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _update_cache(cache, new, idx):
    """cache: (B, L, ...), new: (B, S, ...), idx: (B,) write offsets."""
    def upd(c, u, i):
        start = (i,) + (0,) * (c.ndim - 1)
        return jax.lax.dynamic_update_slice(c, u.astype(c.dtype), start)
    return jax.vmap(upd)(cache, new, idx)


def _sdpa(q, k, v, mask, scale, cap: float = 0.0, merged: bool = True):
    """q: (B,S,Hq,Dh) k,v: (B,L,Hkv,Dv') mask: (B,1,1,S,L) bool.

    merged=True (training, no cache): GQA is computed with KV heads
    broadcast up to the merged Hq head dim: the (B,H,S,L) score/
    probability tensors then shard cleanly as ("batch", "heads") even when
    Hkv < model-axis size.  With the earlier grouped (B,Hkv,G,S,L) layout
    GSPMD hit 'involuntary full rematerialization' and all-gathered
    multi-TB probability tensors in the backward pass (EXPERIMENTS.md
    §Perf, kimi-k2 iteration 2).

    merged=False (prefill/decode against a sequence-sharded cache): the
    grouped form keeps the cache layout undisturbed — broadcasting KV
    heads there forces a cache re-shard gather per layer (measured 20x
    regression on qwen25 prefill, §Perf)."""
    B, S, Hq, Dh = q.shape
    L, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    if not merged:
        qg = q.reshape(B, S, Hkv, G, Dh)
        scores = jnp.einsum("bskgd,blkd->bkgsl", qg, k,
                            preferred_element_type=jnp.float32) * scale
        scores = softcap(scores, cap)
        scores = jnp.where(mask.transpose(0, 2, 1, 3, 4) if mask.ndim == 5
                           else mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgsl,blkv->bskgv", probs.astype(v.dtype), v)
        return out.reshape(B, S, Hq, out.shape[-1])
    if G > 1:
        k = jnp.broadcast_to(k[:, :, :, None], (B, L, Hkv, G, Dh))
        k = k.reshape(B, L, Hq, Dh)
        vd = v.shape[-1]
        v = jnp.broadcast_to(v[:, :, :, None], (B, L, Hkv, G, vd))
        v = v.reshape(B, L, Hq, vd)
    scores = jnp.einsum("bshd,blhd->bhsl", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, cap)
    scores = jnp.where(mask[:, 0] if mask.ndim == 5 else mask,
                       scores, NEG_INF)
    scores = shard(scores, "batch", "heads", None, None)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = shard(probs, "batch", "heads", None, None)
    out = jnp.einsum("bhsl,blhv->bshv", probs.astype(v.dtype), v)
    return shard(out, "batch", None, "heads", None)


def _kernels():
    """Deferred import: Pallas kernels are optional at model-exec time."""
    from repro.kernels import ops as kops
    return kops


def _heads_shardable(n_heads: int) -> bool:
    """True iff `n_heads` divides the model-axis extent the "heads" rule
    maps to — the precondition for the merged-head attention layout
    (e.g. qwen-2.5's 40 heads do NOT divide a 16-way axis; the merged
    layout would replicate multi-GB score tensors, §Perf)."""
    mesh = current_mesh()
    rules = current_rules()
    if mesh is None or rules is None:
        return True
    n = 1
    for a in rules.get("heads", ()):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n <= 1 or n_heads % n == 0


def _pallas_attn(cfg: ModelConfig, q, kc, vc, ctx: ApplyCtx, scale):
    """Route attention through the Pallas kernels (REPRO_USE_PALLAS=1).

    train/prefill -> chunked_prefill_attention (offset = chunk start);
    decode        -> flash-decode."""
    kops = _kernels()
    B, S = q.shape[0], q.shape[1]
    if ctx.mode == "decode":
        return kops.decode_attention_op(
            q[:, 0], kc, vc, ctx.positions[:, 0],
            window=ctx.window, softcap=float(cfg.attn_softcap),
            scale=scale)[:, None]
    offset = ctx.positions[:, 0]
    if ctx.lengths is not None:
        lengths = ctx.lengths
    else:
        lengths = jnp.full((B,), kc.shape[1], jnp.int32)
    return kops.prefill_attention(
        q, kc, vc, offset, lengths, window=ctx.window,
        softcap=float(cfg.attn_softcap), scale=scale)


def _causal_mask(ctx: ApplyCtx, q_pos, k_pos, k_len=None, window: int = 0):
    """(B, 1, 1, S, L) boolean mask."""
    m = k_pos[:, None, :] <= q_pos[:, :, None]           # (B, S, L)
    if window:
        m &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    if k_len is not None:
        m &= k_pos[:, None, :] < k_len[:, None, None]
    return m[:, None, None]


# ---------------------------------------------------------------------------
# Attention (GQA / MQA, local, softcap, bias) + KV cache
# ---------------------------------------------------------------------------

def apply_attn(cfg: ModelConfig, p, x, state, ctx: ApplyCtx):
    if cfg.kv_lora_rank:
        return _apply_mla(cfg, p, x, state, ctx)
    B, S, d = x.shape
    dh, nq, nkv = cfg.head_dim_, cfg.num_heads, cfg.num_kv_heads
    h = rmsnorm(x, p["ln1"], cfg.norm_plus_one)
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, nq, dh)
    k = k.reshape(B, S, nkv, dh)
    v = v.reshape(B, S, nkv, dh)
    q = shard(apply_rope(q, ctx.positions, cfg.rope_theta),
              "batch", None, "heads", None)
    k = apply_rope(k, ctx.positions, cfg.rope_theta)
    scale = cfg.query_scale or dh ** -0.5

    new_state = state
    if ctx.mode == "train":
        k_pos = ctx.positions
        mask = _causal_mask(ctx, ctx.positions, k_pos,
                            ctx.lengths, ctx.window)
        kc, vc = k, v
    else:
        # write offset = absolute position of the first new token
        # (0 for whole-prompt prefill, chunk start for chunked prefill,
        #  cur_len for decode)
        write_idx = ctx.positions[:, 0]
        # reshard the new K/V to the CACHE layout before the in-place
        # update: without this GSPMD falls back to "involuntary full
        # rematerialization" (a whole-cache f32 all-gather per layer,
        # 722 GB/chip on gemma2 prefill — EXPERIMENTS.md §Perf)
        k = shard(k, "batch", "ctx", "kv_heads", None)
        v = shard(v, "batch", "ctx", "kv_heads", None)
        if cfg.kv_cache_dtype == "int8":
            # quantized KV cache: per-(token, head) absmax scales
            kq, ks = _quant_kv(k)
            vq, vs = _quant_kv(v)
            kcq = _update_cache(state["k"], kq, write_idx)
            vcq = _update_cache(state["v"], vq, write_idx)
            kss = _update_cache(state["k_scale"], ks, write_idx)
            vss = _update_cache(state["v_scale"], vs, write_idx)
            new_state = {**state, "k": kcq, "v": vcq,
                         "k_scale": kss, "v_scale": vss}
            kc = (kcq.astype(x.dtype)
                  * kss[..., None].astype(x.dtype))
            vc = (vcq.astype(x.dtype)
                  * vss[..., None].astype(x.dtype))
        else:
            kc = _update_cache(state["k"], k, write_idx)
            vc = _update_cache(state["v"], v, write_idx)
            new_state = {**state, "k": kc, "v": vc}
        L = kc.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
        mask = _causal_mask(ctx, ctx.positions, k_pos,
                            ctx.lengths, ctx.window)
    kc = shard(kc, "batch", "ctx", "kv_heads", None)
    vc = shard(vc, "batch", "ctx", "kv_heads", None)
    if _kernels().use_pallas():
        out = _pallas_attn(cfg, q, kc, vc, ctx, scale)
    else:
        out = _sdpa(q, kc, vc, mask, scale, cfg.attn_softcap,
                    merged=(ctx.mode == "train" and _heads_shardable(nq)))
    out = out.reshape(B, S, nq * dh) @ p["wo"]
    if cfg.post_norms:
        out = rmsnorm(out, p["ln1_post"], cfg.norm_plus_one)
    return out.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# MLA (DeepSeek latent attention) — naive expand for train/prefill,
# weight-absorbed scoring for decode (production path).
# ---------------------------------------------------------------------------

def _apply_mla(cfg: ModelConfig, p, x, state, ctx: ApplyCtx):
    B, S, d = x.shape
    nq = cfg.num_heads
    nope, rope, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    lora = cfg.kv_lora_rank
    h = rmsnorm(x, p["ln1"], cfg.norm_plus_one)
    q = (h @ p["wq"]).reshape(B, S, nq, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, ctx.positions, cfg.rope_theta)
    ckr = h @ p["w_dkv"]                                  # (B,S,lora+rope)
    c_kv = rmsnorm(ckr[..., :lora], p["kv_norm"])
    k_rope = apply_rope(ckr[..., None, lora:], ctx.positions,
                        cfg.rope_theta)[:, :, 0]          # (B,S,rope)
    scale = (nope + rope) ** -0.5

    new_state = state
    if ctx.mode == "train":
        cc, kr = c_kv, k_rope
        k_pos = ctx.positions
    else:
        write_idx = ctx.positions[:, 0]
        cc = _update_cache(state["c_kv"], c_kv, write_idx)
        kr = _update_cache(state["k_rope"], k_rope, write_idx)
        new_state = {**state, "c_kv": cc, "k_rope": kr}
        L = cc.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    mask = _causal_mask(ctx, ctx.positions, k_pos, ctx.lengths)[:, 0, 0]
    cc = shard(cc, "batch", "ctx", "kv_lora")

    w_uk = p["w_uk"].reshape(lora, nq, nope)
    if ctx.mode == "decode":
        # absorbed: score against the latent cache directly
        q_abs = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        scores = (jnp.einsum("bshr,blr->bhsl", q_abs,
                             cc.astype(jnp.float32))
                  + jnp.einsum("bshr,blr->bhsl", q_rope.astype(jnp.float32),
                               kr.astype(jnp.float32))) * scale
        scores = jnp.where(mask[:, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhsl,blr->bshr", probs, cc.astype(jnp.float32))
        w_uv = p["w_uv"].reshape(lora, nq, vdim)
        out = jnp.einsum("bshr,rhv->bshv", o_lat, w_uv.astype(jnp.float32))
        out = out.astype(x.dtype)
    else:
        k_nope = jnp.einsum("blr,rhn->blhn", cc, w_uk.astype(cc.dtype))
        v = jnp.einsum("blr,rhv->blhv", cc,
                       p["w_uv"].reshape(lora, nq, vdim).astype(cc.dtype))
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr[:, :, None, :],
                                      (*kr.shape[:2], nq, rope))], -1)
        q_full = jnp.concatenate([q_nope, q_rope], -1)
        out = _sdpa(q_full, k_full, v, mask[:, None, None], scale,
                    merged=(ctx.mode == "train" and _heads_shardable(nq)))
    out = out.reshape(B, S, nq * vdim) @ p["wo"]
    return out.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Cross attention (VLM image layers)
# ---------------------------------------------------------------------------

def apply_cross_attn(cfg: ModelConfig, p, x, state, ctx: ApplyCtx):
    B, S, d = x.shape
    dh, nq, nkv = cfg.head_dim_, cfg.num_heads, cfg.num_kv_heads
    h = rmsnorm(x, p["ln1"], cfg.norm_plus_one)
    q = (h @ p["wq"]).reshape(B, S, nq, dh)
    q = rmsnorm(q, p["q_norm"])
    new_state = state
    if ctx.mode == "decode":
        k, v = state["xk"], state["xv"]
    else:
        assert ctx.image_embeds is not None, "vlm prefill needs image_embeds"
        ie = ctx.image_embeds.astype(x.dtype)
        k = (ie @ p["wk"]).reshape(B, -1, nkv, dh)
        v = (ie @ p["wv"]).reshape(B, -1, nkv, dh)
        k = rmsnorm(k, p["k_norm"])
        if state is not None:
            new_state = {**state, "xk": k.astype(state["xk"].dtype),
                         "xv": v.astype(state["xv"].dtype)}
    mask = jnp.ones((B, 1, 1, S, k.shape[1]), bool)
    out = _sdpa(q, k, v, mask, dh ** -0.5)
    out = out.reshape(B, S, nq * dh) @ p["wo"]
    out = jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) * out
    return out.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Dense gated FFN
# ---------------------------------------------------------------------------

def apply_dense_ffn(cfg: ModelConfig, p, x):
    h = rmsnorm(x, p["ln2"], cfg.norm_plus_one)
    g = _act(h @ p["w_gate"], cfg.act)
    u = h @ p["w_up"]
    out = shard(g * u, "batch", None, "ff") @ p["w_down"]
    if cfg.post_norms:
        out = rmsnorm(out, p["ln2_post"], cfg.norm_plus_one)
    return out.astype(x.dtype), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Mixture of Experts
#   * dense-masked path: every expert computed, mask-combined (CPU smoke /
#     tiny models / no mesh)
#   * expert-parallel path: shard_map over the "experts"->model mesh axis,
#     capacity-bounded scatter dispatch (GShard-style dropping), psum combine
# ---------------------------------------------------------------------------

def _router(cfg: ModelConfig, p, h):
    m = cfg.moe
    logits = (h.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)              # (..., E)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * sum(frac_tokens * frac_prob)
    T = probs.shape[0] * probs.shape[1] if probs.ndim == 3 else probs.shape[0]
    me = probs.mean(axis=tuple(range(probs.ndim - 1)))
    ce = jnp.zeros((m.num_experts,), jnp.float32)
    ce = ce.at[top_i.reshape(-1)].add(1.0) / max(T * m.top_k, 1)
    aux = m.router_aux_coef * m.num_experts * jnp.sum(me * ce)
    return top_p, top_i, aux


def _expert_ffn(cfg, we_gate, we_up, we_down, xe):
    """xe: (E, C, d) -> (E, C, d)."""
    g = _act(jnp.einsum("ecd,edf->ecf", xe, we_gate), cfg.act)
    u = jnp.einsum("ecd,edf->ecf", xe, we_up)
    return jnp.einsum("ecf,efd->ecd", g * u, we_down)


def _moe_dense_path(cfg: ModelConfig, p, h, top_p, top_i):
    m = cfg.moe
    B, S, d = h.shape
    x = h.reshape(B * S, d)
    gates = jnp.zeros((B * S, m.num_experts), h.dtype)
    gates = gates.at[jnp.arange(B * S)[:, None],
                     top_i.reshape(B * S, -1)].set(
        top_p.reshape(B * S, -1).astype(h.dtype))
    g = _act(jnp.einsum("td,edf->tef", x, p["we_gate"]), cfg.act)
    u = jnp.einsum("td,edf->tef", x, p["we_up"])
    ye = jnp.einsum("tef,efd->ted", g * u, p["we_down"])
    y = jnp.einsum("ted,te->td", ye, gates)
    return y.reshape(B, S, d)


def _moe_ep_path(cfg: ModelConfig, p, h, mesh, ep_axes):
    """Expert-parallel MoE under shard_map.

    Two data layouts:

    * S > 1 (train/prefill, token-heavy): tokens sharded over the batch
      axes and replicated over the expert axis; weights gathered to each
      expert shard (FSDP semantics).
    * S == 1 (decode, token-light): WEIGHT-STATIONARY 2D EP — weights stay
      sharded (experts x model, d_ff x expert_ff-axes) and the tiny token
      batch is replicated to them instead; partial outputs psum over both
      weight axes.  This removes the per-token re-gather of FSDP'd expert
      weights that made giant-MoE decode collective-bound
      (EXPERIMENTS.md §Perf, kimi-k2 decode).

    Each expert shard dispatches only the (token, k) pairs routed to its
    local experts into a capacity-bounded (E_local, C, d) buffer, runs its
    experts, gathers back and psums partial outputs.
    """
    m = cfg.moe
    rules = current_rules() or {}
    stationary = h.shape[1] == 1
    batch_axes = () if stationary else tuple(
        a for a in rules.get("batch", ()) if a in mesh.axis_names)
    ff_axes = tuple(a for a in rules.get("expert_ff", ())
                    if a in mesh.axis_names and a not in ep_axes) \
        if stationary else ()
    if ff_axes and m.d_ff_expert % _axes_size(mesh, ff_axes) != 0:
        ff_axes = ()
    n_ep = 1
    for a in ep_axes:
        n_ep *= mesh.shape[a]
    e_local = m.num_experts // n_ep

    def local_moe(h_l, router, we_gate, we_up, we_down):
        B, S, d = h_l.shape
        T = B * S
        x = h_l.reshape(T, d)
        top_p, top_i, aux = _router(cfg, {"router": router}, h_l)
        top_p = top_p.reshape(T, m.top_k)
        top_i = top_i.reshape(T, m.top_k)
        cap = int(max(8, T * m.top_k / m.num_experts * m.capacity_factor))
        ep_rank = jax.lax.axis_index(
            ep_axes[0] if len(ep_axes) == 1 else ep_axes)
        lo = ep_rank * e_local
        flat_e = top_i.reshape(-1) - lo                     # (T*k,)
        local = (flat_e >= 0) & (flat_e < e_local)
        flat_e = jnp.where(local, flat_e, 0)
        onehot = (jax.nn.one_hot(flat_e, e_local, dtype=jnp.int32)
                  * local[:, None].astype(jnp.int32))       # (T*k, El)
        pos = jnp.cumsum(onehot, axis=0) - onehot            # pos within expert
        pos_e = (pos * onehot).sum(-1)                       # (T*k,)
        keep = local & (pos_e < cap)
        tok = jnp.repeat(jnp.arange(T), m.top_k)
        buf = jnp.zeros((e_local, cap, d), x.dtype)
        buf = buf.at[jnp.where(keep, flat_e, 0),
                     jnp.where(keep, pos_e, cap - 1)].add(
            x[tok] * keep[:, None].astype(x.dtype),
            mode="drop")
        y_e = _expert_ffn(cfg, we_gate, we_up, we_down, buf)
        y_pairs = y_e[flat_e, jnp.minimum(pos_e, cap - 1)]   # (T*k, d)
        w = (top_p.reshape(-1) * keep).astype(x.dtype)
        y = jnp.zeros_like(x).at[tok].add(y_pairs * w[:, None])
        y = jax.lax.psum(y, ep_axes + ff_axes)
        # aux varies per data shard; average over every named axis so the
        # out_spec P() (fully replicated) is semantically true.
        aux = jax.lax.pmean(aux, ep_axes + ff_axes + batch_axes)
        return y.reshape(B, S, d), aux

    bspec = batch_axes if batch_axes else None
    in_specs = (P(bspec),
                P(), P(ep_axes, None, ff_axes or None),
                P(ep_axes, None, ff_axes or None),
                P(ep_axes, ff_axes or None, None))
    out_specs = (P(bspec), P())
    return compat_shard_map(
        local_moe, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )(h, p["router"], p["we_gate"], p["we_up"], p["we_down"])


def apply_moe_ffn(cfg: ModelConfig, p, x):
    m = cfg.moe
    h = rmsnorm(x, p["ln2"], cfg.norm_plus_one)
    mesh = current_mesh()
    rules = current_rules() or {}
    ep_axes = tuple(a for a in rules.get("experts", ())
                    if mesh is not None and a in mesh.axis_names)
    use_ep = (mesh is not None and ep_axes
              and m.num_experts % _axes_size(mesh, ep_axes) == 0
              and _axes_size(mesh, ep_axes) > 1)
    if use_ep:
        y, aux = _moe_ep_path(cfg, p, h, mesh, ep_axes)
    else:
        top_p, top_i, aux = _router(cfg, p, h)
        y = _moe_dense_path(cfg, p, h, top_p, top_i)
    if m.num_shared:
        g = _act(h @ p["ws_gate"], cfg.act)
        u = h @ p["ws_up"]
        y = y + (g * u) @ p["ws_down"]
    if cfg.post_norms:
        y = rmsnorm(y, p["ln2_post"], cfg.norm_plus_one)
    return y.astype(x.dtype), aux


def _axes_size(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# Mamba (selective SSM) — sequential scan + single-step
# ---------------------------------------------------------------------------

def _mamba_proj(cfg, p, h):
    mc = cfg.mamba
    di = mc.expand * cfg.d_model
    xz = h @ p["in_proj"]
    return xz[..., :di], xz[..., di:]


def _mamba_ssm_params(cfg, p, xc):
    """xc: (B, S, di) post-conv activations -> dt, Bm, Cm."""
    mc = cfg.mamba
    dtr = mc.dt_rank or -(-cfg.d_model // 16)
    x_dbl = xc @ p["x_proj"]
    dt = jax.nn.softplus(
        x_dbl[..., :dtr] @ p["dt_w"] + p["dt_b"].astype(jnp.float32))
    Bm = x_dbl[..., dtr:dtr + mc.d_state].astype(jnp.float32)
    Cm = x_dbl[..., dtr + mc.d_state:].astype(jnp.float32)
    return dt.astype(jnp.float32), Bm, Cm


def _mamba_conv_seq(p, x, conv_state):
    """Causal depthwise conv over time. x: (B,S,di); conv_state: (B,K-1,di)."""
    K = p["conv_w"].shape[0]
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i] for i in range(K))
    new_state = xp[:, xp.shape[1] - (K - 1):]
    return out + p["conv_b"], new_state


def apply_mamba(cfg: ModelConfig, p, x, state, ctx: ApplyCtx):
    B, S, d = x.shape
    mc = cfg.mamba
    di = mc.expand * d
    h = rmsnorm(x, p["ln1"], cfg.norm_plus_one)
    xi, z = _mamba_proj(cfg, p, h)
    xi = shard(xi, "batch", None, "ff")
    conv0 = (state["conv"] if state is not None
             else jnp.zeros((B, mc.d_conv - 1, di), x.dtype))
    ssm0 = (state["ssm"].astype(jnp.float32) if state is not None
            else jnp.zeros((B, di, mc.d_state), jnp.float32))
    xc, conv1 = _mamba_conv_seq(p, xi, conv0)
    xc = _act(xc, "silu")
    dt, Bm, Cm = _mamba_ssm_params(cfg, p, xc)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (di, ds)
    xcf = xc.astype(jnp.float32)
    if ctx.lengths is not None:
        # padded prefill: freeze the state past each row's valid length
        m = (ctx.positions < ctx.lengths[:, None]).astype(jnp.float32)
        dt = dt * m[:, :, None]
        xcf = xcf * m[:, :, None]
        # conv state must reflect the last K-1 *valid* inputs
        # (local chunk coordinates: absolute length minus chunk start)
        loc = jnp.clip(ctx.lengths - ctx.positions[:, 0], 0, S)
        xp_full = jnp.concatenate([conv0.astype(xi.dtype), xi], axis=1)
        conv1 = jax.vmap(
            lambda xp, ln: jax.lax.dynamic_slice(
                xp, (ln, 0), (mc.d_conv - 1, di)))(xp_full, loc)

    def step(hprev, t_in):
        dt_t, B_t, C_t, x_t = t_in                         # (B,di),(B,ds),(B,ds),(B,di)
        da = jnp.exp(dt_t[:, :, None] * A[None])           # (B,di,ds)
        hn = da * hprev + dt_t[:, :, None] * B_t[:, None, :] * x_t[:, :, None]
        y = jnp.einsum("bds,bs->bd", hn, C_t)
        return hn, y

    hT, ys = jax.lax.scan(
        step, ssm0,
        (dt.transpose(1, 0, 2), Bm.transpose(1, 0, 2),
         Cm.transpose(1, 0, 2), xcf.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2) + xcf * p["D"].astype(jnp.float32)
    y = (y.astype(x.dtype) * _act(z, "silu")) @ p["out_proj"]
    new_state = state
    if state is not None:
        new_state = {"ssm": hT.astype(state["ssm"].dtype),
                     "conv": conv1.astype(state["conv"].dtype)}
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# RWKV-6 (Finch): data-dependent decay time-mix + channel-mix
# ---------------------------------------------------------------------------

def _token_shift(x, shift_state):
    """x: (B,S,d); shift_state: (B,d) = last token of previous chunk."""
    prev = jnp.concatenate([shift_state[:, None], x[:, :-1]], axis=1)
    return prev - x


def rwkv_wkv_chunked(r, k, v, w, u, s0, chunk: int = 16):
    """Chunked WKV6: C-token chunks as dense matmuls (MXU-friendly) with a
    cross-chunk state carry — the jnp twin of kernels/wkv6.py, used for
    training/prefill where the token-by-token scan is HBM-bound
    (EXPERIMENTS.md §Perf, rwkv6-3b iteration 1).

    r,k,v,w: (B,S,H,K) f32; u: (H,K); s0: (B,H,K,K) f32."""
    B, S, H, K = r.shape
    pad = (-S) % chunk
    if pad:
        zeros = jnp.zeros((B, pad, H, K), r.dtype)
        r = jnp.concatenate([r, zeros], 1)
        k = jnp.concatenate([k, zeros], 1)
        v = jnp.concatenate([v, zeros], 1)
        w = jnp.concatenate([w, jnp.ones((B, pad, H, K), w.dtype)], 1)
    NC = (S + pad) // chunk
    resh = lambda x: x.reshape(B, NC, chunk, H, K).transpose(1, 0, 2, 3, 4)  # noqa: E731
    rs, ks, vs, ws = resh(r), resh(k), resh(v), resh(w)
    t_idx = jnp.arange(chunk)[:, None]
    s_idx = jnp.arange(chunk)[None, :]

    def body(s, xs):
        rc, kc, vc, wc = xs                           # (B,C,H,K)
        logw = jnp.log(jnp.maximum(wc, 1e-38))
        L = jnp.cumsum(logw, axis=1)
        L_prev = L - logw
        q_in = rc * jnp.exp(L_prev)
        k_out = kc * jnp.exp(-L)
        y = jnp.einsum("bchk,bhkv->bchv", q_in, s)
        scores = jnp.einsum("bthk,bshk->bhts", q_in, k_out)
        scores = jnp.where((s_idx < t_idx)[None, None], scores, 0.0)
        diag = jnp.sum(rc * u[None, None] * kc, axis=-1)   # (B,C,H)
        y += jnp.einsum("bhts,bshv->bthv", scores, vc)
        y += diag.transpose(0, 1, 2)[..., None] * vc
        L_C = L[:, -1:]                               # (B,1,H,K)
        k_carry = kc * jnp.exp(L_C - L)
        s = (jnp.exp(L_C[:, 0])[..., None] * s
             + jnp.einsum("bchk,bchv->bhkv", k_carry, vc))
        return s, y

    sT, ys = jax.lax.scan(body, s0.astype(jnp.float32), (rs, ks, vs, ws))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S + pad, H, K)
    return y[:, :S], sT


def rwkv_wkv(r, k, v, w, u, s0):
    """WKV6 recurrence.

    r,k,w: (B,S,H,K) f32; v: (B,S,H,V) f32; u: (H,K); s0: (B,H,K,V).
    Returns y: (B,S,H,V), sT.
    """
    def step(s, t_in):
        r_t, k_t, v_t, w_t = t_in                       # (B,H,K)...(B,H,V)
        kv = k_t[..., :, None] * v_t[..., None, :]      # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    sT, ys = jax.lax.scan(
        step, s0, (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
                   v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3), sT


def apply_rwkv_tm(cfg: ModelConfig, p, x, state, ctx: ApplyCtx):
    B, S, d = x.shape
    K = cfg.rwkv_head_dim
    H = d // K
    h = rmsnorm(x, p["ln1"], cfg.norm_plus_one)
    shift0 = (state["shift_t"] if state is not None
              else jnp.zeros((B, d), x.dtype))
    sx = _token_shift(h, shift0.astype(h.dtype))
    xxx = h + sx * p["mu_x"]
    lora = jnp.tanh(xxx @ p["lora_A"]).reshape(B, S, 5, _RWKV_LORA)
    mixes = jnp.einsum("bsln,lnd->bsld", lora, p["lora_B"])
    xw, xk, xv, xr, xg = [
        h + sx * (p[f"mu_{n}"] + mixes[:, :, i])
        for i, n in enumerate(("w", "k", "v", "r", "g"))]
    r = (xr @ p["wr"]).reshape(B, S, H, K).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(B, S, H, K).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(B, S, H, K).astype(jnp.float32)
    g = _act(xg @ p["wg"], "silu")
    wdec = (p["w0"].astype(jnp.float32)
            + (jnp.tanh(xw @ p["decay_A"]) @ p["decay_B"]).astype(jnp.float32))
    w = jnp.exp(-jnp.exp(wdec)).reshape(B, S, H, K)
    u = p["u"].astype(jnp.float32).reshape(H, K)
    if ctx.lengths is not None:
        # padded prefill: no decay, no writes past each row's valid length
        m = (ctx.positions < ctx.lengths[:, None])[:, :, None, None]
        w = jnp.where(m, w, 1.0)
        k = k * m
    s0 = (state["wkv"].astype(jnp.float32) if state is not None
          else jnp.zeros((B, H, K, K), jnp.float32))
    if _kernels().use_pallas() and S > 1:
        y, sT = _kernels().wkv6_op(r, k, v, w, u, s0)
    elif S > 1:
        y, sT = rwkv_wkv_chunked(r, k, v, w, u, s0)
    else:
        y, sT = rwkv_wkv(r, k, v, w, u, s0)
    # per-head groupnorm
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(B, S, d) * p["lnx_g"].astype(jnp.float32) \
        + p["lnx_b"].astype(jnp.float32)
    out = (y.astype(x.dtype) * g) @ p["wo"]
    new_state = state
    if state is not None:
        new_state = {**state, "wkv": sT.astype(state["wkv"].dtype),
                     "shift_t": _last_valid(h, ctx).astype(
                         state["shift_t"].dtype)}
    return out.astype(x.dtype), new_state


def _last_valid(h, ctx: ApplyCtx):
    """Last *valid* token's activation (B, d), honoring padded prefill.

    Indices are local to the chunk: absolute length minus chunk start."""
    if ctx.lengths is None:
        return h[:, -1]
    idx = jnp.clip(ctx.lengths - ctx.positions[:, 0] - 1, 0, h.shape[1] - 1)
    return jnp.take_along_axis(h, idx[:, None, None], axis=1)[:, 0]


def apply_rwkv_cm(cfg: ModelConfig, p, x, state, ctx: ApplyCtx):
    B, S, d = x.shape
    h = rmsnorm(x, p["ln2"], cfg.norm_plus_one)
    shift0 = (state["shift_c"] if state is not None
              else jnp.zeros((B, d), x.dtype))
    sx = _token_shift(h, shift0.astype(h.dtype))
    xk = h + sx * p["mu_ck"]
    xr = h + sx * p["mu_cr"]
    kk = jnp.square(jax.nn.relu(xk @ p["wk_cm"]))
    kv = shard(kk, "batch", None, "ff") @ p["wv_cm"]
    out = jax.nn.sigmoid(xr @ p["wr_cm"]) * kv
    new_state = state
    if state is not None:
        new_state = {**state, "shift_c": _last_valid(h, ctx).astype(
            state["shift_c"].dtype)}
    return out.astype(x.dtype), new_state
