"""Jit'd public wrappers around the Pallas kernels.

Handles padding to block multiples, layout massaging from the model's
(B, S, H, D) convention, and the interpret switch (CPU containers execute
kernel bodies in Python via interpret=True; on TPU the same call compiles
to Mosaic).  ``use_pallas()`` is the runtime toggle the model layer reads.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.chunked_prefill_attention import chunked_prefill_attention
from repro.kernels.decode_attention import decode_attention
from repro.kernels.wkv6 import wkv6

_INTERPRET = jax.default_backend() != "tpu"


def use_pallas() -> bool:
    return os.environ.get("REPRO_USE_PALLAS", "0") == "1"


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "block_q", "block_k"))
def prefill_attention(q, k, v, offset, lengths, window: int = 0,
                      softcap: float = 0.0, scale: Optional[float] = None,
                      block_q: int = 128, block_k: int = 128):
    """(B,Sq,Hq,D) x (B,Skv,Hkv,D) chunked/whole prefill attention."""
    B, Sq, Hq, D = q.shape
    bq = min(block_q, max(8, 1 << (Sq - 1).bit_length()))
    bk = min(block_k, max(8, 1 << (k.shape[1] - 1).bit_length()))
    qp = _pad_to(q, bq, 1)
    kp = _pad_to(k, bk, 1)
    vp = _pad_to(v, bk, 1)
    out = chunked_prefill_attention(
        qp, kp, vp, offset, lengths, window=window, softcap=softcap,
        scale=scale, block_q=bq, block_k=bk, interpret=_INTERPRET)
    return out[:, :Sq]


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "block_k"))
def decode_attention_op(q, k, v, cur_lens, window: int = 0,
                        softcap: float = 0.0, scale: Optional[float] = None,
                        block_k: int = 256):
    """(B,Hq,D) single-token decode against a (B,L,Hkv,D) cache."""
    bk = min(block_k, max(8, 1 << (k.shape[1] - 1).bit_length()))
    kp = _pad_to(k, bk, 1)
    vp = _pad_to(v, bk, 1)
    return decode_attention(q, kp, vp, cur_lens, window=window,
                            softcap=softcap, scale=scale, block_k=bk,
                            interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6_op(r, k, v, w, u, s0, chunk: int = 16):
    """(B,S,H,K)-layout WKV6 (matches models.ops.rwkv_wkv call shapes).

    Pads S to the chunk multiple with w=1 (no decay), k=0 (no state write)
    so padding cannot disturb the carry."""
    B, S, H, K = r.shape
    pad = (-S) % chunk
    tr = lambda x: x.transpose(0, 2, 1, 3)  # noqa: E731  (B,H,S,K)
    rp, kp2, vp, wp = tr(r), tr(k), tr(v), tr(w)
    if pad:
        zeros = jnp.zeros((B, H, pad, K), r.dtype)
        ones = jnp.ones((B, H, pad, K), w.dtype)
        rp = jnp.concatenate([rp, zeros], axis=2)
        kp2 = jnp.concatenate([kp2, zeros], axis=2)
        vp = jnp.concatenate([vp, zeros], axis=2)
        wp = jnp.concatenate([wp, ones], axis=2)
    y, sT = wkv6(rp, kp2, vp, wp, u, s0, chunk=chunk, interpret=_INTERPRET)
    return y[:, :, :S].transpose(0, 2, 1, 3), sT
