"""Paged flash-decode attention — block-table KV pool, TPU layout.

Like ``decode_attention`` but the cache lives in a shared block pool
(serving/paged.py): each grid step processes one 128-token page whose pool
index comes from the request's block table (SMEM).  Pages beyond the live
length — and unallocated (-1) table entries — are skipped with @pl.when, so
per-step HBM traffic is exactly the request's resident pages: paging adds
zero overhead to the decode roofline while eliminating allocation
fragmentation.

Grid: (num_requests, Hkv, max_blocks_per_request); online-softmax
accumulators in VMEM scratch persist across the page axis.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def paged_decode_attention(
        q: jax.Array,             # (B, Hq, D)
        pool_k: jax.Array,        # (num_blocks, BS, Hkv, D)
        pool_v: jax.Array,
        tables: jax.Array,        # (B, max_blocks) int32, -1 = unallocated
        cur_lens: jax.Array,      # (B,) int32
        scale: Optional[float] = None,
        interpret: bool = False) -> jax.Array:
    B, Hq, D = q.shape
    NB, BS, Hkv, _ = pool_k.shape
    MB = tables.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, G, D)
    kern = functools.partial(_kernel_with_prefetch, bs=BS, scale=scale)

    # page indirection: the index_map reads the block table (scalar
    # prefetch) to pick which pool page this grid step streams in
    grid = (B, Hkv, MB)

    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D),
                             lambda b, h, bi, tr, lr: (b, h, 0, 0)),
                pl.BlockSpec((1, BS, 1, D),
                             lambda b, h, bi, tr, lr:
                             (jnp.maximum(tr[b, bi], 0), 0, h, 0)),
                pl.BlockSpec((1, BS, 1, D),
                             lambda b, h, bi, tr, lr:
                             (jnp.maximum(tr[b, bi], 0), 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D),
                                   lambda b, h, bi, tr, lr: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, D), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), cur_lens.astype(jnp.int32),
      qg, pool_k, pool_v)
    return out.reshape(B, Hq, D)


def _kernel_with_prefetch(table_ref, len_ref, q_ref, pk_ref, pv_ref,
                          o_ref, acc, m_s, l_s, *, bs, scale):
    # (kept for clarity: PrefetchScalarGridSpec passes the scalar refs
    # first; the shared body reads per-request entries)
    b = pl.program_id(0)
    bi = pl.program_id(2)
    nb = pl.num_programs(2)
    cur = len_ref[b]
    blk = table_ref[b, bi]
    _body(q_ref, pk_ref, pv_ref, o_ref, acc, m_s, l_s,
          bi=bi, nb=nb, cur=cur, blk=blk, bs=bs, scale=scale)


def _body(q_ref, pk_ref, pv_ref, o_ref, acc, m_s, l_s, *,
          bi, nb, cur, blk, bs, scale):
    @pl.when(bi == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    live = (blk >= 0) & (bi * bs <= cur)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = pk_ref[0, :, 0, :].astype(jnp.float32)
        v = pv_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = bi * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos <= cur, s, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_s[...] = l_s[...] * alpha + p.sum(axis=1, keepdims=True)
        m_s[...] = m_new
        acc[...] = acc[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(bi == nb - 1)
    def _done():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)
                       ).astype(o_ref.dtype)
