"""Chunked WKV6 kernel — RWKV-6 recurrence as parallel chunks.

The naive recurrence is a length-S scan (MXU-starved: rank-1 updates).
This kernel processes C=16 tokens per chunk with dense matmuls:

With L_t = sum_{r<=t} log w_r (per key channel, L_0-exclusive prefix) and
S0 the carry state entering the chunk:

  y_t   = (r_t . e^{L_{t-1}}) @ S0                      (inter-chunk)
        + sum_{s<t} [(r_t e^{L_{t-1}}) . (k_s e^{-L_s})] v_s   (intra)
        + (r_t . u . k_t) v_t                            (bonus diag)
  S_out = e^{L_C} . S0 + sum_s (k_s e^{L_C - L_s}) (x) v_s

Chunk size 16 bounds |L| <= 16*e^1 so the e^{-L_s} factor stays inside f32
range for RWKV-6's decay parameterization (log w in [-e, ~0)); all chunk
math is f32 in VMEM.  Grid = (B, H, num_chunks): the chunk axis is
innermost/sequential on TPU, the (K, K) state rides in VMEM scratch across
chunk steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 16


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
            y_ref, sT_ref, s_scr, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)            # (C, K)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)               # (1, K) block
    s0 = s_scr[...]                                # (K, K)

    logw = jnp.log(jnp.maximum(w, 1e-38))          # (C, K), <= 0
    L = jnp.cumsum(logw, axis=0)                   # inclusive prefix
    L_prev = L - logw                              # exclusive prefix (L_{t-1})

    q_in = r * jnp.exp(L_prev)                     # (C, K)
    k_out = k * jnp.exp(-L)                        # (C, K)
    # inter-chunk contribution
    y = jax.lax.dot_general(q_in, s0, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk: strictly-causal scores + bonus diagonal
    scores = jax.lax.dot_general(q_in, k_out, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(s_idx < t_idx, scores, 0.0)
    diag = jnp.sum(r * u * k, axis=1)              # (C,)
    scores += jnp.where(s_idx == t_idx, diag[:, None], 0.0)
    y += jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state carry: S_out = e^{L_C} . S0 + sum_s k_s e^{L_C - L_s} (x) v_s
    L_C = L[-1:, :]                                # (1, K)
    k_carry = k * jnp.exp(L_C - L)                 # (C, K)
    s_scr[...] = (jnp.exp(L_C).T * s0
                  + jax.lax.dot_general(k_carry, v, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))

    @pl.when(ci == nc - 1)
    def _done():
        sT_ref[0, 0] = s_scr[...].astype(sT_ref.dtype)


def wkv6(r, k, v, w, u, s0, chunk: int = DEFAULT_CHUNK,
         interpret: bool = False):
    """r,k,v,w: (B,H,S,K); u: (H,K); s0: (B,H,K,K) ->
    (y (B,H,S,K), sT (B,H,K,K))."""
    B, H, S, K = r.shape
    assert S % chunk == 0, (S, chunk)
    grid = (B, H, S // chunk)
    kern = functools.partial(_kernel, chunk=chunk)
    y, sT = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, K), lambda b, h, ci: (h, 0)),
            pl.BlockSpec((1, 1, K, K), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, K, K), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, K), r.dtype),
            jax.ShapeDtypeStruct((B, H, K, K), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, K), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, sT
