"""Flash-decode attention — the memory-bound decode hot-spot.

Decode reads the whole KV cache once per step: the roofline is HBM
bandwidth.  The kernel splits the cache sequence into BK-sized blocks
(grid innermost axis) and streams them HBM->VMEM while a (G, D) output
accumulator for one (batch, kv_head) group lives in VMEM scratch — the
flash-decoding scheme adapted to TPU block semantics.  G = Hq/Hkv query
heads share one KV head (GQA), so the MXU operates on (G, BK) score tiles;
for MQA (G=Hq) this becomes a single dense (Hq, BK) tile — ideal.

`cur_lens` rides in SMEM; a block whose positions all exceed cur_len is
skipped entirely (@pl.when), so per-step work is O(cur_len), not O(max_len)
— this is what makes the 32k/500k decode shapes bandwidth- rather than
padding-bound.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30
DEFAULT_BK = 256


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s,
            *, scale: float, window: int, softcap: float, bk: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    cur = len_ref[0]

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    # skip blocks entirely beyond the live cache (or behind the window)
    blk_lo = ki * bk
    live = blk_lo <= cur
    if window:
        live &= (blk_lo + bk) > (cur - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)          # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (BK, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)          # (BK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = blk_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos <= cur
        if window:
            mask &= k_pos > (cur - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_s[...] = l_s[...] * alpha + p.sum(axis=1, keepdims=True)
        m_s[...] = m_new
        acc[...] = acc[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _done():
        l = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, 0, :, :] = (acc[...] / l).astype(o_ref.dtype)


def decode_attention(
        q: jax.Array,            # (B, Hq, D)
        k: jax.Array,            # (B, L, Hkv, D)
        v: jax.Array,
        cur_lens: jax.Array,     # (B,) int32
        window: int = 0,
        softcap: float = 0.0,
        scale: Optional[float] = None,
        block_k: int = DEFAULT_BK,
        interpret: bool = False) -> jax.Array:
    B, Hq, D = q.shape
    L, Hkv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0 and L % block_k == 0, (q.shape, k.shape, block_k)
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, G, D)
    grid = (B, Hkv, L // block_k)
    kern = functools.partial(_kernel, scale=scale, window=window,
                             softcap=softcap, bk=block_k)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ki: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(cur_lens.astype(jnp.int32), qg, k, v)
    return out.reshape(B, Hq, D)
