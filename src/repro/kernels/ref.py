"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function mirrors its kernel's semantics exactly, in plain jax.numpy —
tests sweep shapes/dtypes and assert_allclose kernels against these.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def chunked_prefill_attention_ref(
        q: jax.Array,            # (B, Sq, Hq, D)
        k: jax.Array,            # (B, Skv, Hkv, D)  — the KV cache
        v: jax.Array,            # (B, Skv, Hkv, D)
        offset: jax.Array,       # (B,) absolute position of q row 0
        lengths: jax.Array,      # (B,) absolute valid key length
        window: int = 0,
        softcap: float = 0.0,
        scale: Optional[float] = None) -> jax.Array:
    """Causal (chunked) prefill attention against a cache.

    Row t of q sits at absolute position offset+t; keys are cache slots
    0..Skv-1; a key is visible iff k_pos <= q_pos and k_pos < lengths
    (and within the sliding window if window > 0)."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qq = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqkgd,blkd->bkgql", qq.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = offset[:, None] + jnp.arange(Sq)[None]           # (B, Sq)
    k_pos = jnp.arange(Skv)[None]                            # (1, Skv)
    mask = k_pos[:, None, :] <= q_pos[:, :, None]            # (B, Sq, Skv)
    mask &= k_pos[:, None, :] < lengths[:, None, None]
    if window:
        mask &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgql,blkd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


def decode_attention_ref(
        q: jax.Array,            # (B, Hq, D) — the single new token
        k: jax.Array,            # (B, L, Hkv, D)
        v: jax.Array,            # (B, L, Hkv, D)
        cur_lens: jax.Array,     # (B,) cache tokens; new token at cur_lens
        window: int = 0,
        softcap: float = 0.0,
        scale: Optional[float] = None) -> jax.Array:
    """Flash-decode semantics: attend to k_pos <= cur_len (the new token's
    k/v has already been written at slot cur_len)."""
    B, Hq, D = q.shape
    L, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qq = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bkgd,blkd->bkgl", qq.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = jnp.arange(L)[None]
    mask = k_pos <= cur_lens[:, None]
    if window:
        mask &= k_pos > (cur_lens[:, None] - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgl,blkd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q.dtype)


def wkv6_ref(r, k, v, w, u, s0):
    """RWKV6 recurrence oracle (same math as models.ops.rwkv_wkv).

    r,k,w: (B,H,S,K) f32; v: (B,H,S,K); u: (H,K); s0: (B,H,K,K).
    Returns (y (B,H,S,K), sT (B,H,K,K))."""
    B, H, S, K = r.shape

    def step(s, t_in):
        r_t, k_t, v_t, w_t = t_in                     # (B,H,K)
        kv = k_t[..., :, None] * v_t[..., None, :]    # (B,H,K,K)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    xs = tuple(a.transpose(2, 0, 1, 3) for a in (r, k, v, w))
    sT, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return ys.transpose(1, 2, 0, 3), sT
