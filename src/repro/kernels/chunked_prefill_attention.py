"""Chunked-prefill flash attention — the Convertible Decoder's hot kernel.

One kernel covers the whole prefill family:
  * whole-prompt prefill          (offset = 0, Skv = Sq)
  * restricted chunked prefill    (offset = chunk start, keys = live cache)
  * sliding-window (local) layers (window > 0, e.g. gemma-2)
  * softcapped attention          (softcap > 0)

TPU mapping: flash-attention with a 4D grid (batch, q_head, q_block,
kv_block); the kv_block axis is innermost and iterated sequentially on TPU,
so the online-softmax running stats (m, l) and the output accumulator live
in VMEM scratch that persists across kv steps.  Q blocks are
(BQ=128, D) and KV blocks (BK=128, D): MXU-aligned (128 lanes), three
f32 accumulators + two input tiles ≈ (128*128)*4B*4 ≈ 256 KiB — comfortably
inside the ~16 MiB v5e VMEM budget with double buffering.

Per-batch `offset` and `lengths` ride in SMEM; masking is computed from
broadcasted iotas against absolute positions, which is what lets the SAME
kernel serve both the prefiller instances and the convertible decoder's
restricted chunks.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30
DEFAULT_BQ = 128
DEFAULT_BK = 128


def _kernel(off_ref, len_ref,                      # SMEM scalars (per batch)
            q_ref, k_ref, v_ref,                   # VMEM blocks
            o_ref,                                 # VMEM out block
            acc, m_s, l_s,                         # scratch
            *, scale: float, window: int, softcap: float,
            bq: int, bk: int):
    ki = pl.program_id(3)
    qi = pl.program_id(2)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0, :, 0, :].astype(jnp.float32)             # (BQ, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)             # (BK, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)             # (BK, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    offset = off_ref[0]
    length = len_ref[0]
    q_pos = offset + qi * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                        (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (k_pos <= q_pos) & (k_pos < length)
    if window:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_s[...]                                      # (BQ, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                 # (BQ, BK)
    l_s[...] = l_s[...] * alpha + p.sum(axis=1, keepdims=True)
    m_s[...] = m_new
    acc[...] = acc[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _done():
        l = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, :, 0, :] = (acc[...] / l).astype(o_ref.dtype)


def chunked_prefill_attention(
        q: jax.Array,            # (B, Sq, Hq, D)
        k: jax.Array,            # (B, Skv, Hkv, D)
        v: jax.Array,
        offset: jax.Array,       # (B,) int32
        lengths: jax.Array,      # (B,) int32
        window: int = 0,
        softcap: float = 0.0,
        scale: Optional[float] = None,
        block_q: int = DEFAULT_BQ,
        block_k: int = DEFAULT_BK,
        interpret: bool = False) -> jax.Array:
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0 and Sq % block_q == 0 and Skv % block_k == 0, (
        q.shape, k.shape, block_q, block_k)
    scale = scale if scale is not None else D ** -0.5
    grid = (B, Hq, Sq // block_q, Skv // block_k)
    group = Hq // Hkv

    kern = functools.partial(
        _kernel, scale=scale, window=window, softcap=softcap,
        bq=block_q, bk=block_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, qi, ki: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda b, h, qi, ki: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block_q, 1, D),
                         lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, qi, ki: (b, ki, h // group, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, qi, ki: (b, ki, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(offset.astype(jnp.int32), lengths.astype(jnp.int32), q, k, v)
