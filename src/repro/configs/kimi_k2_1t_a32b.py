"""Kimi K2 — trillion-parameter MoE (paper-table geometry).

[arXiv:2501.kimi2] 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048
vocab=163840, MoE 384 routed experts top-8 + 1 shared; first layer dense
(dense d_ff=18432, per the K2 card).
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    citation="arXiv:2501.kimi2",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=18432,                      # dense-FFN layers (first_k_dense)
    vocab_size=163840,
    first_k_dense=1,
    block_pattern=(LayerSpec(ffn="moe"),),
    moe=MoEConfig(num_experts=384, top_k=8, num_shared=1, d_ff_expert=2048),
    rope_theta=5e4,
)

SMOKE = CONFIG.replace(
    name="kimi-k2-smoke",
    num_layers=2, first_k_dense=1, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=2, num_shared=1, d_ff_expert=128),
    dtype="float32", param_dtype="float32",
)
