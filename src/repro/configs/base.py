"""Model / run configuration system.

Every assigned architecture gets a module ``src/repro/configs/<id>.py`` that
exports ``CONFIG`` (the exact published geometry, cited) and ``SMOKE``
(a reduced same-family variant: <=2 blocks, d_model<=512, <=4 experts) used by
CPU smoke tests.  The FULL configs are only ever lowered abstractly
(ShapeDtypeStruct) by the dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Layer / model configs
# ---------------------------------------------------------------------------

MIXERS = ("attn", "local_attn", "cross_attn", "mamba", "rwkv")
FFNS = ("dense", "moe", "rwkv_cm")


@dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"          # one of MIXERS
    ffn: str = "dense"           # one of FFNS

    def __post_init__(self):
        assert self.mixer in MIXERS, self.mixer
        assert self.ffn in FFNS, self.ffn


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8          # routed experts
    top_k: int = 2
    num_shared: int = 0           # shared (always-on) experts
    d_ff_expert: int = 0          # expert hidden dim
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01  # load-balance loss coefficient


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                # dense | moe | ssm | hybrid | vlm | audio
    citation: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    # layer pattern: `block_pattern` repeats `num_layers // len(block_pattern)`
    # times after `first_k_dense` unrolled prefix layers (dense-FFN attn).
    block_pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    first_k_dense: int = 0
    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0       # window for local_attn layers
    attn_softcap: float = 0.0
    logit_softcap: float = 0.0
    query_scale: float = 0.0      # 0 -> 1/sqrt(head_dim)
    # MLA (deepseek-style latent attention); kv_lora_rank>0 enables it
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    # FFN
    act: str = "silu"             # silu (SwiGLU) | gelu (GeGLU)
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    # embeddings
    tie_embeddings: bool = False
    scale_embeds: bool = False    # gemma-style sqrt(d_model) scaling
    norm_plus_one: bool = False   # gemma RMSNorm (1+w)
    post_norms: bool = False      # gemma2 post-attn/post-ffn norms
    # multimodal
    num_vision_tokens: int = 0    # vlm cross-attn source length (stub frontend)
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    kv_cache_dtype: str = ""      # "" -> dtype; "int8" = quantized KV cache
                                  # (per-token-per-head scales; halves decode
                                  # HBM traffic and doubles the memory-bound
                                  # batch -> raises decode Token Velocity)
    # rwkv
    rwkv_head_dim: int = 64

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def num_blocks(self) -> int:
        body = self.num_layers - self.first_k_dense
        assert body % len(self.block_pattern) == 0, (
            f"{self.name}: {body} layers not divisible by block "
            f"pattern of {len(self.block_pattern)}")
        return body // len(self.block_pattern)

    @property
    def layer_specs(self) -> tuple[LayerSpec, ...]:
        return (tuple(LayerSpec() for _ in range(self.first_k_dense))
                + self.block_pattern * self.num_blocks)

    @property
    def uses_attention(self) -> bool:
        return any(s.mixer in ("attn", "local_attn", "cross_attn")
                   for s in self.layer_specs)

    @property
    def is_subquadratic(self) -> bool:
        """True if no unbounded full-attention KV cache is required."""
        return all(s.mixer in ("mamba", "rwkv", "local_attn", "cross_attn")
                   for s in self.layer_specs)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for roofline MODEL_FLOPS = 6 N D) ----
    def param_counts(self) -> dict[str, float]:
        d, dh = self.d_model, self.head_dim_
        nq, nkv = self.num_heads, self.num_kv_heads
        embed = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d

        def attn_params(cross: bool = False) -> float:
            if self.kv_lora_rank and not cross:
                qk = self.qk_nope_dim + self.qk_rope_dim
                p = d * nq * qk                                 # q proj
                p += d * (self.kv_lora_rank + self.qk_rope_dim)  # kv down
                p += self.kv_lora_rank * nq * (self.qk_nope_dim
                                               + self.v_head_dim)  # kv up
                p += nq * self.v_head_dim * d                   # o proj
                return p
            return d * (nq * dh) + 2 * d * (nkv * dh) + (nq * dh) * d

        def ffn_params(spec: LayerSpec) -> float:
            if spec.ffn == "dense":
                return 3 * d * self.d_ff
            if spec.ffn == "rwkv_cm":
                return 2 * d * self.d_ff + d * d
            m = self.moe
            routed = m.num_experts * 3 * d * m.d_ff_expert
            shared = m.num_shared * 3 * d * m.d_ff_expert
            return routed + shared + d * m.num_experts

        def ffn_active(spec: LayerSpec) -> float:
            if spec.ffn != "moe":
                return ffn_params(spec)
            m = self.moe
            return (m.top_k + m.num_shared) * 3 * d * m.d_ff_expert \
                + d * m.num_experts

        def mixer_params(spec: LayerSpec) -> float:
            if spec.mixer in ("attn", "local_attn"):
                return attn_params()
            if spec.mixer == "cross_attn":
                return attn_params(cross=True)
            if spec.mixer == "mamba":
                mc = self.mamba
                di = mc.expand * d
                dtr = mc.dt_rank or -(-d // 16)
                return (d * 2 * di + di * mc.d_conv
                        + di * (dtr + 2 * mc.d_state) + dtr * di
                        + di * mc.d_state + di + di * d)
            if spec.mixer == "rwkv":
                # r,k,v,g,o projections + decay lora + token-shift loras
                return 5 * d * d + 6 * (d * 32 + 32 * d) + d * 64 + 64 * d
            raise ValueError(spec.mixer)

        total = embed + head
        active = embed + head
        for spec in self.layer_specs:
            mp = mixer_params(spec)
            total += mp + ffn_params(spec)
            active += mp + ffn_active(spec)
        return {"total": float(total), "active": float(active)}


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def long_context_variant(cfg: ModelConfig) -> Optional[ModelConfig]:
    """Sub-quadratic variant used for long_500k, or None if the arch must
    skip that shape (pure full-attention; see DESIGN.md)."""
    if cfg.is_subquadratic:
        return cfg
    specs = cfg.layer_specs
    n_attn = sum(s.mixer == "attn" for s in specs)
    n_local = sum(s.mixer == "local_attn" for s in specs)
    n_ssm = sum(s.mixer in ("mamba", "rwkv") for s in specs)
    if n_ssm and n_attn <= len(specs) // 4:
        # jamba-style hybrid: the minority attention layers run with a
        # context-parallel (sequence-sharded) cache; the SSM majority keeps
        # O(1) state — run the shape as-is.
        return cfg
    if n_local and n_attn:
        # gemma2-style alternating: long-decode config runs every attention
        # layer with the sliding window (paper-permitted dense carve-out).
        pat = tuple(
            LayerSpec("local_attn" if s.mixer == "attn" else s.mixer, s.ffn)
            for s in cfg.block_pattern)
        return cfg.replace(block_pattern=pat, name=cfg.name + "-swa")
    return None


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = [
    "rwkv6_3b", "qwen2_0_5b", "kimi_k2_1t_a32b", "deepseek_v2_lite_16b",
    "yi_9b", "musicgen_large", "gemma2_9b", "gemma_2b",
    "llama_3_2_vision_11b", "jamba_v0_1_52b",
    # the paper's own evaluation models
    "llama31_8b", "qwen25_32b",
]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIAS.update({
    "rwkv6-3b": "rwkv6_3b", "qwen2-0.5b": "qwen2_0_5b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b", "yi-9b": "yi_9b",
    "musicgen-large": "musicgen_large", "gemma2-9b": "gemma2_9b",
    "gemma-2b": "gemma_2b", "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "llama-3.1-8b": "llama31_8b", "qwen-2.5-32b": "qwen25_32b",
})


def canonical_id(arch: str) -> str:
    return _ALIAS.get(arch, arch.replace("-", "_").replace(".", "_"))


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical_id(arch)}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}


# ---------------------------------------------------------------------------
# input_specs(): abstract inputs per (config, shape) — the dry-run contract
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: InputShape) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input.  No allocation.

    train  -> {tokens, labels [, image_embeds]}
    prefill-> {tokens, lengths [, image_embeds]}
    decode -> {last_tokens, cur_lens} (+ state built separately)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    out: dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        out["tokens"] = sds((B, S), i32)
        out["labels"] = sds((B, S), i32)
    elif shape.kind == "prefill":
        out["tokens"] = sds((B, S), i32)
        out["lengths"] = sds((B,), i32)
    else:  # decode: one new token against a cache of S
        out["last_tokens"] = sds((B,), i32)
        out["cur_lens"] = sds((B,), i32)
    if cfg.num_vision_tokens and shape.kind != "decode":
        out["image_embeds"] = sds(
            (B, cfg.num_vision_tokens, cfg.d_model), jnp.bfloat16)
    return out
