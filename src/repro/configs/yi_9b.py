"""Yi-9B — llama-architecture dense GQA.

[arXiv:2403.04652] 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    arch_type="dense",
    citation="arXiv:2403.04652",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5e6,
    block_pattern=(LayerSpec(),),
)

SMOKE = CONFIG.replace(
    name="yi-smoke",
    num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
    d_ff=512, vocab_size=512, dtype="float32", param_dtype="float32",
)
