"""Qwen2-0.5B — dense GQA with QKV bias, tied embeddings.

[arXiv:2407.10671] 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    arch_type="dense",
    citation="arXiv:2407.10671",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
    block_pattern=(LayerSpec(),),
)

SMOKE = CONFIG.replace(
    name="qwen2-smoke",
    num_layers=2, d_model=224, num_heads=14, num_kv_heads=2,
    d_ff=512, vocab_size=512, dtype="float32", param_dtype="float32",
)
