"""Gemma 2B — GeGLU, head_dim=256, MQA (kv=1), tied embeddings.

[arXiv:2403.08295] 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    arch_type="dense",
    citation="arXiv:2403.08295",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    act="gelu",
    tie_embeddings=True,
    scale_embeds=True,
    norm_plus_one=True,
    block_pattern=(LayerSpec(),),
)

SMOKE = CONFIG.replace(
    name="gemma-smoke",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=1, head_dim=64,
    d_ff=512, vocab_size=512, dtype="float32", param_dtype="float32",
)
