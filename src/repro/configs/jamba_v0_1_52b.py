"""Jamba v0.1 52B — Mamba+attention 1:7 interleave with MoE.

[arXiv:2403.19887] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536;
attention at layer offset 4 of each period-8 block; MoE (16 experts top-2)
every other layer; Mamba d_state=16 d_conv=4 expand=2.
"""
from repro.configs.base import LayerSpec, MambaConfig, ModelConfig, MoEConfig

_BLOCK = (
    LayerSpec(mixer="mamba", ffn="dense"),
    LayerSpec(mixer="mamba", ffn="moe"),
    LayerSpec(mixer="mamba", ffn="dense"),
    LayerSpec(mixer="mamba", ffn="moe"),
    LayerSpec(mixer="attn", ffn="dense"),
    LayerSpec(mixer="mamba", ffn="moe"),
    LayerSpec(mixer="mamba", ffn="dense"),
    LayerSpec(mixer="mamba", ffn="moe"),
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    citation="arXiv:2403.19887",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=_BLOCK,
    moe=MoEConfig(num_experts=16, top_k=2, num_shared=0, d_ff_expert=14336),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
)

SMOKE = CONFIG.replace(
    name="jamba-smoke",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512,
    block_pattern=(LayerSpec(mixer="mamba", ffn="moe"),
                   LayerSpec(mixer="attn", ffn="dense")),
    moe=MoEConfig(num_experts=4, top_k=2, num_shared=0, d_ff_expert=128),
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
    dtype="float32", param_dtype="float32",
)
