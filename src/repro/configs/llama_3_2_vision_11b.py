"""Llama-3.2-Vision 11B — text decoder with cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision] 40L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256; cross-attention layers every 5th layer
(positions 3, 8, 13, ... — block pattern of 5 with cross at index 3).
The ViT vision encoder + projector is the stubbed modality frontend:
input_specs() provides (B, 6400, d_model) patch embeddings.
"""
from repro.configs.base import LayerSpec, ModelConfig

_BLOCK = (LayerSpec(), LayerSpec(), LayerSpec(),
          LayerSpec(mixer="cross_attn"), LayerSpec())

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    arch_type="vlm",
    citation="hf:meta-llama/Llama-3.2-11B-Vision",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=5e5,
    num_vision_tokens=6400,
    block_pattern=_BLOCK,
)

SMOKE = CONFIG.replace(
    name="llama-vision-smoke",
    num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
    d_ff=512, vocab_size=512, num_vision_tokens=64,
    block_pattern=(LayerSpec(), LayerSpec(mixer="cross_attn")),
    dtype="float32", param_dtype="float32",
)
