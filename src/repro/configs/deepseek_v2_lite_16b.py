"""DeepSeek-V2-Lite 16B — MLA latent attention + fine-grained MoE.

[arXiv:2405.04434] 27L d_model=2048 16H, MLA kv_lora_rank=512
(qk_nope=128, qk_rope=64, v_head=128), expert d_ff=1408,
2 shared + 64 routed experts top-6, first layer dense (d_ff=10944).
(The pool line's "160 routed" is the full V2; the Lite card is 64 routed —
we follow the Lite card, see DESIGN.md.)
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    citation="arXiv:2405.04434",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,                      # dense-FFN first layer
    vocab_size=102400,
    first_k_dense=1,
    block_pattern=(LayerSpec(ffn="moe"),),
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_ff_expert=1408),
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    rope_theta=1e4,
)

SMOKE = CONFIG.replace(
    name="deepseek-v2-lite-smoke",
    num_layers=2, first_k_dense=1, d_model=256, num_heads=4, num_kv_heads=4,
    d_ff=512, vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=2, num_shared=1, d_ff_expert=128),
    kv_lora_rank=64, qk_rope_dim=16, qk_nope_dim=32, v_head_dim=32,
    dtype="float32", param_dtype="float32",
)
