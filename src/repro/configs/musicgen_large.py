"""MusicGen-Large — decoder-only transformer over EnCodec audio tokens.

[arXiv:2306.05284] 48L d_model=2048 32H (kv=32, i.e. MHA) d_ff=8192
vocab=2048 (EnCodec codebook).  Backbone only: the EnCodec conv codec
(audio -> discrete tokens) is the stubbed modality frontend; input_specs()
provides token ids / frame embeddings of the right shape (see DESIGN.md).
GELU-gated FFN; rope replaces the original learned sinusoidal embedding
(TPU-idiomatic adaptation, noted in DESIGN.md).
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    citation="arXiv:2306.05284",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    act="gelu",
    block_pattern=(LayerSpec(),),
)

SMOKE = CONFIG.replace(
    name="musicgen-smoke",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
    d_ff=512, vocab_size=512, dtype="float32", param_dtype="float32",
)
