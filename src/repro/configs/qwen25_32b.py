"""Qwen-2.5-32B — the paper's *large model* evaluation target (§V).

[arXiv:2412.15115] 64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064,
QKV bias.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen-2.5-32b",
    arch_type="dense",
    citation="arXiv:2412.15115 (paper §V large model)",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    block_pattern=(LayerSpec(),),
)

SMOKE = CONFIG.replace(
    name="qwen25-smoke",
    num_layers=2, d_model=320, num_heads=5, num_kv_heads=1,
    d_ff=512, vocab_size=512, dtype="float32", param_dtype="float32",
)
