"""Gemma-2 9B — alternating local/global attention with logit softcaps.

[arXiv:2408.00118] 42L d_model=3584 16H (GQA kv=8) head_dim=256 d_ff=14336
vocab=256000; sliding window 4096 on local layers; attn softcap 50, final
logit softcap 30; GeGLU; tied embeddings; pre+post norms.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    arch_type="dense",
    citation="arXiv:2408.00118",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    sliding_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
    scale_embeds=True,
    norm_plus_one=True,
    post_norms=True,
    query_scale=1.0 / 256.0 ** 0.5,
    block_pattern=(LayerSpec(mixer="local_attn"), LayerSpec(mixer="attn")),
)

SMOKE = CONFIG.replace(
    name="gemma2-smoke",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=512, sliding_window=64,
    query_scale=1.0 / 64.0 ** 0.5,
    dtype="float32", param_dtype="float32",
)
