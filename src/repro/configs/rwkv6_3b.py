"""RWKV-6 "Finch" 3B — attention-free SSM with data-dependent decay.

[arXiv:2404.05892] 32L d_model=2560 d_ff=8960 vocab=65536; head_dim=64
(40 wkv heads).  Mixer = RWKV6 time-mix, FFN = RWKV channel-mix.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    arch_type="ssm",
    citation="arXiv:2404.05892",
    num_layers=32,
    d_model=2560,
    num_heads=40,          # wkv heads = d_model / rwkv_head_dim
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    rwkv_head_dim=64,
    block_pattern=(LayerSpec(mixer="rwkv", ffn="rwkv_cm"),),
)

SMOKE = CONFIG.replace(
    name="rwkv6-smoke",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
    d_ff=512, vocab_size=512, dtype="float32", param_dtype="float32",
)
