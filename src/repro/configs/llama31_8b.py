"""Llama-3.1-8B — the paper's *small model* evaluation target (§V).

[arXiv:2407.21783] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.1-8b",
    arch_type="dense",
    citation="arXiv:2407.21783 (paper §V small model)",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=5e5,
    block_pattern=(LayerSpec(),),
)

SMOKE = CONFIG.replace(
    name="llama31-smoke",
    num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
    d_ff=512, vocab_size=512, dtype="float32", param_dtype="float32",
)
