from repro.configs.base import (  # noqa: F401
    ARCH_IDS, INPUT_SHAPES, InputShape, LayerSpec, MambaConfig, ModelConfig,
    MoEConfig, all_configs, canonical_id, get_config, input_specs,
    long_context_variant,
)
