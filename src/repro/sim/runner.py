"""Experiment runner: build (cluster, policy) pairs the way §V configures
them and produce the paper's comparison numbers."""
from __future__ import annotations

from typing import Optional

from repro.configs import get_config
from repro.core import (AIBrixPolicy, BlitzScalePolicy, DistServePolicy,
                        InstanceSpec, OutputPredictor, TokenScalePolicy,
                        plan_convertible, profile)
from repro.core.hardware import CHIPS
from repro.core.velocity import VelocityProfile
from repro.sim.cluster import Cluster, SimReport
from repro.sim.events import EventCluster
from repro.sim.traces import get_trace

#: engine name -> cluster class; both drive the identical control plane.
ENGINES = {"fluid": Cluster, "events": EventCluster}


def get_engine(name: str):
    try:
        return ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; expected one of {sorted(ENGINES)}")


def make_policy(name: str, prof: VelocityProfile, n_convertible: int = 1,
                mean_in: float = 1024.0, mean_out: float = 240.0):
    """§V Baselines.  Threshold derivations follow Table I's recipes:
    request-based thresholds = stage capacity / mean request size, with the
    safety factors the respective papers use (which is exactly why they
    overprovision after bursts, §VI-A)."""
    if name == "tokenscale":
        return TokenScalePolicy(prof, convertible=n_convertible)
    if name == "distserve":
        # "uses a simulator to determine scaling thresholds" — capacity/size
        # with a 0.7 safety factor
        return DistServePolicy(
            rps_per_prefiller=max(0.7 * prof.v_prefill / mean_in, 0.5),
            rps_per_decoder=max(
                0.5 * prof.v_decode_mean() / (mean_in + mean_out), 0.5))
    if name == "aibrix":
        # Table I: concurrency threshold = max prefill throughput / average
        # prefill length (in requests); decoder fixed at 70% memory util
        return AIBrixPolicy(
            conc_per_prefiller=max(prof.v_prefill / mean_in * 0.5, 1.0),
            mem_util_target=0.7)
    if name == "blitzscale":
        # Table I: prefiller = avg prefill length / max prefill throughput;
        # decoder = available KVC memory / per-request footprint
        return BlitzScalePolicy(
            req_per_prefiller=max(prof.v_prefill / mean_in * 0.5, 1.0),
            req_per_decoder=max(prof.max_batch.get("M-M", 45) * 0.6, 4.0))
    raise ValueError(name)


def run_policy(policy_name: str, trace_name: str = "mixed",
               model: str = "llama31_8b", chip: str = "a100", tp: int = 1,
               duration: float = 120.0, rps: float = 8.0, seed: int = 0,
               n_convertible: int = 1, predictor_accuracy: float = 0.85,
               dt: float = 0.025,
               prof: Optional[VelocityProfile] = None,
               engine: str = "fluid",
               preemption: str = "none",
               priority_mix: Optional[dict] = None,
               max_instances: int = 64) -> SimReport:
    cfg = get_config(model)
    inst = InstanceSpec(CHIPS[chip], tp=tp)
    prof = prof or profile(cfg, inst)
    trace = get_trace(trace_name, duration, rps, seed,
                      priority_mix=priority_mix)
    mean_in = (sum(r.in_len for r in trace) / max(len(trace), 1)) or 1024.0
    mean_out = (sum(r.out_len for r in trace) / max(len(trace), 1)) or 240.0
    policy = make_policy(policy_name, prof, n_convertible, mean_in, mean_out)
    conv_cfg = plan_convertible(
        cfg, inst, expected_decode_batch=max(
            prof.max_batch.get("M-M", 16) // 2, 1),
        avg_ctx=1200.0, burst_ratio=0.2, max_decoders=8)
    n_conv = n_convertible if policy_name == "tokenscale" else 0
    cl = get_engine(engine)(
        cfg, inst, prof, policy,
        predictor=OutputPredictor(predictor_accuracy, seed),
        conv_cfg=conv_cfg, n_convertible=n_conv, dt=dt,
        preemption=preemption, max_instances=max_instances)
    rep = cl.run(trace, duration + 30.0)
    return rep


def compare_policies(trace_name: str = "mixed", model: str = "llama31_8b",
                     chip: str = "a100", tp: int = 1,
                     duration: float = 120.0, rps: float = 8.0,
                     seed: int = 0,
                     engine: str = "fluid") -> dict[str, SimReport]:
    cfg = get_config(model)
    inst = InstanceSpec(CHIPS[chip], tp=tp)
    prof = profile(cfg, inst)
    out = {}
    for name in ["tokenscale", "distserve", "aibrix", "blitzscale"]:
        out[name] = run_policy(name, trace_name, model, chip, tp,
                               duration, rps, seed, prof=prof, engine=engine)
    return out


def compare_engines(policy_name: str, trace_name: str = "mixed",
                    duration: float = 60.0, rps: float = 8.0,
                    seed: int = 0, **kw) -> dict[str, SimReport]:
    """Differential validation helper: the same trace + policy through both
    engines (tests/test_sim_differential.py asserts their agreement)."""
    return {name: run_policy(policy_name, trace_name, duration=duration,
                             rps=rps, seed=seed, engine=name, **kw)
            for name in ENGINES}
