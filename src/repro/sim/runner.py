"""Experiment runner: execute a declarative ``ExperimentSpec`` (fleet +
trace routing + policy + engine + preemption) end-to-end, the way §V
configures its experiments.

``run_spec`` is the single entry point: it resolves the spec's pools into
runtime ``Fleet`` objects (velocity profile per (model, chip, tp) pool,
Eq. 5-6 convertible plan per convertible pool), generates one trace per
model route, builds the policy per model group through the string-keyed
registry (``core.autoscaler.build_policy``), and drives either engine.
Heterogeneous fleets (mixed chips/TP across pools) and multi-model
serving are just specs; the legacy single-pool helpers ``run_policy`` /
``make_policy`` survive as thin shims over one-pool specs and remain
byte-stable with the pre-pool control plane (the golden fixtures enforce
this).
"""
from __future__ import annotations

from typing import Optional

from repro.configs import get_config
from repro.core import (CHIPS, ExperimentSpec, InstanceSpec, OutputPredictor,
                        PerModelFleetPolicy, build_policy,
                        default_convertible_plan, profile_for,
                        single_pool_fleet)
from repro.core.fleet import (FLEET_POLICY_REGISTRY, FleetSpec, PoolSpec,
                              TraceRoute, build_fleet_policy)
from repro.core.velocity import VelocityProfile
from repro.sim.cluster import Cluster, SimReport
from repro.sim.events import EventCluster
from repro.sim.instances import Fleet, Pool
from repro.sim.traces import TraceRequest, get_trace, trace_stats

#: engine name -> cluster class; both drive the identical control plane.
ENGINES = {"fluid": Cluster, "events": EventCluster}

#: seed decorrelation between a spec's model routes (route 0 keeps the
#: spec seed verbatim so one-route specs reproduce legacy traces exactly)
_ROUTE_SEED_STRIDE = 7919


def get_engine(name: str):
    try:
        return ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; expected one of {sorted(ENGINES)}")


# ---------------------------------------------------------------------------
# Spec resolution
# ---------------------------------------------------------------------------

def build_fleet(fs: FleetSpec,
                profiles: Optional[dict[str, VelocityProfile]] = None,
                max_decoders: Optional[int] = None) -> Fleet:
    """Resolve a declarative ``FleetSpec`` into a runtime ``Fleet``: each
    pool gets its own model config, instance spec, (cached) velocity
    profile, and — for convertible pools — an Eq. 5-6 restriction planned
    against that pool's own hardware.  ``profiles`` overrides profiling
    per pool name (e.g. the int8-KV what-if in ``benchmarks.run.kv8``);
    ``max_decoders`` feeds the §IV-C2 offline pool sizing (defaults to
    the historical 8-decoder fleet when the caller has no scale cap)."""
    pools = []
    for ps in fs.pools:
        cfg = get_config(ps.model)
        inst = InstanceSpec(CHIPS[ps.chip], tp=ps.tp)
        prof = (profiles or {}).get(ps.name) \
            or profile_for(ps.model, ps.chip, ps.tp,
                           hbm_frac=ps.hbm_frac)
        conv = default_convertible_plan(
            cfg, inst, prof, max_decoders=max_decoders or 8) \
            if ps.role == "convertible" else None
        pools.append(Pool(ps, cfg, inst, prof, conv_cfg=conv))
    return Fleet(pools)


def build_traces(spec: ExperimentSpec) -> list[TraceRequest]:
    """One trace per model route, each request tagged with its model.
    Route 0 uses the spec seed verbatim (single-route specs reproduce the
    legacy ``run_policy`` arrivals byte-for-byte); later routes draw from
    decorrelated seed streams.  Multi-route traces are merged by arrival
    time and renumbered like the paper's Mixed workload."""
    if not spec.fleet.routes:
        raise ValueError("ExperimentSpec needs at least one TraceRoute")
    parts = []
    for i, route in enumerate(spec.fleet.routes):
        part = get_trace(route.trace, spec.duration, route.rps,
                         spec.seed + _ROUTE_SEED_STRIDE * i,
                         priority_mix=route.priority_mix,
                         session_prob=route.session_prob,
                         shared_prefix_prob=route.shared_prefix_prob,
                         shared_prefix_len=route.shared_prefix_len,
                         shared_prefix_count=route.shared_prefix_count)
        for r in part:
            r.model = route.model
        parts.append(part)
    if len(parts) == 1:
        return parts[0]
    merged = [r for part in parts for r in part]
    merged.sort(key=lambda r: r.t)
    for i, r in enumerate(merged):
        r.rid = i
    return merged


def run_spec(spec: ExperimentSpec,
             profiles: Optional[dict[str, VelocityProfile]] = None
             ) -> SimReport:
    """The pool-centric entry point: heterogeneous fleets and multi-model
    serving run end-to-end on either engine from one declarative spec."""
    fleet = build_fleet(spec.fleet, profiles,
                        max_decoders=spec.max_instances)
    trace = build_traces(spec)
    if spec.policy in FLEET_POLICY_REGISTRY:
        # fleet-native planner: sees the whole spec + one profile per
        # pool, plans all pools jointly (same-role pool sets, cross-model
        # spill, drain-based scale-down)
        fpolicy = build_fleet_policy(
            spec.policy, spec.fleet,
            {name: pool.prof for name, pool in fleet.pools.items()},
            **spec.policy_options)
    else:
        policies = {}
        for model, g in fleet.groups.items():
            stats = trace_stats(
                [r for r in trace
                 if (r.model or fleet.default_model) == model])
            policies[model] = build_policy(
                spec.policy, g.prefill.prof, decode_prof=g.decode.prof,
                mean_in=stats.mean_in, mean_out=stats.mean_out,
                n_convertible=g.convertible.spec.init if g.convertible
                else 0,
                **spec.policy_options)
        fpolicy = PerModelFleetPolicy(policies)
    cl = get_engine(spec.engine)(
        fleet, policy=fpolicy,
        predictor=OutputPredictor(spec.predictor_accuracy, spec.seed),
        dt=spec.dt, preemption=spec.preemption,
        max_instances=spec.max_instances,
        snapshot_interval=spec.snapshot_interval,
        faults=spec.faults)
    if spec.telemetry:
        # flight recorder (repro.obs): pure observer attached before the
        # run so every hook site sees it; the default-off path above never
        # imports the package
        from repro.obs import FlightRecorder
        cl.attach_obs(FlightRecorder(meta={
            "policy": spec.policy, "seed": spec.seed,
            "preemption": spec.preemption,
            "routes": [{"model": r.model, "trace": r.trace, "rps": r.rps}
                       for r in spec.fleet.routes],
        }))
    return cl.run(trace, spec.duration + spec.extra_horizon)


def hetero_demo_spec(duration: float = 30.0, rps: float = 6.0,
                     seed: int = 0, engine: str = "fluid",
                     policy: str = "tokenscale") -> ExperimentSpec:
    """The canonical heterogeneous-fleet scenario (shared by the smoke
    bench, the golden fixture regenerator, and the differential tests):
    a100-TP2 prefillers feed h100-TP1 decoders plus one h100 Convertible
    Decoder — prefill and decode pools with different chips, TP degrees,
    and therefore different Token Velocities."""
    return ExperimentSpec(
        fleet=FleetSpec(
            pools=(
                PoolSpec("pre-a100", "prefill", "llama31_8b", "a100", tp=2),
                PoolSpec("dec-h100", "decode", "llama31_8b", "h100", tp=1),
                PoolSpec("conv-h100", "convertible", "llama31_8b", "h100",
                         tp=1, init=1),
            ),
            routes=(TraceRoute("llama31_8b", "azure_conv", rps=rps),)),
        policy=policy, engine=engine, duration=duration, seed=seed)


# ---------------------------------------------------------------------------
# Legacy single-pool shims (thin wrappers over one-pool specs)
# ---------------------------------------------------------------------------

def make_policy(name: str, prof: VelocityProfile, n_convertible: int = 1,
                mean_in: Optional[float] = None,
                mean_out: Optional[float] = None,
                trace: Optional[list[TraceRequest]] = None):
    """§V Baselines, via the policy registry.  Threshold derivations
    follow Table I's recipes: request-based thresholds = stage capacity /
    mean request size, with the safety factors the respective papers use
    (which is exactly why they overprovision after bursts, §VI-A).

    ``mean_in``/``mean_out`` must come from the *actual* workload — pass
    them explicitly or pass ``trace=`` to derive them here
    (``sim.traces.trace_stats``); the historical hardcoded 1024/240
    defaults mis-calibrated baselines on skewed traces."""
    if trace is not None:
        stats = trace_stats(trace)
        mean_in = stats.mean_in if mean_in is None else mean_in
        mean_out = stats.mean_out if mean_out is None else mean_out
    if mean_in is None or mean_out is None:
        raise ValueError(
            "make_policy needs the workload's request-size stats: pass "
            "mean_in/mean_out or trace= (see sim.traces.trace_stats)")
    return build_policy(name, prof, decode_prof=prof, mean_in=mean_in,
                        mean_out=mean_out, n_convertible=n_convertible)


def run_policy(policy_name: str, trace_name: str = "mixed",
               model: str = "llama31_8b", chip: str = "a100", tp: int = 1,
               duration: float = 120.0, rps: float = 8.0, seed: int = 0,
               n_convertible: int = 1, predictor_accuracy: float = 0.85,
               dt: float = 0.025,
               prof: Optional[VelocityProfile] = None,
               engine: str = "fluid",
               preemption: str = "none",
               priority_mix: Optional[dict] = None,
               max_instances: int = 64,
               session_prob: float = 0.0,
               block_size: int = 0,
               hbm_frac: float = 0.9,
               offload_gb: Optional[float] = None,
               prefix_cache: bool = False,
               prefill_chunking: int = 0,
               gateway: bool = False,
               kv_alloc: str = "reserve",
               shared_prefix_prob: float = 0.0,
               shared_prefix_len: int = 512,
               shared_prefix_count: int = 8,
               telemetry: bool = False,
               faults: Optional[dict] = None) -> SimReport:
    """The classic single-pool experiment, desugared to a one-pool spec.
    Kept byte-stable with the pre-pool control plane (golden fixtures).
    The KV-tier knobs (``block_size``/``hbm_frac``/``offload_gb``/
    ``prefix_cache``, sim.kvcache), the multi-turn ``session_prob``, the
    chunked-prefill/deflection knob ``prefill_chunking``, the locality
    gateway (``gateway``/``kv_alloc``, core.gateway) and the Zipf shared-
    prompt workload knobs (``shared_prefix_*``, sim.traces) default to
    the legacy flat-byte-counter, single-turn, wholesale-conversion,
    owner-steered behavior.  ``faults`` (a ``sim.faults.FaultConfig``
    dict) arms the chaos engine; None keeps the run fault-free and
    byte-identical."""
    n_conv = n_convertible if policy_name == "tokenscale" else 0
    fleet_spec = single_pool_fleet(model, chip, tp, trace=trace_name,
                                   rps=rps, n_convertible=n_conv,
                                   priority_mix=priority_mix,
                                   session_prob=session_prob,
                                   block_size=block_size,
                                   hbm_frac=hbm_frac,
                                   offload_gb=offload_gb,
                                   prefix_cache=prefix_cache,
                                   prefill_chunking=prefill_chunking,
                                   gateway=gateway,
                                   kv_alloc=kv_alloc,
                                   shared_prefix_prob=shared_prefix_prob,
                                   shared_prefix_len=shared_prefix_len,
                                   shared_prefix_count=shared_prefix_count)
    spec = ExperimentSpec(
        fleet=fleet_spec, policy=policy_name, engine=engine,
        preemption=preemption, duration=duration, seed=seed, dt=dt,
        predictor_accuracy=predictor_accuracy, max_instances=max_instances,
        telemetry=telemetry, faults=faults)
    profiles = {p.name: prof for p in fleet_spec.pools} if prof else None
    return run_spec(spec, profiles=profiles)


def compare_policies(trace_name: str = "mixed", model: str = "llama31_8b",
                     chip: str = "a100", tp: int = 1,
                     duration: float = 120.0, rps: float = 8.0,
                     seed: int = 0,
                     engine: str = "fluid") -> dict[str, SimReport]:
    prof = profile_for(model, chip, tp)
    out = {}
    for name in ["tokenscale", "distserve", "aibrix", "blitzscale"]:
        out[name] = run_policy(name, trace_name, model, chip, tp,
                               duration, rps, seed, prof=prof, engine=engine)
    return out


def compare_engines(policy_name: str, trace_name: str = "mixed",
                    duration: float = 60.0, rps: float = 8.0,
                    seed: int = 0, **kw) -> dict[str, SimReport]:
    """Differential validation helper: the same trace + policy through both
    engines (tests/test_sim_differential.py asserts their agreement)."""
    return {name: run_policy(policy_name, trace_name, duration=duration,
                             rps=rps, seed=seed, engine=name, **kw)
            for name in ENGINES}
