"""Seeded, deterministic fault injection for the sim engines.

ROADMAP item 5: failure injection as first-class events — instance crash
+ warm-restart, straggler chips, degraded swap bandwidth, KVC-transfer
link loss — so Token Velocity's leading-indicator claim is tested on a
fleet that silently loses capacity, not just a healthy one.

Determinism contract
--------------------
The schedule is drawn *before* the run from one independent RNG
substream (``sim.traces.substream(seed, SALT_FAULTS)``), so:

  * arrivals (and every other decorator stream) stay byte-identical
    whether faults are on or off — same construction as the priority/
    session/shared-prefix streams;
  * the same ``FaultConfig`` yields the same ``FaultEvent`` list on both
    engines.  Events carry a unit-interval ``pick`` instead of a concrete
    instance id: the *target* is resolved at fire time against the live
    fleet (which may legitimately differ between engines mid-run), and
    the resolution is a pure function of the sorted candidate list — no
    RNG is consumed during the run.

The events engine injects each ``FaultEvent`` as an exact heap event
(``_ev_fault``); the fluid engine drains due events at tick granularity
(DESIGN.md "Fault fidelity").  Everything is default-off: with
``ExperimentSpec.faults`` unset no schedule exists, no per-event work
runs, and goldens reproduce byte-identically.
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields
from typing import Optional

from repro.sim.traces import SALT_FAULTS, substream

#: fault kinds, in schedule-draw order (stable tiebreaker for same-t draws)
FAULT_KINDS = ("crash", "straggler", "swap_degrade", "link_down")


@dataclass
class FaultEvent:
    """One scheduled injection.  ``pick`` selects the target at fire time:
    index = int(pick * len(candidates)) over the live, ready, non-draining
    instances of ``role`` sorted by instance id."""
    t: float
    kind: str                  # one of FAULT_KINDS
    role: str = "decode"       # target role ("prefill" | "decode")
    pick: float = 0.0          # uniform [0, 1) target selector
    dur: float = 0.0           # window length (straggler/swap/link)
    factor: float = 1.0        # velocity / bandwidth multiplier
    jitter: float = 1.0        # warm-restart startup_s multiplier (crash)


@dataclass(frozen=True)
class FaultConfig:
    """The ``ExperimentSpec.faults`` knob, JSON-round-trippable.

    Counts are draws over the injection window ``[t0, t1]`` (``t1``
    defaults to 60% of the horizon so recovery is observable before the
    drain tail).  ``recovery`` gates the *entire* self-healing path:
    health-monitor detection + warm replacement, KVC retry/backoff with
    recompute fallback, crash-resident prefix reuse, and measured
    effective velocity feeding Eq. 2-4.  With it off, faults still fire
    but the control plane is blind — crashed capacity stays on the books
    (the lagging-signal contrast ``--bench=chaos`` measures)."""
    seed: int = 0
    crashes: int = 0
    stragglers: int = 0
    straggler_factor: float = 0.5
    straggler_dur: float = 10.0
    swap_degrades: int = 0
    swap_factor: float = 0.25
    swap_dur: float = 10.0
    link_outages: int = 0
    link_dur: float = 2.0
    t0: float = 5.0
    t1: Optional[float] = None
    recovery: bool = True
    #: health-monitor probe cadence: a crash is *detected* at the next
    #: probe tick, and the replacement boots startup_s * jitter later
    detect_s: float = 1.0
    #: recovery-off client abandon time: crash-lost residents re-enter
    #: the system only after their client times out and resubmits
    client_timeout_s: float = 10.0
    #: KVC-transfer retry ladder during a link outage (recovery on)
    max_retries: int = 4
    backoff0_s: float = 0.25
    #: crash/straggler target roles, in draw order
    roles: tuple = ("prefill", "decode")

    def __post_init__(self):
        for name in ("crashes", "stragglers", "swap_degrades",
                     "link_outages"):
            if getattr(self, name) < 0:
                raise ValueError(f"faults.{name} must be >= 0")
        if not 0.0 < self.straggler_factor <= 1.0:
            raise ValueError("faults.straggler_factor must be in (0, 1]")
        if not 0.0 < self.swap_factor <= 1.0:
            raise ValueError("faults.swap_factor must be in (0, 1]")
        bad = [r for r in self.roles if r not in ("prefill", "decode")]
        if bad:
            raise ValueError(f"faults.roles: unknown roles {bad}")
        object.__setattr__(self, "roles", tuple(self.roles))

    @classmethod
    def from_dict(cls, d: dict) -> "FaultConfig":
        known = {f.name for f in fields(cls)}
        bad = sorted(set(d) - known)
        if bad:
            raise ValueError(f"unknown fault-config keys {bad}; "
                             f"expected a subset of {sorted(known)}")
        return cls(**d)


def build_schedule(cfg: FaultConfig, duration: float) -> list[FaultEvent]:
    """Draw the full, time-sorted injection schedule for one run.  Pure
    function of (config, horizon): one substream, category draws in a
    fixed order, stable sort — both engines replay the identical list."""
    rng = substream(cfg.seed, SALT_FAULTS)
    t1 = cfg.t1 if cfg.t1 is not None else max(cfg.t0, 0.6 * duration)
    span = max(t1 - cfg.t0, 0.0)

    def draw_t() -> float:
        return cfg.t0 + float(rng.random_sample()) * span

    events: list[FaultEvent] = []
    for _ in range(cfg.crashes):
        role = cfg.roles[int(rng.random_sample() * len(cfg.roles))]
        events.append(FaultEvent(
            t=draw_t(), kind="crash", role=role,
            pick=float(rng.random_sample()),
            jitter=0.75 + 0.5 * float(rng.random_sample())))
    for _ in range(cfg.stragglers):
        role = cfg.roles[int(rng.random_sample() * len(cfg.roles))]
        events.append(FaultEvent(
            t=draw_t(), kind="straggler", role=role,
            pick=float(rng.random_sample()),
            dur=cfg.straggler_dur, factor=cfg.straggler_factor))
    for _ in range(cfg.swap_degrades):
        events.append(FaultEvent(
            t=draw_t(), kind="swap_degrade", role="decode",
            pick=float(rng.random_sample()),
            dur=cfg.swap_dur, factor=cfg.swap_factor))
    for _ in range(cfg.link_outages):
        events.append(FaultEvent(
            t=draw_t(), kind="link_down", dur=cfg.link_dur))
    events.sort(key=lambda e: (e.t, FAULT_KINDS.index(e.kind)))
    return events


def pick_target(ev: FaultEvent, candidates: list) -> Optional[object]:
    """Resolve an event's target against the current fleet: the
    ``pick``-indexed entry of the candidate list sorted by instance id.
    Deterministic per engine; ``None`` when no instance is eligible (the
    injection is skipped, counted in ``FaultStats.skipped``)."""
    if not candidates:
        return None
    ordered = sorted(candidates, key=lambda i: i.iid)
    return ordered[min(int(ev.pick * len(ordered)), len(ordered) - 1)]


@dataclass
class FaultStats:
    """Injection + recovery odometers, surfaced as
    ``SimReport.fault_summary()``.  The zero-valued instance defines the
    stable faults-off schema (the PR 9 degradation contract)."""
    crashes: int = 0
    restarts: int = 0
    residents_requeued: int = 0
    prefill_requeued: int = 0
    kvc_retries: int = 0
    kvc_retry_backoff_s: float = 0.0
    kvc_fallbacks: int = 0
    straggler_windows: int = 0
    swap_degrade_windows: int = 0
    link_down_windows: int = 0
    skipped: int = 0

    def summary(self) -> dict:
        return {f.name: getattr(self, f.name) for f in fields(self)}


@dataclass
class HealthMonitor:
    """Snapshot-cadence failure detector: a crash at ``t`` is *noticed*
    at the next probe tick (quantized up, never the same instant), at
    which point the husk leaves the pool books and its warm replacement
    is provisioned — so the autoscaler's Eq. 2-4 view counts the lost
    capacity as demand immediately instead of waiting for queue backlog
    to build (the lagging-signal failure mode ``--bench=chaos`` pins)."""
    cadence: float = 1.0
    detections: int = 0

    def detect_at(self, t_crash: float) -> float:
        k = int(t_crash / self.cadence) + 1
        self.detections += 1
        return k * self.cadence

    def restart_at(self, t_detect: float, startup_s: float,
                   jitter: float) -> float:
        return t_detect + startup_s * jitter
