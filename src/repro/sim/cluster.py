"""Time-stepped (fluid) PD-disaggregated cluster simulator.

This is the stand-in for the paper's 4/16-node GPU clusters: instances are
modeled with the same analytic roofline step-latency model the offline
profiler uses (``core.hardware``), and the control plane under test is the
*real* TokenScale implementation (``core.*``) — policies, router, burst
detector, convertible planning all execute unmodified.

Fidelity choices (documented in DESIGN.md):
  * dt-stepped fluid model (default 25 ms) rather than per-iteration events
    (for faithful per-request tails use ``sim.events.EventCluster``, which
    drives the same instances/control plane with a discrete-event heap);
  * decoders process all resident requests at the batch's iteration time;
  * prefillers serialize requests (batch ~1, §II-C1);
  * KVC transfer delays from interconnect bandwidth;
  * instance startup latency hidden for BlitzScale scale-ups ("ideal live
    autoscaling", §V Baselines).

The instance/roofline/metrics layer shared with the event engine lives in
``sim.instances``, which also holds the pool layer: the ``prefillers``/
``decoders``/``convertibles`` views this loop iterates flatten the
fleet's named pools, so the same tick loop drives heterogeneous
(mixed-chip/TP) and multi-model fleets — per-pool scaling happens in the
shared ``ClusterBase._scale`` executing the policy's ``FleetPlan``.
"""
from __future__ import annotations

from typing import Optional

from repro.sim.instances import (  # noqa: F401  (re-exported for compat)
    ClusterBase, Decoder, Instance, ModelCost, Prefiller, SimReport,
    SimRequest, _ModelCost, _pred_out)
from repro.sim.traces import TraceRequest


class Cluster(ClusterBase):
    """Fluid engine: advances the whole cluster in fixed dt steps, smearing
    queueing and batching across ticks."""

    engine = "fluid"

    def run(self, trace: list[TraceRequest],
            duration: Optional[float] = None) -> SimReport:
        trace = sorted(trace, key=lambda r: r.t)
        t_end = duration or (trace[-1].t + 60.0 if trace else 60.0)
        ti = 0
        t = 0.0
        tick = 0        # exact tick index; float-accumulated t drifts, so
                        # deriving the index as int(t / dt) skips or
                        # duplicates snapshot rows on long traces
        next_scale = 0.0
        # snapshot cadence (0.2 s historically; adaptive past ~13 min so
        # multi-hour traces cap the timeline length — DESIGN.md §Perf)
        snap_mod = max(int(self._snapshot_every(t_end) / self.dt), 1)
        # the fleet only changes inside _scale, so the per-tick GPU count
        # is a cached constant between scale executions
        gpus = self._gpu_count(t)
        if self.obs is not None:
            # trace consumers need the tick granularity to interpret
            # fluid timestamps: arrivals are batched and completions
            # quantized to dt, unlike the event engine's exact stamps
            self.obs.meta.setdefault("dt", self.dt)
            self.obs.meta.setdefault("duration", t_end)
        self._faults_begin(t_end)
        while t < t_end:
            # ---- chaos engine: due fault injections, tick granularity
            # (the event engine schedules them as exact events) ----
            if self._faults_tick(t):
                gpus = self._gpu_count(t)
            # ---- arrivals ----
            while ti < len(trace) and trace[ti].t <= t:
                self._on_arrival(SimRequest(trace[ti]), t)
                ti += 1
            # ---- stage ticks ----
            for pool in self.fleet.role_pools("prefill"):
                for p in pool.instances:
                    for req in p.tick(t, self.dt):
                        self._to_network(req, t, pool)
            for role in ("decode", "convertible"):
                for pool in self.fleet.role_pools(role):
                    for d in pool.instances:
                        self.finished += d.tick(t, self.dt)
            # ---- network -> decoder admission ----
            # (priority-ordered; under HBM backpressure this is also where
            # the fluid approximation of preemption fires: victims leave
            # decode between ticks and re-enter pending_decode after their
            # recompute/swap-in delay.  KV-tier swap completions and
            # prefix-penalty stalls are likewise approximated here at tick
            # granularity — the event engine schedules them as exact
            # swap_done events; DESIGN.md "KV-tier fidelity")
            self._admit_pending(t)
            # ---- retry queued prefills (§IV-E re-evaluation) ----
            self._drain_wait_queue(t)
            # ---- autoscaling ----
            if t >= next_scale:
                self._scale(t)
                next_scale = t + self.scale_interval
                gpus = self._gpu_count(t)
            # ---- accounting ----
            self.gpu_seconds += gpus * self.dt
            if tick % snap_mod == 0:
                self.timeline.append(self._snapshot(t))
            tick += 1
            t += self.dt
        return self._report(t_end)
