from repro.sim.cluster import Cluster, SimReport, SimRequest  # noqa: F401
from repro.sim.events import EventCluster  # noqa: F401
from repro.sim.instances import (  # noqa: F401
    ClusterBase, Decoder, Fleet, ModelCost, ModelGroup, Pool, Prefiller,
    PreemptionPolicy,
)
from repro.sim.kvcache import (  # noqa: F401
    KVAllocator, KVError, KVStats, KVTierConfig,
)
from repro.sim.traces import (  # noqa: F401
    DEFAULT_PRIORITY_MIX, PRIORITY_CLASSES, TRACES, TraceRequest, TraceSpec,
    TraceStats, assign_priorities, assign_sessions, assign_shared_prefixes,
    generate, generate_mixed, get_trace, step_trace, stream_trace,
    trace_stats,
)
from repro.sim.runner import (  # noqa: F401
    ENGINES, build_fleet, build_traces, compare_engines, compare_policies,
    get_engine, make_policy, run_policy, run_spec,
)
