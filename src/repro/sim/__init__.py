from repro.sim.cluster import Cluster, SimReport, SimRequest  # noqa: F401
from repro.sim.traces import (  # noqa: F401
    TRACES, TraceRequest, TraceSpec, generate, generate_mixed, get_trace,
    step_trace,
)
from repro.sim.runner import run_policy, compare_policies  # noqa: F401
