from repro.sim.cluster import Cluster, SimReport, SimRequest  # noqa: F401
from repro.sim.events import EventCluster  # noqa: F401
from repro.sim.instances import (  # noqa: F401
    ClusterBase, Decoder, ModelCost, Prefiller, PreemptionPolicy,
)
from repro.sim.traces import (  # noqa: F401
    DEFAULT_PRIORITY_MIX, PRIORITY_CLASSES, TRACES, TraceRequest, TraceSpec,
    assign_priorities, generate, generate_mixed, get_trace, step_trace,
)
from repro.sim.runner import (  # noqa: F401
    ENGINES, compare_engines, compare_policies, get_engine, run_policy,
)
