"""Synthetic production-like traces (§V Workload Generation).

The paper replays Azure LLM inference traces [35] and BurstGPT [38].  Those
datasets are not available offline, so we generate traces with the published
summary statistics:

  * burstiness: the system is in a burst ~47% of operational time, mean
    burst duration 2.3 s (§I) — modeled as an ON/OFF modulated Poisson
    process (OFF ~ Exp(2.6 s), ON ~ Exp(2.3 s), ON rate multiplier 2-6x);
  * Azure *Conversation*: medium prompts / medium outputs;
  * Azure *Code*: long prompts / short outputs;
  * BurstGPT 1/2: shorter prompts, heavier burst multipliers;
  * *Mixed*: equal-rate mixture (the paper's third workload).

Lengths are lognormal, clipped to the Table II bucket range [32, 8192] /
[16, 640].  Everything is deterministic in the seed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator

import numpy as np


#: priority classes (lower = more urgent); canonical scales live in
#: core.router.  The default mix models a mixed-criticality production
#: tenant: a latency-critical slice, a standard bulk, and batch traffic.
PRIORITY_CLASSES = {"interactive": 0, "standard": 1, "batch": 2}
DEFAULT_PRIORITY_MIX = {0: 0.2, 1: 0.6, 2: 0.2}


@dataclass(slots=True)
class TraceRequest:
    rid: int
    t: float
    in_len: int
    out_len: int
    priority: int = 1          # PRIORITY_CLASSES["standard"]
    model: str = ""            # "" = the fleet's default model; multi-model
                               # fleets tag each request with its route's
                               # model (core.fleet.TraceRoute)
    session: int = -1          # conversation id (-1 = single-turn); set by
                               # assign_sessions
    prefix_len: int = 0        # leading prompt tokens shared with the
                               # session's context (prior prompt + output) —
                               # what the KV prefix cache can reuse
    shared_id: int = -1        # catalog id of the Zipf-popular system
                               # prompt this request opens with (-1 = none);
                               # set by assign_shared_prefixes
    shared_len: int = 0        # leading prompt tokens covered by that
                               # shared system prompt — reusable *across*
                               # sessions (what the gateway hashtrie sees)


@dataclass(frozen=True)
class TraceStats:
    """Request-size statistics of an actual trace — what the baseline
    policies' Table I threshold derivations must be calibrated from
    (hardcoded means mis-calibrate them on skewed traces)."""
    mean_in: float
    mean_out: float
    n: int


def trace_stats(reqs: list[TraceRequest],
                default_in: float = 1024.0,
                default_out: float = 240.0) -> TraceStats:
    """Mean prompt/output lengths of ``reqs`` (falling back to the
    historical Table II-ish defaults only for an empty trace)."""
    mean_in = (sum(r.in_len for r in reqs) / max(len(reqs), 1)) \
        or default_in
    mean_out = (sum(r.out_len for r in reqs) / max(len(reqs), 1)) \
        or default_out
    return TraceStats(mean_in=mean_in, mean_out=mean_out, n=len(reqs))


@dataclass(frozen=True)
class TraceSpec:
    name: str
    in_mean: float            # lognormal mean of prompt tokens
    in_sigma: float
    out_mean: float
    out_sigma: float
    burst_mult_lo: float = 2.0
    burst_mult_hi: float = 6.0
    burst_on_mean: float = 2.3     # §I: mean burst duration
    burst_off_mean: float = 2.6    # -> ~47% of time bursting


TRACES: dict[str, TraceSpec] = {
    "azure_conv": TraceSpec("azure_conv", in_mean=1024, in_sigma=0.9,
                            out_mean=240, out_sigma=0.7),
    "azure_code": TraceSpec("azure_code", in_mean=2048, in_sigma=0.8,
                            out_mean=80, out_sigma=0.6,
                            burst_mult_lo=2.0, burst_mult_hi=4.0),
    "burstgpt1": TraceSpec("burstgpt1", in_mean=512, in_sigma=1.0,
                           out_mean=300, out_sigma=0.8,
                           burst_mult_lo=3.0, burst_mult_hi=8.0),
    "burstgpt2": TraceSpec("burstgpt2", in_mean=640, in_sigma=1.1,
                           out_mean=350, out_sigma=0.9,
                           burst_mult_lo=4.0, burst_mult_hi=10.0),
}


def _lognormal(rng, mean, sigma, lo, hi, size):
    mu = np.log(mean) - sigma ** 2 / 2.0
    return np.clip(rng.lognormal(mu, sigma, size), lo, hi).astype(int)


#: prime salts for the independent RNG substreams layered over a seeded
#: trace.  Each decorating pass (and the fault injector) derives its own
#: stream from the base seed + a distinct prime, so turning any knob on
#: never perturbs the arrival times or lengths — nor any *other* knob's
#: draws — of an existing trace.
SALT_PRIORITY = 104729
SALT_SESSION = 15485863
SALT_SHARED_PREFIX = 2750159
SALT_FAULTS = 6291469


def substream(seed: int, salt: int) -> np.random.RandomState:
    """An RNG stream independent of the base trace stream (and of every
    other salt's stream): ``RandomState((seed + salt) % 2**31)``.  The
    construction is part of the byte-identical-goldens contract — all
    existing decorator streams were built exactly this way, so routing
    them through this helper changes no draw."""
    return np.random.RandomState((seed + salt) % (2 ** 31))


def assign_priorities(reqs: list[TraceRequest],
                      priority_mix: dict[int, float] | None,
                      seed: int = 0) -> list[TraceRequest]:
    """Draw per-request priority classes in place.  The draw uses an
    *independent* RNG stream, so adding a mix never perturbs the arrival
    times or lengths of an existing seeded trace."""
    if not priority_mix:
        return reqs
    classes = sorted(priority_mix)
    w = np.array([priority_mix[c] for c in classes], dtype=float)
    w /= w.sum()
    rng = substream(seed, SALT_PRIORITY)
    draws = rng.choice(len(classes), size=len(reqs), p=w)
    for r, k in zip(reqs, draws):
        r.priority = int(classes[k])
    return reqs


def assign_sessions(reqs: list[TraceRequest], session_prob: float,
                    seed: int = 0, think_s: float = 2.0,
                    max_open: int = 64) -> list[TraceRequest]:
    """Group arrivals into multi-turn sessions in place (§V conversational
    workloads): each request joins an open session with probability
    ``session_prob`` — its prompt then *extends the shared prefix* (prior
    prompt + response), recorded as ``prefix_len`` — or opens a new one.

    Only ``session``/``prefix_len`` are written: arrival times and lengths
    stay byte-identical, and the draw uses an *independent* RNG stream
    (like ``assign_priorities``), so adding the knob never perturbs an
    existing seeded trace.  A session is joinable once its previous turn is
    at least ``think_s`` old (user think time); at most ``max_open``
    sessions stay joinable (oldest retired first)."""
    if session_prob <= 0.0:
        return reqs
    rng = substream(seed, SALT_SESSION)
    open_sessions: list[list] = []   # [sid, last_t, kv_len]
    next_sid = 0
    for r in sorted(reqs, key=lambda r: (r.t, r.rid)):
        ready = [s for s in open_sessions if r.t - s[1] >= think_s]
        if ready and rng.uniform() < session_prob:
            s = ready[rng.randint(len(ready))]
            r.session = s[0]
            # the follow-up prompt extends the session context; a shorter
            # drawn prompt is simply fully covered by it
            r.prefix_len = min(s[2], r.in_len)
        else:
            r.session, r.prefix_len = next_sid, 0
            next_sid += 1
            open_sessions.append([r.session, r.t, 0])
            if len(open_sessions) > max_open:
                open_sessions.pop(0)
            s = open_sessions[-1]
        # next turn's shared context = this prompt + this response
        s[1], s[2] = r.t, r.in_len + r.out_len
    return reqs


def assign_shared_prefixes(reqs: list[TraceRequest], prob: float,
                           seed: int = 0, prefix_len: int = 512,
                           n_prompts: int = 8,
                           zipf_a: float = 1.2) -> list[TraceRequest]:
    """Mark arrivals as opening with a Zipf-popular system prompt in place
    — the cross-session prefix reuse today's per-session chains cannot
    express (one hot system prompt shared by *many* conversations, the
    workload the KV-locality gateway routes on).

    A catalog of ``n_prompts`` system prompts is drawn once (lengths
    around ``prefix_len``); each conversation opener (or sessionless
    arrival) starts from catalog prompt ``k`` with probability ``prob``,
    ``k`` Zipf-distributed with exponent ``zipf_a`` so a couple of
    prompts dominate.  Follow-up turns inherit their opener's prompt (a
    conversation keeps its system prompt).  Only ``shared_id`` /
    ``shared_len`` are written, and the draw uses an *independent* RNG
    stream (like ``assign_priorities`` / ``assign_sessions``), so adding
    the knob never perturbs an existing seeded trace."""
    if prob <= 0.0:
        return reqs
    rng = substream(seed, SALT_SHARED_PREFIX)
    lens = rng.randint(max(prefix_len // 2, 1),
                       prefix_len + prefix_len // 2 + 1, size=n_prompts)
    w = 1.0 / np.arange(1, n_prompts + 1) ** zipf_a
    w /= w.sum()
    by_session: dict[int, tuple[int, int]] = {}   # sid -> (pid, eff_len)
    for r in sorted(reqs, key=lambda r: (r.t, r.rid)):
        if r.session >= 0 and r.session in by_session:
            pid, eff = by_session[r.session]      # follow-up: inherit
        elif rng.uniform() < prob:
            pid = int(rng.choice(n_prompts, p=w))
            eff = int(lens[pid])
        else:
            pid, eff = -1, 0
        if r.session >= 0 and r.session not in by_session:
            by_session[r.session] = (pid, eff)
        if pid >= 0:
            r.shared_id = pid
            r.shared_len = min(eff, r.in_len)
    return reqs


def burst_phases(spec: TraceSpec, duration_s: float,
                 rng) -> list[tuple[float, float, float]]:
    """The ON/OFF burst timeline as (start, end, rate-multiplier) phases.
    Long-run ON duty cycle is on_mean / (on_mean + off_mean) — ~47% with
    the paper's 2.3 s / 2.6 s constants (§I)."""
    t, phases = 0.0, []
    while t < duration_s:
        off = rng.exponential(spec.burst_off_mean)
        on = rng.exponential(spec.burst_on_mean)
        mult = rng.uniform(spec.burst_mult_lo, spec.burst_mult_hi)
        phases.append((t, t + off, 1.0))
        phases.append((t + off, t + off + on, mult))
        t += off + on
    return phases


def generate(spec: TraceSpec, duration_s: float, rps: float,
             seed: int = 0,
             priority_mix: dict[int, float] | None = None,
             session_prob: float = 0.0,
             shared_prefix_prob: float = 0.0,
             shared_prefix_len: int = 512,
             shared_prefix_count: int = 8
             ) -> list[TraceRequest]:
    """ON/OFF modulated Poisson arrivals with lognormal lengths."""
    rng = np.random.RandomState(seed)
    phases = burst_phases(spec, duration_s, rng)
    # thinning: draw at the max rate, accept by local multiplier
    max_mult = spec.burst_mult_hi
    base = rps / (1.0 + 0.47 * (spec.burst_mult_lo + spec.burst_mult_hi) / 2.0
                  - 0.47)  # normalize so the long-run average ~= rps
    base = max(base, 0.1)
    lam = base * max_mult
    n_candidates = rng.poisson(lam * duration_s)
    times = np.sort(rng.uniform(0, duration_s, n_candidates))
    mults = np.ones_like(times)
    for (s, e, m) in phases:
        mults[(times >= s) & (times < e)] = m
    accept = rng.uniform(0, max_mult, len(times)) < mults
    times = times[accept]
    n = len(times)
    ins = _lognormal(rng, spec.in_mean, spec.in_sigma, 32, 8192, n)
    outs = _lognormal(rng, spec.out_mean, spec.out_sigma, 16, 640, n)
    reqs = [TraceRequest(i, float(times[i]), int(ins[i]), int(outs[i]))
            for i in range(n)]
    assign_priorities(reqs, priority_mix, seed)
    assign_sessions(reqs, session_prob, seed)
    return assign_shared_prefixes(reqs, shared_prefix_prob, seed,
                                  prefix_len=shared_prefix_len,
                                  n_prompts=shared_prefix_count)


def generate_mixed(duration_s: float, rps: float, seed: int = 0,
                   priority_mix: dict[int, float] | None = None,
                   session_prob: float = 0.0,
                   shared_prefix_prob: float = 0.0,
                   shared_prefix_len: int = 512,
                   shared_prefix_count: int = 8
                   ) -> list[TraceRequest]:
    """The paper's Mixed trace: conv + code + BurstGPT 1/2 at equal rates."""
    parts = []
    for i, name in enumerate(["azure_conv", "azure_code",
                              "burstgpt1", "burstgpt2"]):
        parts += generate(TRACES[name], duration_s, rps / 4.0, seed + i,
                          priority_mix=priority_mix)
    parts.sort(key=lambda r: r.t)
    for i, r in enumerate(parts):
        r.rid = i
    # sessions (and shared prompts) are drawn over the merged arrival order
    # (conversations are a property of the workload, not of one component
    # trace)
    assign_sessions(parts, session_prob, seed)
    return assign_shared_prefixes(parts, shared_prefix_prob, seed,
                                  prefix_len=shared_prefix_len,
                                  n_prompts=shared_prefix_count)


def get_trace(name: str, duration_s: float = 120.0, rps: float = 8.0,
              seed: int = 0,
              priority_mix: dict[int, float] | None = None,
              session_prob: float = 0.0,
              shared_prefix_prob: float = 0.0,
              shared_prefix_len: int = 512,
              shared_prefix_count: int = 8
              ) -> list[TraceRequest]:
    kw = dict(priority_mix=priority_mix, session_prob=session_prob,
              shared_prefix_prob=shared_prefix_prob,
              shared_prefix_len=shared_prefix_len,
              shared_prefix_count=shared_prefix_count)
    if name == "mixed":
        return generate_mixed(duration_s, rps, seed, **kw)
    return generate(TRACES[name], duration_s, rps, seed, **kw)


def stream_trace(name: str, duration_s: float, rps: float, seed: int = 0,
                 chunk_s: float = 300.0,
                 priority_mix: dict[int, float] | None = None
                 ) -> Iterator[TraceRequest]:
    """Yield arrivals in time order without materializing the whole trace.

    Million-request, multi-hour workloads (``benchmarks/perf.py``'s
    perfscale suite) would hold the entire request list — and, with the
    historical eager pre-push, the entire event heap — in memory at once.
    This generator produces the workload in ``chunk_s``-long windows:
    each chunk is an independent seeded ``generate`` (seed stream
    ``seed + 31 * i``) shifted to its window start, so the stream is
    deterministic in ``seed``, has the same ON/OFF burst structure and
    lognormal lengths per window, and the consumer (``EventCluster.run``
    feeds arrivals lazily) keeps only live requests resident.

    Request ids are globally sequential.  Note the chunk boundary resets
    the burst phase (each window draws its own ON/OFF timeline) — fine
    for throughput/scale benches; use ``generate`` when a single
    continuous burst process matters."""
    spec = TRACES[name]
    rid = 0
    t0 = 0.0
    i = 0
    while t0 < duration_s:
        horizon = min(chunk_s, duration_s - t0)
        part = generate(spec, horizon, rps, seed + 31 * i,
                        priority_mix=priority_mix)
        for r in part:
            r.rid = rid
            r.t += t0
            rid += 1
            yield r
        t0 += horizon
        i += 1


def varying_rate_trace(segments: list[tuple[float, float]],
                       spec: TraceSpec = TRACES["azure_conv"],
                       seed: int = 0,
                       priority_mix: dict[int, float] | None = None,
                       session_prob: float = 0.0,
                       shared_prefix_prob: float = 0.0,
                       shared_prefix_len: int = 512,
                       shared_prefix_count: int = 8
                       ) -> list[TraceRequest]:
    """Piecewise-rate workload (large-scale load swings; used by the
    provisioned-vs-required correlation study, Fig. 11)."""
    out: list[TraceRequest] = []
    t0 = 0.0
    for i, (dur, rps) in enumerate(segments):
        part = generate(spec, dur, rps, seed + 7 * i)
        for r in part:
            r.t += t0
        out += part
        t0 += dur
    out.sort(key=lambda r: r.t)
    for i, r in enumerate(out):
        r.rid = i
    assign_priorities(out, priority_mix, seed)
    assign_sessions(out, session_prob, seed)
    return assign_shared_prefixes(out, shared_prefix_prob, seed,
                                  prefix_len=shared_prefix_len,
                                  n_prompts=shared_prefix_count)


def step_trace(duration_s: float, base_rps: float, burst_rps: float,
               burst_start: float, burst_len: float,
               spec: TraceSpec = TRACES["azure_conv"],
               seed: int = 0,
               priority_mix: dict[int, float] | None = None,
               session_prob: float = 0.0,
               shared_prefix_prob: float = 0.0,
               shared_prefix_len: int = 512,
               shared_prefix_count: int = 8
               ) -> list[TraceRequest]:
    """Deterministic-rate step trace (Fig. 10: 1 -> 10 RPS at t=10 s)."""
    rng = np.random.RandomState(seed)
    reqs, t, rid = [], 0.0, 0
    while t < duration_s:
        rate = burst_rps if burst_start <= t < burst_start + burst_len \
            else base_rps
        t += rng.exponential(1.0 / rate)
        if t >= duration_s:
            break
        in_len = int(_lognormal(rng, spec.in_mean, spec.in_sigma,
                                32, 8192, 1)[0])
        out_len = int(_lognormal(rng, spec.out_mean, spec.out_sigma,
                                 16, 640, 1)[0])
        reqs.append(TraceRequest(rid, t, in_len, out_len))
        rid += 1
    assign_priorities(reqs, priority_mix, seed)
    assign_sessions(reqs, session_prob, seed)
    return assign_shared_prefixes(reqs, shared_prefix_prob, seed,
                                  prefix_len=shared_prefix_len,
                                  n_prompts=shared_prefix_count)
