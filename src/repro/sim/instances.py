"""Shared simulator substrate: instances, roofline costs, metrics, control
plane.

Both cluster engines — the dt-stepped *fluid* model (``sim.cluster``) and
the discrete-*event* model (``sim.events``) — are thin drivers over this
module.  Everything here is engine-agnostic:

  * ``ModelCost``     — cached roofline constants per model (one cost model);
  * ``Prefiller`` / ``Decoder`` — instance state, memory accounting,
    iteration-time roofline, convertible-prefill progress;
  * ``SimRequest`` / ``SimReport`` — per-request timestamps and the SLO
    metrics pipeline (one metrics pipeline);
  * ``ClusterBase``   — the control-plane glue that executes the *real*
    TokenScale implementation (``core.autoscaler``, ``core.router``,
    ``core.convertible``) unmodified: arrival routing (Alg. 1), wait-queue
    re-evaluation (§IV-E), Observation construction, and scaling.

Engines differ only in how they advance time (see DESIGN.md).

Performance (DESIGN.md "Performance"): the hot-path aggregates on
``Decoder``/``Prefiller`` (``mem_used``, ``iter_time``, inflight-token
totals, per-bucket/per-class resident counts) are *cached with dirty-flag
invalidation*, never incrementally-drifted floats: a cache is dropped on
any membership/length change and the next read re-runs the identical
from-scratch reduction, so every value is bit-for-bit what the seed code
computed (the golden fixtures pin this).  Integer counters (bucket/class
residency) are maintained incrementally because integer arithmetic is
exact.  ``check_aggregates`` re-derives everything from first principles
— the perf-invariant fuzz (tests/test_perf_invariants.py) calls it after
every operation, mirroring ``KVAllocator.check``.
"""
from __future__ import annotations

import heapq
from bisect import bisect_right, insort
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import hardware as hw
from repro.core.autoscaler import Observation, Policy, TokenScalePolicy
from repro.core.convertible import ConvertibleConfig
from repro.core.fleet import (FleetObservation, FleetPolicy, GatewayStats,
                              PerModelFleetPolicy, PoolSnapshot, PoolSpec,
                              flat_observation)
from repro.core.gateway import (Gateway, GatewayConfig, RoutingStats,
                                prefix_chain)
from repro.core.hardware import InstanceSpec
from repro.core.predictor import OutputPredictor
from repro.core.router import (PRIORITY_STANDARD, BurstDetector, Router,
                               tpot_slo, ttft_slo)
from repro.core.velocity import (BUCKET_OUTPUT, VelocityProfile, bucket_of,
                                 chunked_prefill_velocity,
                                 deflected_prefill_rate,
                                 headroom_chunk_tokens)
from repro.sim.faults import (FaultConfig, FaultStats, HealthMonitor,
                              build_schedule, pick_target)
from repro.sim.kvcache import KVAllocator, KVStats, KVTierConfig

#: chunked prefill: minimum per-iteration progress (tokens) once a chunk
#: queue exists on a decoder whose batch has no Eq. 5 headroom left — the
#: DynaServe-style starvation guard; without it a saturated batch could
#: park deflected prompts indefinitely.  Kept small so the TPOT overshoot
#: it can cause is bounded by ~64 tokens' roofline cost per iteration.
MIN_DEFLECT_CHUNK = 64


@dataclass(slots=True)
class SimRequest:
    src: "TraceRequest"  # noqa: F821  (sim.traces.TraceRequest)
    bucket_pred: str = ""
    t_prefill_start: float = -1.0
    t_prefill_end: float = -1.0
    t_kv_ready: float = -1.0
    t_first_token: float = -1.0
    t_decode_start: float = -1.0
    t_finish: float = -1.0
    generated: float = 0.0
    decode_time: float = 0.0
    n_evictions: int = 0       # times preempted out of a decoder
    # ---- KV-tier state (sim.kvcache; all None/0 when tiers are off) ----
    kv_hit_tokens: int = 0     # prompt tokens reused from a cached prefix
    kv_prefix: Optional[tuple] = None   # (owner decoder, tokens, tier) pin
    kv_swap: Optional[object] = None    # allocator holding our DRAM ticket
    # Alg. 1 round 2b: the decoder this prompt was deflected to.  Its KV
    # is produced on that box, so admission stays there (deflection
    # affinity in ``_admit_pending``) instead of re-entering bucket-aware
    # load balancing; cleared if the target leaves the fleet.
    deflect_tgt: Optional[object] = None
    # ---- hot-path caches (immutable trace facts, resolved once: the
    # preemption scans touch .priority millions of times per run) ----
    priority: int = field(init=False, repr=False, compare=False, default=1)
    session: int = field(init=False, repr=False, compare=False, default=-1)
    model: str = field(init=False, repr=False, compare=False, default="")
    # admission-generation stamp issued by Decoder.admit: the event engine
    # grants an iteration's token only to requests admitted before the
    # iteration started (and not evicted/re-admitted since)
    _res_gen: int = field(init=False, repr=False, compare=False, default=0)

    def __post_init__(self):
        src = self.src
        self.priority = getattr(src, "priority", PRIORITY_STANDARD)
        self.session = getattr(src, "session", -1)
        # "" = the fleet's default model
        self.model = getattr(src, "model", "")

    @property
    def prefill_tokens(self) -> float:
        """Prompt tokens the prefill stage must actually compute (the
        cached-prefix hit is served from the KV tier)."""
        return float(self.src.in_len - self.kv_hit_tokens)

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.src.t

    @property
    def tpot(self) -> float:
        if self.src.out_len <= 1 or self.t_finish < 0:
            return 0.0
        return self.decode_time / max(self.src.out_len, 1)

    @property
    def bucket_true(self) -> str:
        return bucket_of(self.src.in_len, self.src.out_len)


@dataclass
class ModelCost:
    """Cached per-model roofline constants for the hot loop."""
    flops_tok: float
    kv_tok: float
    state_fix: float
    w_bytes: float
    aw_bytes: float
    attn_coef: float          # 4*H*Dh summed over attn layers

    @classmethod
    def of(cls, cfg: ModelConfig):
        return cls(
            flops_tok=hw.flops_per_token(cfg),
            kv_tok=hw.kv_bytes_per_token(cfg),
            state_fix=hw.state_bytes_fixed(cfg),
            w_bytes=hw.weight_bytes(cfg),
            aw_bytes=hw.active_weight_bytes(cfg),
            attn_coef=hw.attn_flops_per_token(cfg, 1.0))


# Backwards-compatible alias (pre-refactor name in sim.cluster).
_ModelCost = ModelCost


@dataclass(frozen=True)
class PreemptionPolicy:
    """Decode-side HBM backpressure handling (DESIGN.md §1).

      none          — KV-ready requests wait in ``pending_decode`` until a
                      decoder frees memory (pre-PR-2 behavior);
      evict-lowest  — the lowest-priority resident request is evicted, its
                      KV dropped; re-admission pays a full recomputation of
                      the context at prefill velocity;
      evict-least-slack — SLO-aware victim selection (the ROADMAP's
                      deadline-based preemption): the victim is the
                      resident with the lowest deadline slack — arrival +
                      per-class TTFT/TPOT SLO budget, minus the estimated
                      remaining decode time — i.e. the request most likely
                      to miss its SLO anyway; KV dropped like evict-lowest;
      pause-requeue — the victim's KV is swapped out and restored on
                      re-admission: to the host-DRAM tier at the chip's
                      swap bandwidth when the pool runs the paged KV
                      subsystem (``sim.kvcache``; recompute fallback when
                      the tier is full), over the interconnect otherwise.

    Victims are always *strictly* lower priority than the request being
    admitted, so high-priority work is never displaced by lower classes.
    """

    mode: str = "none"

    MODES = ("none", "evict-lowest", "evict-least-slack", "pause-requeue")

    def __post_init__(self):
        if self.mode not in self.MODES:
            raise ValueError(
                f"unknown preemption mode {self.mode!r}; "
                f"expected one of {self.MODES}")

    @property
    def enabled(self) -> bool:
        return self.mode != "none"

    @classmethod
    def of(cls, x) -> "PreemptionPolicy":
        return x if isinstance(x, cls) else cls(x or "none")


def _entry_priority(entry: tuple) -> int:
    return entry[0].priority


def _priority_insert(queue: list, entry: tuple):
    """Insert a (request, remaining) entry behind the (possibly
    in-progress) head, ahead of queued work of strictly lower priority.
    Within a class the order stays FIFO.

    The tail ``queue[1:]`` is always sorted by priority (head-protected
    inserts keep it that way, and only heads are ever popped), so the
    historical linear scan is a bisect: the insertion point it finds is
    identical, at O(log n) comparisons instead of O(n)."""
    j = bisect_right(queue, entry[0].priority, lo=1 if queue else 0,
                     key=_entry_priority)
    queue.insert(j, entry)


class Instance:
    def __init__(self, iid: int, inst: InstanceSpec, cost: ModelCost,
                 ready_t: float):
        self.iid = iid
        self.spec = inst
        self.cost = cost
        self.ready_t = ready_t
        self.draining = False
        # True while the instance belongs to a pool; cleared on scale-down
        # removal.  Replaces the historical ``inst in self.decoders +
        # self.convertibles`` list-concat membership probes on the event
        # hot path (O(pools + instances) per event) with an O(1) flag.
        self.live = True
        # flight recorder (repro.obs.FlightRecorder) or None = telemetry
        # off.  Set by ClusterBase._spawn / attach_obs; the tick paths
        # only ever test it for None, so disabled telemetry costs one
        # attribute test and cannot perturb float math or event order.
        self.obs = None
        # effective-velocity multiplier (sim.faults straggler windows);
        # 1.0 = nominal chip.  Decoders fold it into the iteration
        # roofline (guarded, so the nominal path is bitwise unchanged);
        # prefillers scale v_p directly and keep this as the marker the
        # fleet observation reads (PoolSnapshot.eff_perf).
        self.perf = 1.0

    def ready(self, t: float) -> bool:
        return t >= self.ready_t


class Prefiller(Instance):
    def __init__(self, iid, inst, cost, ready_t, v_prefill: float):
        super().__init__(iid, inst, cost, ready_t)
        self.v_p = v_prefill
        self.queue: list[tuple[SimRequest, float]] = []   # (req, remaining)
        self._inflight_cache: Optional[float] = None

    def inflight_tokens(self) -> float:
        # cached, invalidated on any queue mutation; the recompute runs
        # the identical reduction, so the value is bit-for-bit stable
        v = self._inflight_cache
        if v is None:
            v = self._inflight_cache = sum(r for _, r in self.queue)
        return v

    def prefill_velocity(self) -> float:
        return self.v_p

    def submit(self, req: SimRequest, t: float):
        if req.t_prefill_start < 0:
            req.t_prefill_start = t
        _priority_insert(self.queue, (req, req.prefill_tokens))
        self._inflight_cache = None

    def advance(self, budget: float) -> list[SimRequest]:
        """Serialized head-of-line progress by `budget` tokens; returns
        requests whose prefill completed."""
        done = []
        if self.queue and budget > 0:
            self._inflight_cache = None
        while self.queue and budget > 0:
            req, rem = self.queue[0]
            take = min(rem, budget)
            rem -= take
            budget -= take
            if rem <= 1e-9:
                self.queue.pop(0)
                done.append(req)
            else:
                self.queue[0] = (req, rem)
        return done

    def tick(self, t: float, dt: float) -> list[SimRequest]:
        """Fluid engine: advance by dt; return completed prefills."""
        if not self.ready(t):
            return []
        return self.advance(self.v_p * dt)

    def check_aggregates(self):
        """Invariant audit (mirrors ``KVAllocator.check``): the cached
        inflight-token total must equal the from-scratch reduction."""
        if self._inflight_cache is not None:
            expect = sum(r for _, r in self.queue)
            if self._inflight_cache != expect:
                raise AssertionError(
                    f"prefiller {self.iid}: inflight cache drift "
                    f"{self._inflight_cache} != {expect}")
        tail = [e[0].priority for e in self.queue[1:]]
        if tail != sorted(tail):
            raise AssertionError(
                f"prefiller {self.iid}: queue tail not priority-sorted")

    @property
    def idle(self) -> bool:
        return not self.queue


class Decoder(Instance):
    is_convertible = False

    def __init__(self, iid, inst, cost, ready_t,
                 conv: Optional[ConvertibleConfig] = None):
        super().__init__(iid, inst, cost, ready_t)
        self.active: list[SimRequest] = []
        self.conv = conv
        self.prefill_q: list[tuple[SimRequest, float]] = []
        # chunked prefill (PoolSpec.prefill_chunking, set by _spawn):
        # tokens-per-chunk cap; 0 = legacy wholesale conversion.  The
        # event engine records the chunk it planned for the in-flight
        # iteration in _iter_chunk and advances the queue by exactly that
        # budget when the iteration completes (exact chunk boundaries).
        self.chunking = 0
        self._iter_chunk = 0.0
        # KV-tier state (sim.kvcache): None keeps the legacy flat byte
        # counter byte-for-byte; ClusterBase._spawn attaches an allocator
        # when the pool sets block_size > 0
        self.kv: Optional[KVAllocator] = None
        self.hbm_frac = 0.9
        # on-box convertible completions that found no blocks free wait
        # here for the shared pending_decode path (kv mode only)
        self.kv_spill: list[tuple[float, SimRequest]] = []
        # ---- gateway / lazy paging (PoolSpec.gateway / kv_alloc) ----
        # lazy: admission reserves context + 1 token instead of the full
        # predicted output; blocks grow per generated token (grow_lazy)
        self.lazy = False
        self.gateway: Optional[Gateway] = None      # model group's gateway
        self.gw_stats: Optional[RoutingStats] = None
        # residents whose per-token block grow found no HBM free: the
        # cluster resolves them (retry / preempt) in _admit_pending
        self.oom_pending: list[SimRequest] = []
        # ---- hot-path aggregates (DESIGN.md "Performance") ----
        # float aggregates are dirty-flag caches over the identical
        # from-scratch reduction (bitwise-stable); integer residency
        # counters are maintained incrementally (integer math is exact)
        self._mem_cache: Optional[float] = None     # mem_used (legacy path)
        self._iter_cache: Optional[float] = None    # iter_time
        self._pq_cache: Optional[float] = None      # inflight prefill toks
        self._cap_cache: Optional[float] = None     # mem_cap (constant)
        self._bucket_counts: dict[str, int] = {}    # bucket -> residents
        self._prio_counts: dict[int, int] = {}      # class -> residents
        # Σ (in_len + generated) over the batch, maintained incrementally
        # while every contribution is a whole number (always true in the
        # event engine: prompts are ints, tokens land one at a time) —
        # integer-valued float adds are exact and order-independent, so
        # this equals the sequential reduction bit-for-bit.  The first
        # fractional fluid tick flips ``_ctx_exact`` and iter_time falls
        # back to the cached from-scratch sum.
        self._ctx_sum = 0.0
        self._ctx_exact = True
        # admission-generation stamps (event engine's iteration membership)
        self._admit_seq = 0
        self._iter_gen = 0

    # ---- aggregate bookkeeping ----
    def _invalidate(self):
        """Drop the float caches: active membership or a resident's
        context length changed."""
        self._mem_cache = None
        self._iter_cache = None

    def _count_add(self, req: SimRequest):
        bc = self._bucket_counts
        bc[req.bucket_pred] = bc.get(req.bucket_pred, 0) + 1
        pc = self._prio_counts
        pc[req.priority] = pc.get(req.priority, 0) + 1
        if self._ctx_exact:
            c = req.src.in_len + req.generated
            if float(c).is_integer():
                self._ctx_sum += c
            else:
                self._ctx_exact = False

    def _count_remove(self, req: SimRequest):
        bc = self._bucket_counts
        n = bc.get(req.bucket_pred, 0) - 1
        if n <= 0:
            bc.pop(req.bucket_pred, None)
        else:
            bc[req.bucket_pred] = n
        pc = self._prio_counts
        n = pc.get(req.priority, 0) - 1
        if n <= 0:
            pc.pop(req.priority, None)
        else:
            pc[req.priority] = n
        if self._ctx_exact:
            c = req.src.in_len + req.generated
            if float(c).is_integer():
                self._ctx_sum -= c
            else:
                self._ctx_exact = False

    def remove_active(self, req: SimRequest):
        """The one sanctioned way to pull a resident request out of the
        batch (preemption): keeps the residency counters and caches in
        step with ``active``."""
        self.active.remove(req)
        self._count_remove(req)
        self._invalidate()

    def max_resident_priority(self) -> int:
        """Lowest-urgency (highest-value) priority class resident right
        now, or -1 with an empty batch — the preemption fast path skips
        decoders with no strictly-lower-priority victims without scanning
        the batch."""
        pc = self._prio_counts
        return max(pc) if pc else -1

    def check_aggregates(self):
        """Invariant audit (mirrors ``KVAllocator.check``): every cached
        aggregate must equal its from-scratch recomputation."""
        c = self.cost
        if self.kv is None and self._mem_cache is not None:
            expect = sum((r.src.in_len + r.generated) * c.kv_tok
                         + c.state_fix for r in self.active)
            if self._mem_cache != expect:
                raise AssertionError(
                    f"decoder {self.iid}: mem_used cache drift "
                    f"{self._mem_cache} != {expect}")
        if self._pq_cache is not None:
            expect = sum(rem for _, rem in self.prefill_q)
            if self._pq_cache != expect:
                raise AssertionError(
                    f"decoder {self.iid}: inflight-token cache drift "
                    f"{self._pq_cache} != {expect}")
        if self._ctx_exact:
            expect = sum(r.src.in_len + r.generated for r in self.active)
            if self._ctx_sum != expect:
                raise AssertionError(
                    f"decoder {self.iid}: ctx-sum drift "
                    f"{self._ctx_sum} != {expect}")
        if self._iter_cache is not None:
            cached = self._iter_cache
            self._iter_cache = None
            fresh = self.iter_time()
            if cached != fresh:
                raise AssertionError(
                    f"decoder {self.iid}: iter_time cache drift "
                    f"{cached} != {fresh}")
        for bucket in {r.bucket_pred for r in self.active}:
            expect_n = sum(1 for r in self.active
                           if r.bucket_pred == bucket)
            if self._bucket_counts.get(bucket, 0) != expect_n:
                raise AssertionError(
                    f"decoder {self.iid}: bucket count drift for "
                    f"{bucket!r}")
        if sum(self._bucket_counts.values()) != len(self.active):
            raise AssertionError(
                f"decoder {self.iid}: bucket counts don't cover the batch")
        prio_expect: dict[int, int] = {}
        for r in self.active:
            prio_expect[r.priority] = prio_expect.get(r.priority, 0) + 1
        if self._prio_counts != prio_expect:
            raise AssertionError(
                f"decoder {self.iid}: priority counts drift "
                f"{self._prio_counts} != {prio_expect}")

    # ---- memory ----
    def mem_used(self) -> float:
        if self.kv is not None:
            return self.kv.used_bytes()
        m = self._mem_cache
        if m is None:
            c = self.cost
            m = self._mem_cache = sum(
                (r.src.in_len + r.generated) * c.kv_tok + c.state_fix
                for r in self.active)
        return m

    def mem_cap(self) -> float:
        # constant once the decoder is provisioned (hbm_frac/convertible
        # role are assigned before first use); computed lazily once
        v = self._cap_cache
        if v is None:
            reserve = self.conv.mem_reserved if (self.is_convertible
                                                 and self.conv) else 0.0
            v = self._cap_cache = self.spec.hbm_cap * self.hbm_frac \
                - self.cost.w_bytes - reserve
        return v

    def mem_util(self) -> float:
        return min(self.mem_used() / max(self.mem_cap(), 1.0), 1.5)

    def _need_bytes(self, req: SimRequest) -> float:
        """Full-length KV reservation for one request."""
        c = self.cost
        return (req.src.in_len + req.src.out_len) * c.kv_tok + c.state_fix

    def _admit_bytes(self, req: SimRequest) -> float:
        """Admission-time KV reservation: the full-length reservation, or
        — under allocate-on-generate paging (``PoolSpec.kv_alloc="lazy"``)
        — just the context so far plus one token's slack; the rest grows
        per generated token (``grow_lazy``) and exhaustion is handled by
        mid-decode preemption instead of being reserved away up front."""
        if not self.lazy:
            return self._need_bytes(req)
        c = self.cost
        return (req.src.in_len + req.generated + 1.0) * c.kv_tok \
            + c.state_fix

    def can_admit(self, req: SimRequest) -> bool:
        if self.kv is not None:
            return self.kv.can_admit(req.src.rid, self._admit_bytes(req))
        return self.mem_used() + self._admit_bytes(req) <= self.mem_cap()

    def inflight_of_bucket(self, bucket: str) -> int:
        # incrementally-maintained integer residency counter (exact)
        return self._bucket_counts.get(bucket, 0)

    # ---- convertible prefill (Alg. 1 round 2 target) ----
    def inflight_tokens(self) -> float:
        v = self._pq_cache
        if v is None:
            v = self._pq_cache = sum(rem for _, rem in self.prefill_q)
        return v

    def prefill_velocity(self) -> float:
        return self.conv.v_prefill if self.conv else 0.0

    def submit_prefill(self, req: SimRequest, t: float):
        if req.t_prefill_start < 0:
            req.t_prefill_start = t
        _priority_insert(self.prefill_q, (req, req.prefill_tokens))
        self._pq_cache = None
        self._iter_cache = None    # mixed-iteration term keys off prefill_q

    def advance_prefill(self, budget: float, t: float) -> list[SimRequest]:
        """Restricted-velocity convertible prefill (Eq. 5); completed
        requests transition seamlessly to decode on the same instance.
        Returns the requests that completed prefill.  With the paged KV
        subsystem the on-box admission is no longer unconditional: when no
        blocks are free the request spills to ``pending_decode`` (drained
        by ``ClusterBase._admit_pending``) instead of overcommitting."""
        done = []
        if self.prefill_q and budget > 0:
            self._pq_cache = None
            self._iter_cache = None
        while self.prefill_q and budget > 0:
            req, rem = self.prefill_q[0]
            take = min(rem, budget)
            rem -= take
            budget -= take
            if rem <= 1e-9:
                self.prefill_q.pop(0)
                req.t_prefill_end = t
                req.t_kv_ready = t        # on-box: no KVC transfer
                done.append(req)
                if self.obs is not None:
                    # on-box prefill completion odometer (prefiller-side
                    # completions are counted in ClusterBase._to_network)
                    self.obs.prefill_tokens_done += req.prefill_tokens
                if self.kv is not None and not self.can_admit(req):
                    self.kv_spill.append((t, req))
                else:
                    self.admit(req, t)
            else:
                self.prefill_q[0] = (req, rem)
        return done

    # ---- decode ----
    def admit(self, req: SimRequest, t: float):
        # t_decode_start survives preemption round-trips; t_first_token is
        # stamped by the engines when the first decode iteration *completes*
        # (end of first iter_done / first tick), not at admission — stamping
        # here would make TTFT one full iteration optimistic
        if req.t_decode_start < 0:
            req.t_decode_start = t
        if req.kv_swap is not None:
            # the paused victim is back in HBM: release its DRAM ticket on
            # whichever allocator swapped it out
            req.kv_swap.swap_in_release(req.src.rid)
            req.kv_swap = None
        kp = req.kv_prefix
        if kp is not None and (kp[0] is not self or self.kv is None
                               or kp[2] != "hbm"):
            # admitted away from the prefix owner without passing through
            # the cluster's penalty path (on-box convertible admission):
            # migrate the prefix over the owner's interconnect, the stall
            # charged to decode time (DESIGN.md "KV-tier fidelity")
            owner, tokens, _tier = kp
            if owner.kv is not None:
                owner.kv.unpin(req.src.rid)
                req.decode_time += owner.kv.migration_stall(
                    tokens, owner.spec.chip.net_bw)
            req.kv_prefix = None
        if self.kv is not None:
            # consumes this request's pin (CoW-shared prefix blocks), if
            # the pin lives on this decoder
            self.kv.admit(req.src.rid, self._admit_bytes(req))
            req.kv_prefix = None
        self.active.append(req)
        self._admit_seq += 1
        req._res_gen = self._admit_seq
        self._count_add(req)
        self._invalidate()

    def _kv_release(self, req: SimRequest, t: float):
        """Free the finished request's blocks, leaving its prompt+output
        prefix cached under its session for follow-up reuse.  With the
        gateway on, the shared system prompt is additionally aliased under
        its fleet-wide key (cross-session reuse) and this decoder is
        marked as a holder in the fleet's prefix hashtrie."""
        if self.kv is None:
            return
        gw = self.gateway
        src = req.src
        ctx = int(src.in_len + req.generated)
        if gw is not None:
            shid = getattr(src, "shared_id", -1)
            shlen = getattr(src, "shared_len", 0)
            if shid >= 0 and shlen > 0:
                # alias before release: the allocation's blocks are live
                self.kv.cache_alias(("sys", shid), src.rid, shlen, t)
        self.kv.release(src.rid, req.session, ctx, t)
        if gw is not None:
            chain = prefix_chain(
                getattr(src, "shared_id", -1),
                getattr(src, "shared_len", 0),
                req.session, ctx, gw.block_size)
            if chain:
                gw.trie.insert(chain, self, t, gw.block_size)

    def grow_lazy(self, t: float):
        """Allocate-on-generate: after an iteration's tokens land, extend
        each resident's blocks to cover its next token.  A resident whose
        grow finds no HBM (even after reclaiming cached prefixes) joins
        ``oom_pending`` for the cluster to resolve — the model carries at
        most one unbacked token per resident until then (the event engine
        resolves it before the next iteration is scheduled; the fluid
        engine at tick granularity)."""
        kv, st = self.kv, self.gw_stats
        for r in self.active:
            if r.t_finish >= 0:
                continue
            added = kv.try_grow(r.src.rid, self._admit_bytes(r))
            if added is None:
                st.grow_failures += 1
                if r not in self.oom_pending:
                    self.oom_pending.append(r)
            elif added:
                st.block_grows += added

    def iter_time(self) -> float:
        it = self._iter_cache
        if it is None:
            it = self._iter_cache = self._iter_time_fresh()
        return it

    def _iter_terms(self) -> tuple[float, float]:
        """(FLOPs, bytes) of one decode-only iteration over the current
        batch — the roofline numerators shared by ``_iter_time_fresh`` and
        the chunked-prefill mixed-iteration math."""
        b = len(self.active)
        c = self.cost
        if b == 0:
            return 0.0, c.aw_bytes
        if self._ctx_exact:
            # integer-exact running total == the sequential sum, bitwise
            avg_ctx = self._ctx_sum / b
        else:
            avg_ctx = sum(r.src.in_len + r.generated
                          for r in self.active) / b
        mem = c.aw_bytes + b * (c.kv_tok * avg_ctx + c.state_fix)
        f = b * (c.flops_tok + c.attn_coef * avg_ctx)
        return f, mem

    def _iter_time_fresh(self) -> float:
        b = len(self.active)
        if b == 0:
            return 0.0
        f, mem = self._iter_terms()
        if self.is_convertible and self.prefill_q and self.conv \
                and not self.chunking:
            # legacy wholesale conversion — mixed iteration: the chunk
            # occupies (chunk - batch) extra slots.  (Chunked mode charges
            # the actually-planned chunk via mixed_iter_time instead.)
            c = self.cost
            chunk = self.conv.chunk_size
            f += max(chunk - b, 0) * c.flops_tok
            mem += max(chunk - b, 0) * c.kv_tok
        it = max(mem / self.spec.hbm_bw, f / self.spec.flops)
        if self.perf != 1.0:
            # straggler chip (sim.faults): the whole roofline slows by
            # the effective-velocity factor for the window's duration
            it /= self.perf
        return it

    # ---- chunked prefill (per-iteration co-scheduling) ----
    def mixed_iter_time(self, chunk_tok: float) -> float:
        """Iteration time with ``chunk_tok`` prefill tokens co-scheduled
        next to the current decode batch (the chunk streams its KV writes
        and linear FLOPs through the same roofline).  With an empty batch
        this is the chunk-only iteration: weights still stream once."""
        if not self.active and chunk_tok <= 0:
            return 0.0
        c = self.cost
        f, mem = self._iter_terms()
        f += chunk_tok * c.flops_tok
        mem += chunk_tok * c.kv_tok
        it = max(mem / self.spec.hbm_bw, f / self.spec.flops)
        if self.perf != 1.0:
            it /= self.perf
        return it

    def _tpot_budget(self) -> float:
        """Eq. 5's TPOT budget for the *strictest* resident class (the
        chunk must not push any resident past its own SLO); the global
        default paces chunk-only iterations so admissions never wait
        longer than one TPOT-scale boundary."""
        pc = self._prio_counts
        return tpot_slo(min(pc)) if pc else tpot_slo()

    def _headroom_chunk(self) -> float:
        """Online Eq. 5: the largest chunk (whole tokens, capped by the
        pool's configured chunk size) the next iteration can co-schedule
        while staying within ``_tpot_budget``.  0 when the batch alone
        already exceeds the budget."""
        cap = float(self.chunking)
        if self.conv is not None:
            cap = min(cap, float(self.conv.chunk_size))
        if cap <= 0:
            return 0.0
        c = self.cost
        f, mem = self._iter_terms()
        return headroom_chunk_tokens(
            f, mem, c.flops_tok, c.kv_tok, self.spec.flops,
            self.spec.hbm_bw, self._tpot_budget(), cap)

    def plan_chunk(self) -> float:
        """The chunk the next iteration will actually execute: Eq. 5
        headroom, floored at ``MIN_DEFLECT_CHUNK`` (starvation guard —
        queued prompts always make progress, even against a batch with no
        headroom) and capped by the work actually queued."""
        if not self.chunking or not self.prefill_q:
            return 0.0
        c = max(self._headroom_chunk(), float(MIN_DEFLECT_CHUNK))
        return min(c, self.inflight_tokens())

    def deflect_velocity(self) -> float:
        """Mixed-iteration slack as an absorption rate (tok/s): the Eq. 5
        headroom chunk over the mixed iteration that would execute it.
        Advertises 0 when the batch has less than the minimum chunk of
        headroom — the router never *adds* deflected work to a decoder
        that could only serve it through the starvation floor."""
        if not self.chunking:
            return 0.0
        c = self._headroom_chunk()
        if c < MIN_DEFLECT_CHUNK:
            return 0.0
        return chunked_prefill_velocity(c, self.mixed_iter_time(c))

    #: batches at least this large take the vectorized fluid-tick path;
    #: numpy's per-call overhead beats the Python loop beyond it.  Both
    #: paths run the identical per-element IEEE-double operations, so the
    #: results are bitwise equal either way (goldens + differential pin it)
    _VEC_MIN_BATCH = 24

    def tick(self, t: float, dt: float) -> list[SimRequest]:
        """Fluid engine: advance decode (and convertible prefill) by dt.
        Returns finished requests.  ``generated`` is clamped at ``out_len``
        (no memory-accounting overshoot) and the final tick is prorated, so
        a request finishing mid-tick is billed only the fraction of the
        tick it actually decoded.  Large batches advance through numpy
        (elementwise, same float ops as the scalar loop)."""
        if not self.ready(t):
            return []
        finished: list[SimRequest] = []
        it_mix = 0.0
        if self.chunking and self.prefill_q:
            # per-tick approximation of chunk-interleaved execution: one
            # planned chunk per mixed iteration, so queued prefill advances
            # at chunk/iter tok/s while decode is paced by the same mixed
            # iteration (the event engine runs the exact chunk boundaries)
            chunk = self.plan_chunk()
            if chunk > 0:
                it_mix = self.mixed_iter_time(chunk)
                if it_mix > 0:
                    self.advance_prefill(chunk * dt / it_mix, t)
        elif self.is_convertible and self.prefill_q and self.conv:
            self.advance_prefill(self.conv.v_prefill * dt, t)
        it = it_mix if it_mix > 0 else self.iter_time()
        if it <= 0:
            return finished
        rate = dt / it                     # tokens per request this tick
        b = len(self.active)
        if self.obs is not None and rate > 0:
            # decode-token odometer: read-only pre-pass over the residents
            # *before* the grant loop mutates ``generated`` — telemetry-on
            # only, so the default path pays one attribute test per tick
            self.obs.decode_tokens_done += sum(
                min(rate, max(r.src.out_len - r.generated, 0.0))
                for r in self.active)
        self._invalidate()                 # every resident's length moves
        self._ctx_exact = False            # fluid grants fractional tokens
        if b >= self._VEC_MIN_BATCH:
            out_len = np.fromiter((r.src.out_len for r in self.active),
                                  np.float64, b)
            gen = np.fromiter((r.generated for r in self.active),
                              np.float64, b)
            remaining = np.maximum(out_len - gen, 0.0)
            take = np.minimum(rate, remaining)
            frac = take / rate if rate > 0 else np.zeros(b)
            dt_spent = dt * frac
            new_gen = gen + take
            first = new_gen >= 1.0 - 1e-9
            done = (remaining - take) <= 1e-9
            t_evt = t + dt_spent
            for i, r in enumerate(self.active):
                r.generated = float(new_gen[i])
                r.decode_time += float(dt_spent[i])
                if r.t_first_token < 0 and first[i]:
                    r.t_first_token = float(t_evt[i])
                if done[i]:
                    r.generated = float(r.src.out_len)
                    r.t_finish = float(t_evt[i])
                    finished.append(r)
        else:
            for r in self.active:
                remaining = max(r.src.out_len - r.generated, 0.0)
                take = min(rate, remaining)
                frac = take / rate if rate > 0 else 0.0
                r.generated += take
                r.decode_time += dt * frac
                if r.t_first_token < 0 and r.generated >= 1.0 - 1e-9:
                    # end of the tick in which the first token completed
                    r.t_first_token = t + dt * frac
                if remaining - take <= 1e-9:
                    r.generated = float(r.src.out_len)
                    r.t_finish = t + dt * frac
                    finished.append(r)
        for r in finished:
            self._kv_release(r, r.t_finish)
        if finished:
            self.active = [r for r in self.active if r.t_finish < 0]
            for r in finished:
                self._count_remove(r)
        if self.lazy and self.kv is not None and self.active:
            self.grow_lazy(t)
        return finished

    @property
    def idle(self) -> bool:
        # a decoder whose prefix cache is pinned by in-flight arrivals is
        # not scale-down-safe even with no resident work
        return not self.active and not self.prefill_q and not self.kv_spill \
            and not (self.kv is not None and self.kv.busy)


# ---------------------------------------------------------------------------
# Pools & fleets (runtime side of core.fleet's declarative specs)
# ---------------------------------------------------------------------------

@dataclass
class Pool:
    """One named pool of identical instances, with its spec resolved to
    runtime objects: model config, instance spec, velocity profile, cost
    constants, and (for convertible pools) the Eq. 5-6 restriction."""
    spec: PoolSpec
    cfg: ModelConfig
    inst: InstanceSpec
    prof: VelocityProfile
    conv_cfg: Optional[ConvertibleConfig] = None
    cost: Optional[ModelCost] = None
    instances: list = field(default_factory=list)

    def __post_init__(self):
        if self.cost is None:
            self.cost = ModelCost.of(self.cfg)


class ModelGroup:
    """One model's pools (at least one prefill + one decode — same-role
    pool *sets* — and at most one convertible) plus its own router/burst-
    detector: burst detection and Alg. 1 routing are per model, so one
    tenant's spike never routes another tenant's traffic to the wrong
    Convertible Decoders.

    The first-declared pool of each role is the model's *primary* pool
    (``self.prefill`` / ``self.decode``): per-model policy plumbing and
    the legacy single-pool aliases see exactly that one, so single-pool
    fleets behave byte-identically.  Routing and admission candidates
    span the full sets."""

    def __init__(self, model: str, prefill_pools: list[Pool],
                 decode_pools: list[Pool], convertible: Optional[Pool]):
        self.model = model
        self.prefill_pools = list(prefill_pools)
        self.decode_pools = list(decode_pools)
        self.prefill = self.prefill_pools[0]
        self.decode = self.decode_pools[0]
        self.convertible = convertible
        self.router = Router(BurstDetector())
        # locality gateway (core.gateway) — built by ClusterBase when any
        # of this model's decode-side pools sets PoolSpec.gateway
        self.gateway: Optional[Gateway] = None
        # deflection (Alg. 1 round 2b) is enabled per model by a decode
        # pool's chunking knob; convertible pools with chunking keep their
        # round-2 slot but execute chunk-interleaved instead of wholesale
        self.deflect_on = any(p.spec.prefill_chunking > 0
                              for p in self.decode_pools)
        # decode_instances() is probed per (pending request, pass) on the
        # admission path; pool membership only changes inside
        # ClusterBase._scale, which drops these caches
        self._decode_cache: Optional[list] = None
        self._prefill_cache: Optional[list] = None

    def conv_instances(self) -> list:
        return self.convertible.instances if self.convertible else []

    def prefill_instances(self) -> list:
        """All prefill-role instances across the pool set.  Single-pool
        groups return the pool's own (live) list — the historical
        aliasing — multi-pool groups a cached flattening."""
        if len(self.prefill_pools) == 1:
            return self.prefill.instances
        v = self._prefill_cache
        if v is None:
            v = self._prefill_cache = [i for p in self.prefill_pools
                                       for i in p.instances]
        return v

    def deflect_instances(self) -> list:
        """Round-2b candidates: instances of decode pools with chunking on
        (the convertibles are already round-2 targets)."""
        if not self.deflect_on:
            return []
        if len(self.decode_pools) == 1:
            return self.decode.instances
        return [i for p in self.decode_pools
                if p.spec.prefill_chunking > 0 for i in p.instances]

    def decode_instances(self) -> list:
        v = self._decode_cache
        if v is None:
            if len(self.decode_pools) == 1:
                v = self.decode.instances + self.conv_instances()
            else:
                v = [i for p in self.decode_pools for i in p.instances] \
                    + self.conv_instances()
            self._decode_cache = v
        return v


class Fleet:
    """Runtime fleet: named ``Pool``s in declaration order + per-model
    groups.  ``sim.runner.build_fleet`` resolves a declarative
    ``core.fleet.FleetSpec`` into one of these; the legacy single-pool
    constructor path builds one inline."""

    def __init__(self, pools: list[Pool]):
        self.pools: dict[str, Pool] = {}
        for p in pools:
            if p.spec.name in self.pools:
                raise ValueError(f"duplicate pool name {p.spec.name!r}")
            self.pools[p.spec.name] = p
        models: list[str] = []
        for p in pools:
            if p.spec.model not in models:
                models.append(p.spec.model)
        self.groups: dict[str, ModelGroup] = {}
        for m in models:
            mine = [p for p in pools if p.spec.model == m]
            pre = [p for p in mine if p.spec.role == "prefill"]
            dec = [p for p in mine if p.spec.role == "decode"]
            conv = [p for p in mine if p.spec.role == "convertible"]
            if not pre or not dec or len(conv) > 1:
                raise ValueError(
                    f"model {m!r}: need at least one prefill and one decode "
                    f"pool and at most one convertible pool, got "
                    f"{[p.spec.name for p in mine]}")
            self.groups[m] = ModelGroup(m, pre, dec,
                                        conv[0] if conv else None)
        self.default_model = models[0]

    def role_pools(self, role: str) -> list[Pool]:
        return [p for p in self.pools.values() if p.spec.role == role]


# ---------------------------------------------------------------------------
# Metrics pipeline (§V) — shared by both engines
# ---------------------------------------------------------------------------

@dataclass
class SimReport:
    name: str
    requests: list[SimRequest]
    gpu_seconds: float
    duration: float
    timeline: list[dict] = field(default_factory=list)
    engine: str = "fluid"
    # (t, victim_priority, preemptor_priority, victim_generated) rows
    preemptions: list[tuple] = field(default_factory=list)
    # KV-tier counters (sim.kvcache.KVStats.summary(); {} when tiers off)
    kv: dict = field(default_factory=dict)
    # gateway routing/replication/lazy-paging counters
    # (core.gateway.RoutingStats.summary(); {} when no pool enables the
    # gateway or lazy paging — kept separate from ``kv`` so the kvtiers
    # golden's pinned schema never changes)
    gw: dict = field(default_factory=dict)
    # chaos-engine injection/recovery counters
    # (sim.faults.FaultStats.summary(); {} when faults are off)
    faults: dict = field(default_factory=dict)
    # events processed by the run (event engine; 0 for fluid) — the
    # perf-bench suite's events/sec numerator (benchmarks/perf.py)
    n_events: int = 0
    # prompts the router deflected to regular decoders (Alg. 1 round 2b;
    # 0 with chunking off)
    n_deflected: int = 0
    # dollar-weighted billing integral (ChipSpec.cost_per_hour x TP per
    # provisioned instance-second — the weighted analog of gpu_seconds)
    # and its per-pool breakdown; the --bench=pareto cost axis
    cost_dollars: float = 0.0
    pool_cost: dict = field(default_factory=dict)
    # flight recorder (repro.obs.FlightRecorder) carrying the run's span
    # trace / metrics samples / decision log; None unless the run was
    # built with ExperimentSpec.telemetry on
    obs: Optional[object] = None

    # ---- SLO metrics (§V) ----
    # Every metric optionally restricts to one priority class and/or one
    # model (multi-model fleets) and/or the preempted slice; SLO targets
    # are per-class (core.router.ttft_slo / tpot_slo).
    # Filtered views and per-metric value vectors are memoized per filter
    # key (reports are read-only once a run ends): bench tables that probe
    # many percentiles over the same slice extract and sort each slice
    # once instead of per metric.

    def _pool(self, priority: Optional[int] = None,
              model: Optional[str] = None,
              preempted: Optional[bool] = None) -> list[SimRequest]:
        cache = self.__dict__.setdefault("_pool_cache", {})
        key = (priority, model, preempted)
        reqs = cache.get(key)
        if reqs is None:
            reqs = self.requests
            if priority is not None:
                reqs = [r for r in reqs if r.priority == priority]
            if model is not None:
                reqs = [r for r in reqs if r.model == model]
            if preempted is not None:
                reqs = [r for r in reqs if (r.n_evictions > 0) == preempted]
            cache[key] = reqs
        return reqs

    def _finished_vals(self, what: str, priority: Optional[int],
                       model: Optional[str], preempted: Optional[bool]
                       ) -> tuple[np.ndarray, np.ndarray]:
        """(values-in-request-order, sorted-values) for one metric over
        one filtered slice.  ``mean`` consumes the former (numpy's pairwise
        sum is order-sensitive, and the seed code averaged in request
        order); percentiles consume the latter — an order statistic is
        order-blind, so sorting once per (metric, slice) is free."""
        cache = self.__dict__.setdefault("_vals_cache", {})
        key = (what, priority, model, preempted)
        out = cache.get(key)
        if out is None:
            vals = [getattr(r, what)
                    for r in self._pool(priority, model, preempted)
                    if r.t_finish >= 0 and getattr(r, what) >= 0]
            arr = np.asarray(vals, dtype=np.float64)
            out = cache[key] = (arr, np.sort(arr))
        return out

    def priority_classes(self) -> list[int]:
        return sorted({r.priority for r in self.requests})

    def models(self) -> list[str]:
        """Distinct models served, in per-model SLO accounting order."""
        return sorted({r.model for r in self.requests})

    def slo_attainment(self, priority: Optional[int] = None,
                       model: Optional[str] = None) -> float:
        reqs = self._pool(priority, model)
        ok = [1.0 if (r.ttft <= ttft_slo(r.src.in_len, r.priority)
                      and r.tpot <= tpot_slo(r.priority)) else 0.0
              for r in reqs if r.t_finish >= 0]
        unfinished = sum(1 for r in reqs if r.t_finish < 0)
        total = len(ok) + unfinished
        return sum(ok) / max(total, 1)

    def ttft_attainment(self, priority: Optional[int] = None,
                        model: Optional[str] = None) -> float:
        reqs = self._pool(priority, model)
        done = [r for r in reqs if r.t_first_token >= 0]
        ok = sum(1 for r in done
                 if r.ttft <= ttft_slo(r.src.in_len, r.priority))
        return ok / max(len(reqs), 1)

    def tpot_attainment(self, priority: Optional[int] = None,
                        model: Optional[str] = None) -> float:
        reqs = self._pool(priority, model)
        done = [r for r in reqs if r.t_finish >= 0]
        ok = sum(1 for r in done if r.tpot <= tpot_slo(r.priority))
        return ok / max(len(reqs), 1)

    def avg_gpus(self) -> float:
        return self.gpu_seconds / max(self.duration, 1e-9)

    def cost_summary(self) -> dict:
        """Dollar-billing view (the weighted analog of ``avg_gpus``): the
        exact piecewise-constant cost integral, its hourly rate, and the
        per-pool breakdown — the DistServe goodput-per-dollar axis that
        ``--bench=pareto`` plots against SLO attainment."""
        return {
            "cost_dollars": self.cost_dollars,
            "cost_per_hour": self.cost_dollars
            / max(self.duration, 1e-9) * 3600.0,
            "pool_cost": dict(self.pool_cost),
        }

    def throughput(self, model: Optional[str] = None) -> float:
        """Finished requests per second over the horizon."""
        done = sum(1 for r in self._pool(model=model) if r.t_finish >= 0)
        return done / max(self.duration, 1e-9)

    def mean(self, what: str, priority: Optional[int] = None,
             model: Optional[str] = None,
             preempted: Optional[bool] = None) -> float:
        vals, _ = self._finished_vals(what, priority, model, preempted)
        return float(np.mean(vals)) if len(vals) else float("nan")

    def percentile(self, what: str, q: float,
                   priority: Optional[int] = None,
                   model: Optional[str] = None,
                   preempted: Optional[bool] = None) -> float:
        _, svals = self._finished_vals(what, priority, model, preempted)
        return float(np.percentile(svals, q)) if len(svals) \
            else float("nan")

    # ---- canonical metric schemas (golden fixtures + regen share these,
    # so the regenerator and the regression test can never drift apart) --
    def summary(self) -> dict:
        return {
            "n_requests": len(self.requests),
            "slo_attainment": self.slo_attainment(),
            "ttft_attainment": self.ttft_attainment(),
            "tpot_attainment": self.tpot_attainment(),
            "avg_gpus": self.avg_gpus(),
            "throughput": self.throughput(),
            "ttft_mean": self.mean("ttft"),
            "tpot_mean": self.mean("tpot"),
            "ttft_p99": self.percentile("ttft", 99),
            "tpot_p99": self.percentile("tpot", 99),
            "ttft_p999": self.percentile("ttft", 99.9),
        }

    def class_summary(self, priority: int) -> dict:
        n = len(self._pool(priority))
        if n == 0:
            # stable zero-valued schema for absent classes instead of
            # NaN percentiles (the *_summary degradation contract)
            return {"n": 0, "slo_attainment": 0.0,
                    "ttft_p99": 0.0, "tpot_p99": 0.0}
        return {
            "n": n,
            "slo_attainment": self.slo_attainment(priority),
            "ttft_p99": self.percentile("ttft", 99, priority=priority),
            "tpot_p99": self.percentile("tpot", 99, priority=priority),
        }

    def model_summary(self, model: str) -> dict:
        """Per-model SLO accounting for multi-model fleets (same schema
        contract as ``summary``/``class_summary``: goldens and their
        regenerator share it)."""
        n = len(self._pool(model=model))
        if n == 0:
            # stable zero-valued schema for unknown models (see
            # class_summary)
            return {"n": 0, "slo_attainment": 0.0, "ttft_attainment": 0.0,
                    "tpot_attainment": 0.0, "throughput": 0.0,
                    "ttft_p99": 0.0}
        return {
            "n": n,
            "slo_attainment": self.slo_attainment(model=model),
            "ttft_attainment": self.ttft_attainment(model=model),
            "tpot_attainment": self.tpot_attainment(model=model),
            "throughput": self.throughput(model=model),
            "ttft_p99": self.percentile("ttft", 99, model=model),
        }

    def kv_summary(self) -> dict:
        """KV-tier metrics (prefix hit rate, offload bytes, swap stalls,
        blocks-in-use watermarks) plus the preempted-request tail slice —
        the schema the ``kvtiers`` golden and its regenerator share.
        When the fleet runs the legacy flat byte counter the same key
        set comes back zero-valued (the *_summary degradation contract:
        stable schema, no empty-dict/KeyError special cases)."""
        if not self.kv:
            out = KVStats().summary()
            out["n_preempted"] = 0
            out["preempted_ttft_p99"] = 0.0
            out["preempted_tpot_p99"] = 0.0
            return out
        out = dict(self.kv)
        out["n_preempted"] = len(self._pool(preempted=True))
        out["preempted_ttft_p99"] = self.percentile("ttft", 99,
                                                    preempted=True)
        out["preempted_tpot_p99"] = self.percentile("tpot", 99,
                                                    preempted=True)
        return out

    def gw_summary(self) -> dict:
        """Gateway metrics: routing-decision breakdown (affinity hit /
        replica hit / load-balanced fallback), replication traffic, and
        lazy-paging counters — the schema the ``gateway_locality`` golden
        and its regenerator share.  When no pool enables the gateway or
        lazy paging the same key set comes back zero-valued (see
        ``kv_summary``)."""
        if not self.gw:
            return RoutingStats().summary()
        return dict(self.gw)

    def fault_summary(self) -> dict:
        """Chaos-engine counters: injections by kind, crash restarts,
        requeued work, KVC retry/backoff totals — the schema the
        ``chaos_recovery`` golden and its regenerator share.  When faults
        are off the same key set comes back zero-valued (see
        ``kv_summary``)."""
        if not self.faults:
            return FaultStats().summary()
        return dict(self.faults)


# ---------------------------------------------------------------------------
# Control plane glue — shared by both engines
# ---------------------------------------------------------------------------

class ClusterBase:
    """PD-disaggregated cluster state + the unmodified TokenScale control
    plane, executing ``FleetPlan``s against named pools (mixed chips/TP,
    multiple models).  Subclasses implement ``run`` (how time advances)
    and may hook ``_submit_prefill_work`` / ``_after_scale`` to schedule
    work.

    Two construction paths share one body:

      * pool-centric — ``Engine(fleet, policy=fleet_policy)`` with a
        runtime ``Fleet`` and a ``FleetPolicy`` (what ``sim.runner
        .run_spec`` builds from an ``ExperimentSpec``);
      * legacy — ``Engine(cfg, inst_spec, profile, policy, ...)``: the
        historical single-(model, chip, tp) signature, desugared into a
        one-model fleet (pools "prefill"/"decode"/"convertible") with the
        per-model ``Policy`` adapted by ``PerModelFleetPolicy`` — every
        decision it makes is byte-identical to the pre-pool control
        plane (the golden fixtures enforce this).
    """

    engine = "base"

    def __init__(self, cfg: "ModelConfig | Fleet",
                 inst_spec: Optional[InstanceSpec] = None,
                 profile: Optional[VelocityProfile] = None,
                 policy: "Policy | FleetPolicy | None" = None,
                 predictor: Optional[OutputPredictor] = None,
                 conv_cfg: Optional[ConvertibleConfig] = None,
                 n_convertible: int = 0,
                 init_prefillers: int = 1, init_decoders: int = 1,
                 dt: float = 0.025, scale_interval: float = 1.0,
                 max_instances: int = 64,
                 preemption: "PreemptionPolicy | str" = "none",
                 snapshot_interval: Optional[float] = None,
                 faults: "FaultConfig | dict | None" = None):
        if isinstance(cfg, Fleet):
            fleet = cfg
            fpolicy = policy if policy is not None else inst_spec
            if not isinstance(fpolicy, FleetPolicy):
                raise TypeError("fleet construction needs a FleetPolicy")
        else:
            if inst_spec is None or profile is None or policy is None:
                raise TypeError(
                    "legacy construction needs (cfg, inst_spec, profile, "
                    "policy)")
            fleet = self._single_pool_fleet(
                cfg, inst_spec, profile, conv_cfg,
                init_prefillers, init_decoders, n_convertible)
            fpolicy = policy if isinstance(policy, FleetPolicy) \
                else PerModelFleetPolicy({cfg.name: policy})
        self.fleet = fleet
        self.pools = fleet.pools
        self.policy = fpolicy
        self.predictor = predictor or OutputPredictor(0.85)
        self.preemption = PreemptionPolicy.of(preemption)
        # (t, victim_priority, preemptor_priority, victim_generated) audit
        # trail — the preemption property tests assert over it
        self.preemption_log: list[tuple[float, int, int, float]] = []
        self.dt = dt
        self.scale_interval = scale_interval
        self.max_instances = max_instances
        # timeline snapshot cadence; None = adaptive (the historical 0.2 s
        # up to ~13-minute horizons, then stretched to cap the timeline at
        # ~4000 rows so multi-hour traces don't grow it unboundedly)
        self.snapshot_interval = snapshot_interval
        # KV-tier subsystem (sim.kvcache): one stats sink shared by every
        # decoder's allocator; enabled per pool via PoolSpec.block_size
        self.kv_stats = KVStats()
        self._kv_on = any(
            p.spec.block_size > 0 and p.spec.role != "prefill"
            and p.cost.kv_tok > 0 for p in self.pools.values())
        # ---- locality gateway + lazy paging (core.gateway) ----
        # one counter sink across all model groups' gateways; per-group
        # Gateway objects own the trie (placement is per model).  _gw_on
        # gates every gateway/lazy hook so legacy fleets stay byte-
        # identical (the six pre-gateway goldens pin this).
        self.gw_stats = RoutingStats()
        self._gw_jobs: list = []      # pending ReplicationJobs, by t_done
        self._gw_on = any(p.spec.kv_alloc == "lazy"
                          for p in self.pools.values())
        for g in fleet.groups.values():
            gpools = [p for p in g.decode_pools if p.spec.gateway]
            if g.convertible is not None and g.convertible.spec.gateway:
                gpools.append(g.convertible)
            if gpools:
                g.gateway = Gateway(GatewayConfig(),
                                    gpools[0].spec.block_size,
                                    self.gw_stats)
                self._gw_on = True
        # flight recorder (repro.obs): None = telemetry off.  Every hook
        # below is guarded by ``self.obs is not None`` and the recorder
        # is a pure observer, so the disabled path is byte-identical and
        # the enabled path cannot perturb event ordering.  Set before the
        # initial spawns so ``_spawn`` can propagate it unconditionally.
        self.obs = None
        self._iid = 0
        for pool in self.pools.values():     # declaration order = iid order
            for _ in range(pool.spec.init):
                pool.instances.append(self._spawn(pool, 0.0))
        # legacy aliases for the default model group (single-pool callers)
        g = fleet.groups[fleet.default_model]
        self.cfg = g.prefill.cfg
        self.spec = g.prefill.inst
        self.prof = g.prefill.prof
        self.cost = g.decode.cost
        self.conv_cfg = g.convertible.conv_cfg if g.convertible else None
        self.router = g.router
        # (ready_t, req) entries, kept sorted by the admission key
        # (priority, ready_t, rid) — ``_admit_pending`` historically
        # re-sorted the whole list on every call; bisect inserts keep the
        # identical order with O(log n) per entry instead
        self.pending_decode: list[tuple[float, SimRequest]] = []
        # kept sorted by (priority, arrival t, rid) — the §IV-E drain's
        # historical per-call sort key — via bisect inserts
        self.wait_queue: list[SimRequest] = []
        self.finished: list[SimRequest] = []
        self.gpu_seconds = 0.0
        # dollar-weighted billing: a segment-based integral advanced at
        # every fleet-membership change (see _cost_advance) — exact with
        # zero per-tick/per-event cost, unlike gpu_seconds' cached-rate
        # accumulation in the engines' run loops
        self.cost_dollars = 0.0
        self.pool_cost = {name: 0.0 for name in self.pools}
        self._cost_t0 = 0.0
        self.n_deflected = 0     # prompts routed to decoders (round 2b)
        self.timeline: list[dict] = []
        # rolling 1-s gateway counters (deque: the 5 s window expires from
        # the left instead of rebuilding the list on every arrival)
        self._arrivals: deque[tuple[float, SimRequest]] = deque()
        # ---- chaos engine (sim.faults): None = faults off — no schedule
        # is built, every per-tick/per-event hook fast-paths out, and the
        # pre-chaos goldens stay byte-identical ----
        self.faults: Optional[FaultConfig] = None if not faults else (
            faults if isinstance(faults, FaultConfig)
            else FaultConfig.from_dict(dict(faults)))
        self.fault_stats = FaultStats()
        self._fault_work: list[tuple] = []   # (t, kind, *payload), sorted
        self._link_down_until = -1.0         # KVC link-outage window end
        self._monitor = HealthMonitor(self.faults.detect_s) \
            if self.faults is not None else None
        # measured effective velocity feeds Eq. 2-4 only on the
        # self-healing path (the observation stays byte-stable otherwise)
        self._fault_eff = self.faults is not None and self.faults.recovery

    # ---- flight-recorder attachment (repro.obs) ----------------------
    def attach_obs(self, rec):
        """Attach a ``FlightRecorder`` to this run (idempotent per
        recorder).  Wires the per-group router/gateway trace hooks and
        propagates the recorder to already-spawned instances; instances
        spawned later inherit it via ``_spawn``."""
        self.obs = rec
        rec.engine = self.engine
        for g in self.fleet.groups.values():
            g.router.trace_hook = rec.router_hook(g.model)
            if g.gateway is not None:
                g.gateway.trace_hook = rec.gateway_hook(g.model)
        for pool in self.pools.values():
            for i in pool.instances:
                i.obs = rec
        return rec

    # ------------------------------------------------------------------
    @staticmethod
    def _single_pool_fleet(cfg, inst_spec, profile, conv_cfg,
                           init_prefillers, init_decoders,
                           n_convertible) -> Fleet:
        """The legacy signature desugared: one model, one chip, one TP."""
        chip, tp = inst_spec.chip.name, inst_spec.tp
        mk = lambda name, role, init: Pool(      # noqa: E731
            PoolSpec(name, role, cfg.name, chip, tp, init=init),
            cfg, inst_spec, profile,
            conv_cfg=conv_cfg if role == "convertible" else None)
        return Fleet([mk("prefill", "prefill", init_prefillers),
                      mk("decode", "decode", init_decoders),
                      mk("convertible", "convertible", n_convertible)])

    def _spawn(self, pool: Pool, ready_t: float):
        self._iid += 1
        if pool.spec.role == "prefill":
            i: "Prefiller | Decoder" = Prefiller(
                self._iid, pool.inst, pool.cost, ready_t,
                pool.prof.v_prefill)
        else:
            conv = pool.spec.role == "convertible"
            i = Decoder(self._iid, pool.inst, pool.cost, ready_t,
                        conv=pool.conv_cfg if conv else None)
            i.is_convertible = conv
            i.hbm_frac = pool.spec.hbm_frac
            i.chunking = pool.spec.prefill_chunking
            if pool.spec.block_size > 0 and pool.cost.kv_tok > 0:
                i.kv = self._make_allocator(pool, i)
            i.lazy = pool.spec.kv_alloc == "lazy" and i.kv is not None
            i.gw_stats = self.gw_stats
            if pool.spec.gateway:
                i.gateway = self.fleet.groups[pool.spec.model].gateway
        i.pool = pool
        i.obs = self.obs
        return i

    def _make_allocator(self, pool: Pool, d: Decoder) -> KVAllocator:
        """Resolve the pool's tier knobs against the decoder's usable HBM
        (after weights and the Eq. 6 convertible reserve) and the chip's
        host-DRAM constants (``offload_gb=None`` = chip default, 0 = tier
        off)."""
        bs = pool.spec.block_size
        bb = bs * pool.cost.kv_tok
        n_hbm = max(int(max(d.mem_cap(), 0.0) // bb), 1)
        off = pool.spec.offload_gb
        off_bytes = pool.inst.host_dram_cap if off is None else off * 1e9
        cfg = KVTierConfig(
            block_size=bs, block_bytes=bb, n_hbm=n_hbm,
            n_dram=int(max(off_bytes, 0.0) // bb),
            swap_bw=pool.inst.swap_bw or pool.inst.chip.net_bw,
            prefix_cache=pool.spec.prefix_cache)
        return KVAllocator(cfg, self.kv_stats)

    # ---- flat views + legacy factories (compat surface) --------------
    def _role_view(self, role: str) -> list:
        """All instances of one role, flattened across pools.  Always a
        copy — mutating it is a silent no-op regardless of fleet shape,
        so callers that grow/shrink the fleet must go through the pool's
        own ``instances`` list (as ``_scale`` does)."""
        return [i for p in self.fleet.role_pools(role)
                for i in p.instances]

    @property
    def prefillers(self) -> list:
        return self._role_view("prefill")

    @property
    def decoders(self) -> list:
        return self._role_view("decode")

    @property
    def convertibles(self) -> list:
        return self._role_view("convertible")

    def _new_prefiller(self, ready_t: float) -> Prefiller:
        g = self.fleet.groups[self.fleet.default_model]
        return self._spawn(g.prefill, ready_t)

    def _new_decoder(self, ready_t: float, convertible: bool = False
                     ) -> Decoder:
        g = self.fleet.groups[self.fleet.default_model]
        pool = g.convertible if convertible else g.decode
        return self._spawn(pool, ready_t)

    # ---- model routing -----------------------------------------------
    def _group_of(self, req: SimRequest) -> ModelGroup:
        model = req.model or self.fleet.default_model
        try:
            return self.fleet.groups[model]
        except KeyError:
            raise ValueError(
                f"request {req.src.rid} targets model {model!r} but the "
                f"fleet serves {sorted(self.fleet.groups)}")

    # ---- queue maintenance -------------------------------------------
    @staticmethod
    def _pending_key(entry: tuple[float, "SimRequest"]) -> tuple:
        return (entry[1].priority, entry[0], entry[1].src.rid)

    @staticmethod
    def _wait_key(req: "SimRequest") -> tuple:
        return (req.priority, req.src.t, req.src.rid)

    def _pending_add(self, entry: tuple[float, "SimRequest"]):
        insort(self.pending_decode, entry, key=self._pending_key)

    def _wait_add(self, req: "SimRequest"):
        insort(self.wait_queue, req, key=self._wait_key)

    # ------------------------------------------------------------------
    def _submit_prefill_work(self, tgt, kind: str, req: SimRequest, t: float):
        """Hand a routed request to its prefill target.  Engines override to
        additionally schedule completion events.  Deflected requests share
        the convertible on-box path (``Decoder.submit_prefill``): chunks
        execute inside the target's decode iterations and the finished
        prompt admits without a KVC transfer."""
        if self.obs is not None:
            self.obs.on_routed(req, t, kind, tgt)
            if kind == "deflect":
                self.obs.on_deflect(req, t, tgt)
        if kind == "prefiller":
            tgt.submit(req, t)
        else:
            if kind == "deflect":
                self.n_deflected += 1
                req.deflect_tgt = tgt
            tgt.submit_prefill(req, t)

    def _on_arrival(self, req: SimRequest, t: float):
        g = self._group_of(req)
        g.router.burst.observe(t, req.src.in_len)
        req.bucket_pred = self.predictor.predict_bucket(
            req.src.in_len, req.src.out_len)
        if self._kv_on:
            if g.gateway is not None:
                self._gw_lookup(g, req, t)
            else:
                self._kv_lookup(g, req, t)
        arrivals = self._arrivals
        arrivals.append((t, req))
        while t - arrivals[0][0] > 5.0:
            arrivals.popleft()
        is_ts = isinstance(self.policy.model_policy(g.model),
                           TokenScalePolicy)
        convs = g.conv_instances()
        burst = is_ts and convs and g.router.burst.is_burst(t)
        if self.obs is not None:
            self.obs.on_arrival(req, t, burst=bool(burst))
        if burst:
            # burst traffic goes straight to the Convertible Decoders (§IV-A)
            tgt, kind = g.router.route_prefill(
                req.src.in_len, [], self._ready(convs, t), t,
                priority=req.priority)
            if tgt is not None:
                self._submit_prefill_work(tgt, "convertible", req, t)
                return
        tgt, kind = g.router.route_prefill(
            req.src.in_len, self._ready(g.prefill_instances(), t),
            self._ready(convs, t) if is_ts else [], t,
            priority=req.priority,
            deflectables=self._ready(g.deflect_instances(), t))
        if kind is not None:
            self._submit_prefill_work(tgt, kind, req, t)
        else:
            # Alg.1 line 15: central queue, re-evaluated as load changes
            if self.obs is not None:
                self.obs.on_routed(req, t, None, None)
            self._wait_add(req)

    def _ready(self, insts, t: float):
        return [i for i in insts if i.ready(t) and not i.draining]

    def _drain_wait_queue(self, t: float):
        """§IV-E: as load changes (scale-ups, drained convertibles), pending
        prefill tasks are re-evaluated and re-assigned — higher priority
        classes first, FIFO within a class, each within its own model's
        pools.  ``wait_queue`` is maintained in exactly that order
        (``_wait_add``), so the historical per-call sort is gone.

        Failure short-circuit (O(1) amortized per queued request): within
        one pass nothing a failing request observes improves — successful
        submissions only *add* in-flight prefill work, ready/draining
        states are frozen at ``t``, and an idle prefiller cannot appear
        mid-pass — so once a request of some model fails both routing
        rounds with no idle fallback, every later request of that model
        with an equal-or-tighter TTFT budget must fail identically.  Those
        skip straight to the carry-over without re-scanning the pools
        (the historical full scan made overload quadratic in queue
        length).  The ready-candidate lists are likewise frozen per pass
        and computed once per model.  Deflection (round 2b) preserves the
        monotonicity: its acceptance is a pure SLO test, and mid-pass
        submissions only grow the deflected queues (the batches — and so
        each decoder's absorption velocity — cannot change inside the
        pass), so a failed budget still implies failure for every
        equal-or-tighter one."""
        if not self.wait_queue:
            return
        still = []
        ready_cache: dict[str, tuple[list, list]] = {}
        failed_slo: dict[str, float] = {}   # model -> widest failed budget
        for req in list(self.wait_queue):
            g = self._group_of(req)
            m = g.model
            slo = ttft_slo(req.src.in_len, req.priority)
            f = failed_slo.get(m)
            if f is not None and slo <= f:
                still.append(req)
                continue
            cached = ready_cache.get(m)
            if cached is None:
                is_ts = isinstance(self.policy.model_policy(m),
                                   TokenScalePolicy)
                cached = ready_cache[m] = (
                    self._ready(g.prefill_instances(), t),
                    self._ready(g.conv_instances(), t) if is_ts else [],
                    self._ready(g.deflect_instances(), t))
            pres, convs, defl = cached
            tgt, kind = g.router.route_prefill(
                req.src.in_len, pres, convs, t, priority=req.priority,
                deflectables=defl)
            if kind is not None:
                self._submit_prefill_work(tgt, kind, req, t)
            else:
                # work conservation: an idle prefiller always takes work,
                # even if the SLO is already forfeited
                idle = [p for p in pres if p.idle]
                if idle:
                    self._submit_prefill_work(idle[0], "prefiller", req, t)
                else:
                    still.append(req)
                    if f is None or slo > f:
                        failed_slo[m] = slo
        self.wait_queue = still

    def _kv_lookup(self, g: ModelGroup, req: SimRequest, t: float):
        """Arrival-time prefix-cache probe: find the decoder holding the
        longest cached prefix of this request's session and pin it.  A hit
        shrinks the prefill work and the KVC transfer to the uncached
        suffix; the pin keeps the blocks resident until admission."""
        cands = [d for d in g.decode_instances()
                 if d.kv is not None and d.ready(t) and not d.draining]
        if not cands:
            return
        st = self.kv_stats
        st.lookups += 1
        st.prompt_tokens += req.src.in_len
        sid = req.session
        if sid < 0 or req.src.prefix_len <= 0:
            return
        best, best_tok, best_tier = None, 0, ""
        for d in cands:
            tok, tier = d.kv.lookup(sid, req.src.prefix_len)
            # longest prefix wins; at equal coverage prefer the HBM copy
            if tok > best_tok or (tok == best_tok and tier == "hbm"
                                  and best_tier == "dram"):
                best, best_tok, best_tier = d, tok, tier
        if best is None or best_tok <= 0:
            return
        bs = best.kv.cfg.block_size
        # keep at least one uncached token so prefill/TTFT stay defined
        usable = (min(best_tok, req.src.in_len - 1) // bs) * bs
        if usable <= 0:
            return
        best.kv.pin(req.src.rid, sid, usable, t)
        req.kv_hit_tokens = usable
        req.kv_prefix = (best, usable, best_tier)
        st.hits += 1
        st.hit_tokens += usable

    # ---- locality gateway (core.gateway; DESIGN.md "Routing fidelity") --
    def _gw_lookup(self, g: ModelGroup, req: SimRequest, t: float):
        """Gateway placement: map the arrival's block-label chain through
        the fleet prefix hashtrie, score holders by ``cached_suffix_savings
        - alpha * queue_depth``, and pin the winner's prefix — session
        chains, cross-session shared prompts, and hot-prefix replicas all
        route through this one mechanism (it replaces the session-only
        owner steering of ``_kv_lookup`` for gateway pools).  No usable
        holder — or a score the least-loaded candidate beats — falls
        through to the share-of-capacity balancer in ``_admit_pending``,
        exactly like a cache miss."""
        gw = g.gateway
        cands = [d for d in g.decode_instances()
                 if d.kv is not None and d.ready(t) and not d.draining]
        if not cands:
            return
        st = self.kv_stats
        st.lookups += 1
        st.prompt_tokens += req.src.in_len
        chain = gw.chain_of(req.src)
        if not chain:
            gw.stats.balanced += 1
            return
        best = gw.best_holder(
            chain, t,
            lambda h: h.live and h.kv is not None and h.ready(t)
            and not h.draining)
        for job in gw.plan_replication(chain, t, cands):
            self._gw_dispatch(gw, job, t)
        if best is None:
            gw.stats.balanced += 1
            return
        holder, node, depth, replica, score = best
        q_min = min(len(d.active) for d in cands)
        if score <= -gw.cfg.alpha * q_min:
            # locality discounted by queue depth loses to the balancer's
            # least-loaded pick: don't steer
            gw.stats.balanced += 1
            return
        # the trie is advisory — validate against the holder's allocator
        # (the ground truth) and round to its own block geometry
        key = gw.cache_key(node.label, req.session)
        tok, tier = holder.kv.lookup(key, depth)
        bs = holder.kv.cfg.block_size
        usable = (min(tok, depth, req.src.in_len - 1) // bs) * bs
        if usable <= 0:
            node.holders.pop(holder, None)     # stale marking: drop it
            gw.stats.balanced += 1
            return
        holder.kv.pin(req.src.rid, key, usable, t)
        req.kv_hit_tokens = usable
        req.kv_prefix = (holder, usable, tier)
        st.hits += 1
        st.hit_tokens += usable
        gw.stats.steered_tokens += usable
        if replica:
            gw.stats.replica_hits += 1
        else:
            gw.stats.affinity_hits += 1

    def _gw_dispatch(self, gw: Gateway, job, t: float):
        """Stamp a planned hot-prefix copy with its interconnect cost —
        the ``migration_stall`` formula (prefix bytes over the origin
        chip's net bandwidth) — and queue it for completion."""
        src = job.source
        stall = src.kv.token_bytes(job.tokens) \
            / max(src.spec.chip.net_bw, 1e-9)
        job.t_done = t + stall
        job.gw = gw
        gw.stats.replica_stall_s += stall
        if self.obs is not None:
            self.obs.on_replication(
                t, "dispatch", tokens=job.tokens, stall=stall,
                source=getattr(src, "iid", None),
                target=getattr(job.target, "iid", None))
        insort(self._gw_jobs, job, key=lambda j: j.t_done)
        self._on_replication(job)

    def _on_replication(self, job):
        """Engine hook: the event engine schedules the exact replica_done
        event; the fluid engine completes due jobs at tick granularity
        via the ``_admit_pending`` preamble."""

    def _service_gateway(self, t: float):
        """Complete due hot-prefix replications and resolve lazy-paging
        OOMs.  Runs in the ``_admit_pending`` preamble: every tick in the
        fluid engine; on each admission-relevant event — plus the exact
        replica_done events — in the event engine."""
        jobs = self._gw_jobs
        while jobs and jobs[0].t_done <= t:
            job = jobs.pop(0)
            job.node.pending = False
            gw, src, tgt = job.gw, job.source, job.target
            if not (src.live and src.kv is not None and tgt.live
                    and tgt.kv is not None and not tgt.draining):
                continue
            tok, tier = src.kv.lookup(job.key, job.tokens)
            if tok < job.tokens or tier != "hbm":
                continue               # origin lost the prefix mid-flight
            if tgt.kv.install(job.key, job.tokens, t):
                gw.trie.insert(job.chain, tgt, t, gw.block_size,
                               replica=True)
                gw.stats.replications += 1
                gw.stats.replica_bytes += tgt.kv.token_bytes(job.tokens)
                if self.obs is not None:
                    self.obs.on_replication(
                        t, "done", tokens=job.tokens,
                        source=getattr(src, "iid", None),
                        target=getattr(tgt, "iid", None))
        for pool in self.pools.values():
            if pool.spec.kv_alloc != "lazy":
                continue
            for d in pool.instances:
                if d.oom_pending:
                    self._service_oom(d, t)

    def _service_oom(self, d: Decoder, t: float):
        """Mid-decode OOM (allocate-on-generate): a resident's per-token
        block grow found no HBM free.  Retry first (completions since the
        failure may have freed blocks); then preempt strictly-lower-
        priority residents through the existing ``PreemptionPolicy``
        machinery; as the last resort the starved request itself is
        evicted (recompute/swap like any other victim) — decode never
        deadlocks on an unbacked token."""
        pend, d.oom_pending = d.oom_pending, []
        st = self.gw_stats
        for r in pend:
            if r.t_finish >= 0 or r not in d.active:
                continue
            if d.kv.try_grow(r.src.rid, d._admit_bytes(r)) is not None:
                continue
            if self.obs is not None:
                self.obs.on_oom(r, t, d)
            victims = self._victim_order(
                [v for v in d.active
                 if v is not r and v.t_finish < 0
                 and v.priority > r.priority], d, t) \
                if self.preemption.enabled else []
            grown = False
            for v in victims:
                self._evict(d, v, r, t)
                st.oom_preemptions += 1
                if d.kv.try_grow(r.src.rid, d._admit_bytes(r)) is not None:
                    grown = True
                    break
            if not grown:
                self._evict(d, r, r, t)
                st.oom_preemptions += 1

    def _to_network(self, req: SimRequest, t: float,
                    pool: Optional[Pool] = None
                    ) -> Optional[tuple[float, SimRequest]]:
        """Ship the finished prefill's KV over the interconnect; returns
        the ``pending_decode`` entry — or None when a KVC link outage
        exhausted the retry ladder and the prompt fell back to the central
        queue for a recompute (``sim.faults``; chaos runs only)."""
        req.t_prefill_end = t
        # the KVC leaves over the *completing* prefiller's interconnect —
        # engines pass its pool, so heterogeneous prefill pool sets charge
        # each chip's own network (single-pool fleets: identical to the
        # model's primary pool)
        if pool is None:
            pool = self._group_of(req).prefill
        # a prefix-cache hit only ships the uncached suffix (the shared
        # blocks already live on the decode side)
        delay = hw.kvc_transfer_time(pool.cfg, pool.inst,
                                     req.src.in_len - req.kv_hit_tokens)
        if self.faults is not None and t < self._link_down_until:
            wait = self._link_wait(t)
            if wait is None:
                # retry ladder exhausted inside the outage window: fall
                # back to recomputing the prompt at the prefill stage
                self.fault_stats.kvc_fallbacks += 1
                if self.obs is not None:
                    self.obs.on_fault(t, "kvc_fallback", rid=req.src.rid)
                self._wait_add(req)
                return None
            delay += wait
        if self.obs is not None:
            # prefiller-side completion odometer + the transfer event
            # (on-box completions are counted in Decoder.advance_prefill)
            self.obs.prefill_tokens_done += req.prefill_tokens
            self.obs.on_transfer(req, t, delay)
        entry = (t + delay, req)
        self._pending_add(entry)
        return entry

    def _admit_pending(self, t: float):
        """Route KV-ready requests to decoders in priority order; on
        backpressure they stay pending and are retried (each tick in the
        fluid engine; on the next kv_ready/iter_done/scale event in the
        event engine).  If preemption is enabled, a request that fits
        nowhere may instead evict/pause strictly-lower-priority resident
        work (the fluid engine reaches this via its per-tick retry; the
        event engine via exact admission events).  Candidates are always
        the request's own model's decode + convertible pools.

        ``pending_decode`` is maintained in admission order
        (priority, ready_t, rid) — see ``_pending_add`` — so each pass
        walks it without the historical per-call sort.

        Failure short-circuit (legacy byte-counter fleets): within one
        pass decoder memory only shrinks — admissions consume it, nothing
        completes mid-pass — so once a request fails on every candidate,
        any later same-model request reserving at least as many bytes
        must fail identically and skips the candidate scan.  The pass
        walks most-urgent-first, so a later request's preemption victims
        are a subset of an earlier one's, preserving the implication for
        the eviction path too; a successful preemption can leave its host
        with *more* free memory than before, so it resets the
        short-circuit.  Paged-KV fleets skip the fast path: prefix pins
        make the reservation per-decoder."""
        if self._gw_on:
            # due hot-prefix replications + lazy-paging OOM resolution
            self._service_gateway(t)
        if self._kv_on:
            # on-box convertible completions that found no blocks free
            for pool in self.pools.values():
                if pool.spec.role == "prefill":
                    continue
                for x in pool.instances:
                    if x.kv_spill:
                        for e in x.kv_spill:
                            self._pending_add(e)
                        x.kv_spill = []
        if not self.pending_decode:
            return
        rest = []
        queue = self.pending_decode
        self.pending_decode = []      # evicted victims re-enter here
        fast = not self._kv_on
        failed_need: dict[str, float] = {}   # model -> min failed bytes
        for ready_t, req in queue:
            if ready_t > t:
                rest.append((ready_t, req))
                continue
            g = self._group_of(req)
            kp = req.kv_prefix
            need = 0.0
            preempted = False
            if kp is not None:
                # prefix affinity: the hit is only free on the owner with
                # the blocks in HBM; anything else pays a one-time stall
                # (swap-in / migration / recompute) and retries
                owner = kp[0]
                if kp[2] == "hbm" and owner.live and owner.ready(t) \
                        and not owner.draining and owner.can_admit(req):
                    d: Optional[Decoder] = owner
                else:
                    self._kv_prefix_penalty(req, t)
                    continue
            elif req.deflect_tgt is not None and req.deflect_tgt.live \
                    and not req.deflect_tgt.draining:
                # deflection affinity (Alg. 1 round 2b follow-through):
                # the prompt's KV was produced on-box, so it decodes on
                # its deflection target — rerouting through bucket-aware
                # load balancing would ship the KV to another decoder
                # without charging any transfer.  Only the paged-KV spill
                # path reaches here (non-paged deflections admit
                # unconditionally in advance_prefill); if the target
                # can't admit yet the request waits for *it*, not for
                # the pool
                tgt = req.deflect_tgt
                if tgt.ready(t) and tgt.can_admit(req):
                    d = tgt
                else:
                    rest.append((ready_t, req))
                    continue
            else:
                # target torn down or draining: rejoin the shared path
                req.deflect_tgt = None
                if fast:
                    c = g.decode.cost
                    need = (req.src.in_len + req.src.out_len) * c.kv_tok \
                        + c.state_fix
                    f = failed_need.get(g.model)
                    if f is not None and need >= f:
                        rest.append((ready_t, req))
                        continue
                cands = [x for x in g.decode_instances()
                         if x.ready(t) and not x.draining
                         and x.can_admit(req)]
                d = g.router.route_decode(req.bucket_pred, cands)
                if d is None and self.preemption.enabled:
                    n_log = len(self.preemption_log)
                    d = self._preempt_for(req, t)
                    preempted = len(self.preemption_log) > n_log
            if d is None:
                rest.append((ready_t, req))
                if fast and not preempted:
                    f = failed_need.get(g.model)
                    if f is None or need < f:
                        failed_need[g.model] = need
            else:
                if req.t_kv_ready < 0:     # keep the first KV-ready stamp
                    req.t_kv_ready = ready_t   # across preemption re-entries
                d.admit(req, t)
                self._after_admit(d, t)
            if preempted:
                # evictions can leave the host with more free memory than
                # before the pass saw it: re-arm the scan
                failed_need.pop(g.model, None)
        # merge the survivors (an ordered subsequence of the sorted pass)
        # with entries requeued during it (penalties / evicted victims,
        # already insort-ordered) — the list stays admission-ordered
        if self.pending_decode:
            rest = list(heapq.merge(rest, self.pending_decode,
                                    key=self._pending_key))
        self.pending_decode = rest

    def _kv_prefix_penalty(self, req: SimRequest, t: float):
        """The cached prefix is not immediately usable: its owner can't
        admit right now, or the copy lives in the host-DRAM tier.  Charge
        the one-time stall — swap-in at the tier's bandwidth, migration
        over the owner's interconnect, or a recompute if the copy is gone
        — then requeue; afterwards the request admits anywhere with a full
        allocation (the prefill savings already happened)."""
        owner, tokens, tier = req.kv_prefix
        st = self.kv_stats
        kv = owner.kv
        if tier == "dram":
            delay = kv.token_bytes(tokens) / max(kv.cfg.swap_bw, 1e-9)
            st.swap_stall_s += delay
        elif req.src.rid in kv.pins:
            delay = kv.migration_stall(tokens, owner.spec.chip.net_bw)
        else:                           # pin lost (owner torn down)
            g = self._group_of(req)
            delay = tokens / max(g.prefill.prof.v_prefill, 1e-9)
            st.prefix_recomputes += 1
        kv.unpin(req.src.rid)
        req.kv_prefix = None
        entry = (t + delay, req)
        self._pending_add(entry)
        self._on_requeue(entry)

    def _after_admit(self, d: Decoder, t: float):
        """Engine hook: the event engine wakes the decoder's iteration."""

    # ---- preemption (tentpole; DESIGN.md §1) -------------------------
    def _slack(self, v: SimRequest, d: Decoder, t: float) -> float:
        """Deadline slack in seconds: time until the victim's end-to-end
        SLO deadline (arrival + per-class TTFT budget + per-class TPOT
        budget x output length) minus its estimated remaining decode time
        at the decoder's current iteration rate.  Negative = already
        doomed — evicting it forfeits the least attainment."""
        deadline = v.src.t + ttft_slo(v.src.in_len, v.priority) \
            + tpot_slo(v.priority) * v.src.out_len
        remaining = max(v.src.out_len - v.generated, 0.0) * d.iter_time()
        return deadline - t - remaining

    def _victim_order(self, victims: list, d: Decoder, t: float) -> list:
        """evict-lowest/pause-requeue: lowest-class-first, least-progress-
        first (least wasted work).  evict-least-slack: lowest deadline
        slack first — the request most likely to miss its SLO anyway."""
        if self.preemption.mode == "evict-least-slack":
            return sorted(victims,
                          key=lambda v: (self._slack(v, d, t), v.src.rid))
        return sorted(victims,
                      key=lambda v: (-v.priority, v.generated,
                                     v.t_decode_start))

    def _preempt_for(self, req: SimRequest, t: float) -> Optional[Decoder]:
        """HBM backpressure: free memory for ``req`` by preempting
        strictly-lower-priority resident requests.  Returns the decoder
        that can now admit ``req``, or None if no eligible victims exist.
        Host choice: the decoder whose most-expendable victim has the
        lowest class (evict-least-slack: the lowest deadline slack);
        victims are then evicted in ``_victim_order``.  Memory estimates
        use blocks when the decoder runs the paged KV subsystem, bytes
        otherwise."""
        g = self._group_of(req)
        c = g.decode.cost
        slackful = self.preemption.mode == "evict-least-slack"
        best, best_key = None, None
        for d in g.decode_instances():
            if not d.ready(t) or d.draining:
                continue
            # fast path: the residency-class counter says whether any
            # strictly-lower-priority victim exists before scanning the
            # batch — most retries during a burst fail here
            if d.max_resident_priority() <= req.priority:
                continue
            victims = [v for v in d.active
                       if v.t_finish < 0 and v.priority > req.priority]
            if not victims:
                continue
            if d.kv is not None:
                need: float = d.kv.need_blocks(req.src.rid,
                                               d._admit_bytes(req))
                free: float = d.kv.available()
                evictable: float = sum(d.kv.owned_blocks(v.src.rid)
                                       for v in victims)
            else:
                need = (req.src.in_len + req.src.out_len) * c.kv_tok \
                    + c.state_fix
                free = d.mem_cap() - d.mem_used()
                evictable = sum((v.src.in_len + v.generated) * c.kv_tok
                                + c.state_fix for v in victims)
            if free + evictable < need:
                continue
            if slackful:
                key = (-min(self._slack(v, d, t) for v in victims),
                       free + evictable)
            else:
                key = (max(v.priority for v in victims), free + evictable)
            if best_key is None or key > best_key:
                best, best_key = d, key
        if best is None:
            return None
        victims = self._victim_order(
            [v for v in best.active
             if v.t_finish < 0 and v.priority > req.priority], best, t)
        for v in victims:
            if best.can_admit(req):
                break
            self._evict(best, v, req, t)
        return best if best.can_admit(req) else None

    def _evict(self, d: Decoder, victim: SimRequest, preemptor: SimRequest,
               t: float):
        """Remove ``victim`` from decode; it re-enters ``pending_decode``
        after its KV recomputation (evict-lowest / evict-least-slack) or
        swap (pause-requeue) delay, which is also charged to its decode
        time.  With the paged KV subsystem, pause-requeue is a *real*
        swap: owned blocks move to the host-DRAM tier (swap-out overlapped
        with the preemptor; the stall is the swap-in at the tier's
        bandwidth) and fall back to a recompute only when the tier is
        full."""
        d.remove_active(victim)
        victim.n_evictions += 1
        ctx = int(victim.src.in_len + victim.generated)
        g = self._group_of(victim)
        recompute = ctx / max(g.prefill.prof.v_prefill, 1e-9)
        if d.kv is not None:
            # KV-subsystem fidelity: a recomputation runs at the prefill
            # stage, which is exactly what's backlogged during the burst
            # that caused the backpressure — charge the least-loaded ready
            # prefiller's backlog on top of the service time.  (The legacy
            # byte-counter path below keeps the optimistic constant, which
            # the priority_preemption golden pins.)
            backlogs = [p.inflight_tokens() / max(p.prefill_velocity(), 1e-9)
                        for p in self._ready(g.prefill_instances(), t)]
            recompute += min(backlogs) if backlogs else 0.0
            if self.preemption.mode == "pause-requeue":
                kind, nbytes = d.kv.swap_out(victim.src.rid)
                if kind == "swap":
                    delay = nbytes / max(d.kv.cfg.swap_bw, 1e-9)
                    self.kv_stats.swap_stall_s += delay
                    victim.kv_swap = d.kv
                else:                      # host tier full: KV discarded
                    self.kv_stats.swap_fallbacks += 1
                    delay = recompute
            else:
                d.kv.drop(victim.src.rid)
                delay = recompute
        elif self.preemption.mode == "pause-requeue":
            # legacy counter: KV swapped over the decoder's interconnect
            delay = hw.kvc_transfer_time(g.decode.cfg, d.pool.inst, ctx)
        else:                                # KV dropped, full recompute
            delay = recompute
        victim.decode_time += delay
        if self.obs is not None:
            swapped = self.preemption.mode == "pause-requeue" and (
                d.kv is None or victim.kv_swap is not None)
            self.obs.on_preempt(victim, t, d,
                                "swap" if swapped else "recompute", delay)
        self.preemption_log.append(
            (t, victim.priority, preemptor.priority, victim.generated))
        entry = (t + delay, victim)
        self._pending_add(entry)
        self._on_requeue(entry)

    def _on_requeue(self, entry: tuple[float, SimRequest]):
        """Engine hook: the event engine schedules a retry at the victim's
        re-entry ready time."""

    # ---- chaos engine (sim.faults; DESIGN.md "Fault fidelity") --------
    def _faults_begin(self, t_end: float):
        """Draw the run's injection schedule — a pure function of the
        fault config and the horizon, from its own RNG substream.  The
        fluid engine drains it at tick granularity (``_faults_tick``);
        the event engine converts it to exact heap events
        (``_ev_fault``)."""
        if self.faults is None:
            self._fault_work = []
            return
        self._fault_work = [(ev.t, "inject", ev)
                            for ev in build_schedule(self.faults, t_end)]

    def _faults_tick(self, t: float) -> bool:
        """Fluid engine: fire every due fault work item.  Returns True
        when anything fired, so the caller refreshes its cached GPU
        count (crashes/reaps change the fleet outside ``_scale``)."""
        w = self._fault_work
        if not w or w[0][0] > t:
            return False
        while w and w[0][0] <= t:
            item = w.pop(0)
            for derived in self._fault_fire(t, item):
                insort(w, derived, key=lambda x: x[0])
        return True

    def _fault_candidates(self, role: str, t: float) -> list:
        return [i for p in self.fleet.role_pools(role)
                for i in p.instances
                if i.live and i.ready(t) and not i.draining]

    def _fault_fire(self, t: float, item: tuple) -> list[tuple]:
        """Apply one fault work item; returns derived items (window
        ends, husk reaps) for the engine to schedule.  Shared verbatim
        by both engines, so a given schedule produces the same state
        transitions — only the timing granularity differs."""
        kind = item[1]
        if kind == "inject":
            return self._fault_inject(t, item[2])
        if kind == "straggler_end":
            inst, orig_v = item[2], item[3]
            inst.perf = 1.0
            if isinstance(inst, Prefiller):
                inst.v_p = orig_v
            else:
                inst._iter_cache = None
            if self.obs is not None:
                self.obs.on_recovery(t, "straggler_end",
                                     instance=inst.iid)
            return []
        if kind == "swap_restore":
            inst, orig_cfg = item[2], item[3]
            if inst.kv is not None:
                inst.kv.cfg = orig_cfg
            if self.obs is not None:
                self.obs.on_recovery(t, "swap_restore",
                                     instance=inst.iid)
            return []
        if kind == "reap":
            return self._fault_reap(t, item[2], item[3], item[4])
        raise ValueError(f"unknown fault work item {item!r}")

    def _fault_inject(self, t: float, ev) -> list[tuple]:
        st = self.fault_stats
        if ev.kind == "link_down":
            st.link_down_windows += 1
            self._link_down_until = max(self._link_down_until, t + ev.dur)
            if self.obs is not None:
                self.obs.on_fault(t, "link_down", until=t + ev.dur)
            return []
        if ev.kind == "crash":
            inst = pick_target(ev, self._fault_candidates(ev.role, t))
            if inst is None:
                st.skipped += 1
                return []
            return self._fault_crash(t, inst, ev)
        if ev.kind == "straggler":
            inst = pick_target(ev, self._fault_candidates(ev.role, t))
            if inst is None:
                st.skipped += 1
                return []
            st.straggler_windows += 1
            orig_v = 0.0
            inst.perf = ev.factor
            if isinstance(inst, Prefiller):
                orig_v = inst.v_p
                inst.v_p *= ev.factor
            else:
                inst._iter_cache = None
            if self.obs is not None:
                self.obs.on_fault(t, "straggler", instance=inst.iid,
                                  factor=ev.factor, dur=ev.dur)
            return [(t + ev.dur, "straggler_end", inst, orig_v)]
        if ev.kind == "swap_degrade":
            cands = [d for d in self._fault_candidates("decode", t)
                     if getattr(d, "kv", None) is not None]
            inst = pick_target(ev, cands)
            if inst is None:
                st.skipped += 1
                return []
            st.swap_degrade_windows += 1
            # per-instance KVTierConfig (built by _make_allocator), so
            # swapping the frozen cfg object degrades just this box
            orig_cfg = inst.kv.cfg
            inst.kv.cfg = replace(orig_cfg,
                                  swap_bw=orig_cfg.swap_bw * ev.factor)
            if self.obs is not None:
                self.obs.on_fault(t, "swap_degrade", instance=inst.iid,
                                  factor=ev.factor, dur=ev.dur)
            return [(t + ev.dur, "swap_restore", inst, orig_cfg)]
        raise ValueError(f"unknown fault kind {ev.kind!r}")

    def _fault_crash(self, t: float, inst, ev) -> list[tuple]:
        """Instance crash: queued work is lost, on-box KV is gone, the
        box is a dead husk.  With recovery on, the health monitor
        notices at its next probe tick, the husk leaves the books and a
        warm replacement boots (``startup_s`` x jitter) — the planner's
        Eq. 2-4 view counts the lost capacity as missing supply
        immediately.  With recovery off the husk stays on the books —
        counted by the planner, billed, skipped by routing only via
        ``draining`` — the lagging-signal contrast ``--bench=chaos``
        measures."""
        st = self.fault_stats
        st.crashes += 1
        # fleet state mutates outside _scale/_report: settle billing over
        # the closing constant segment first (see _cost_advance)
        self._cost_advance(t)
        pool = inst.pool
        inst.live = False
        inst.draining = True   # _ready() filters draining, not live
        if self.obs is not None:
            self.obs.on_fault(t, "crash", instance=inst.iid,
                              pool=pool.spec.name, role=pool.spec.role)
        if isinstance(inst, Prefiller):
            # in the event engine the head's completion event is already
            # in flight; its handler sees ``not live`` and requeues the
            # head exactly once — everything else requeues here
            keep = 1 if getattr(inst, "_busy", False) else 0
            lost = inst.queue[keep:]
            del inst.queue[keep:]
            inst._inflight_cache = None
            for req, _rem in lost:
                st.prefill_requeued += 1
                self._wait_add(req)
        else:
            self._fault_crash_decoder(t, inst)
        for g in self.fleet.groups.values():
            g._decode_cache = None
            g._prefill_cache = None
        if not self.faults.recovery:
            return []
        t_detect = self._monitor.detect_at(t)
        t_ready = self._monitor.restart_at(
            t_detect, pool.inst.chip.startup_s, ev.jitter)
        return [(t_detect, "reap", pool, inst, t_ready)]

    def _fault_crash_decoder(self, t: float, d):
        """Decode-side crash teardown: purge the paged KV store (audited
        clean), restart lost prefill work from the central queue, and
        re-enter residents exactly once — with recovery on after
        detection + a re-prefill shrunk by any surviving prefix-cache
        copy; with recovery off only after the client timeout, with the
        full context recomputed."""
        st = self.fault_stats
        cfg = self.faults
        g = self.fleet.groups[d.pool.spec.model]
        victims = list(d.active)
        for r in victims:
            d.remove_active(r)
        requeue_prefill = [r for r, _ in d.prefill_q] \
            + [r for _, r in d.kv_spill]
        d.prefill_q = []
        d._pq_cache = None
        d._iter_cache = None
        d.kv_spill = []
        d.oom_pending = []        # subset of active: already pulled out
        if d.kv is not None:
            d.kv.purge()
            d.kv.check()          # a crash must leave the books clean
        # prompts whose prefill/KV died on-box restart from the central
        # queue: the KV is gone, so their pipeline genuinely re-runs
        # (kv_ready is re-stamped at the *new* transfer completion)
        for r in requeue_prefill:
            r.deflect_tgt = None
            r.t_kv_ready = -1.0
            st.prefill_requeued += 1
            self._wait_add(r)
        v_pre = max(g.prefill.prof.v_prefill, 1e-9)
        for r in victims:
            r.n_evictions += 1
            if r.kv_swap is d.kv:
                r.kv_swap = None      # ticket died with the allocator
            ctx = int(r.src.in_len + r.generated)
            if cfg.recovery:
                # self-healing re-entry: re-probe surviving decoders'
                # prefix caches (the dead box is already non-ready) so
                # the recompute only covers the uncached suffix
                r.kv_prefix = None
                r.kv_hit_tokens = 0
                hit = 0
                if self._kv_on:
                    self._kv_lookup(g, r, t)
                    hit = r.kv_hit_tokens
                delay = cfg.detect_s + max(ctx - hit, 0) / v_pre
            else:
                delay = cfg.client_timeout_s + ctx / v_pre
            r.decode_time += delay
            st.residents_requeued += 1
            entry = (t + delay, r)
            self._pending_add(entry)
            self._on_requeue(entry)

    def _fault_reap(self, t: float, pool, inst, t_ready: float
                    ) -> list[tuple]:
        """Health-monitor detection fired: the husk leaves the books and
        its warm replacement starts booting — the lost capacity shows up
        in the planner's very next observation as missing supply instead
        of waiting for queue backlog to build."""
        self._cost_advance(t)
        if inst in pool.instances:
            pool.instances.remove(inst)
        repl = self._spawn(pool, t_ready)
        pool.instances.append(repl)
        self.fault_stats.restarts += 1
        for g in self.fleet.groups.values():
            g._decode_cache = None
            g._prefill_cache = None
        if self.obs is not None:
            self.obs.on_recovery(t, "restart", instance=inst.iid,
                                 replacement=repl.iid, ready_t=t_ready,
                                 pool=pool.spec.name)
        self._after_scale(t)      # event engine schedules the wake
        return []

    def _link_wait(self, t: float) -> Optional[float]:
        """KVC transfer attempted during a link outage.  Recovery on:
        bounded retry with exponential backoff — the transfer departs at
        the first retry past the window's end; None when the ladder is
        exhausted inside the window (recompute-at-prefill fallback).
        Recovery off: the sender is blind — the transfer vanishes into
        the dead link and is retransmitted only on client timeout, so the
        wait is whole timeout multiples, not the oracle remainder."""
        cfg = self.faults
        st = self.fault_stats
        until = self._link_down_until
        if not cfg.recovery:
            wait = cfg.client_timeout_s
            while t + wait < until:
                wait += cfg.client_timeout_s
            return wait
        wait = 0.0
        for i in range(cfg.max_retries):
            st.kvc_retries += 1
            wait += cfg.backoff0_s * (2.0 ** i)
            if t + wait >= until:
                st.kvc_retry_backoff_s += wait
                return wait
        return None

    # ------------------------------------------------------------------
    def _fleet_observation(self, t: float) -> FleetObservation:
        """Per-pool snapshots + per-model gateway aggregates: what the
        metrics plane reports each interval."""
        snaps: dict[str, PoolSnapshot] = {}
        for name, pool in self.pools.items():
            insts = pool.instances
            ready = [i for i in insts if i.ready(t)]
            snap = PoolSnapshot(name, pool.spec.role, pool.spec.model,
                                count=len(insts), ready=len(ready))
            snap.idle = sum(1 for i in ready if i.idle and not i.draining)
            snap.draining = sum(1 for i in insts if i.draining)
            if self._fault_eff:
                # measured effective velocity under straggler windows —
                # surfaced only on the self-healing path so the default
                # observation stays byte-stable
                perfs = [i.perf for i in ready if not i.draining]
                if perfs:
                    snap.eff_perf = float(sum(perfs) / len(perfs))
            if pool.spec.role == "prefill":
                snap.queue_requests = sum(len(p.queue) for p in insts)
                snap.inflight_tokens = sum(p.inflight_tokens()
                                           for p in insts)
            else:
                snap.inflight = sum(len(d.active) for d in insts)
                snap.inflight_tokens = sum(d.inflight_tokens()
                                           for d in insts)
                utils = [d.mem_util() for d in ready]
                snap.mem_util = float(np.mean(utils)) if utils else 0.0
                if pool.spec.prefill_chunking > 0:
                    # chunked absorption in progress: Eq. 2 discounts it
                    snap.deflected_rate = deflected_prefill_rate(ready)
            snaps[name] = snap
        win = [(ts, r) for ts, r in self._arrivals if t - ts <= 1.0]
        gateway: dict[str, GatewayStats] = {}
        for model in self.fleet.groups:
            mwin = [r for _, r in win
                    if (r.model or self.fleet.default_model) == model]
            by_bucket: dict[str, float] = {}
            for r in mwin:
                lam = r.src.in_len + _pred_out(r)
                by_bucket[r.bucket_pred] = by_bucket.get(r.bucket_pred, 0) \
                    + lam
            queued = sum(
                1 for r in self.wait_queue
                if (r.model or self.fleet.default_model) == model)
            g = self.fleet.groups[model]
            gateway[model] = GatewayStats(
                token_rate_in=sum(r.src.in_len for r in mwin) / 1.0,
                token_rate_by_bucket=by_bucket, rps=len(mwin) / 1.0,
                queued=queued,
                # is_burst is idempotent for monotone t (the windows only
                # trim), so observing it here never perturbs the per-
                # arrival detector state the routing path reads
                burst=bool(g.router.burst.is_burst(t)))
        return FleetObservation(t=t, pools=snaps, gateway=gateway)

    def _observation(self, t: float) -> Observation:
        """Legacy flat snapshot of the default model group."""
        return flat_observation(self.fleet.default_model,
                                self._fleet_observation(t))

    def _scale(self, t: float):
        """Execute the policy's ``FleetPlan`` pool by pool, in declaration
        order.  Convertible pools are fixed (§IV-C2) outside explicit
        ``plan.spills`` and pools the plan does not target are left alone.

        Scale-down: pools named in ``plan.drain`` drain — victims are
        marked ``draining`` (no new work, residents finish, billed until
        removal) and reaped once idle — while legacy plans keep the
        historical idle-only immediate eviction byte-for-byte.  Both
        respect the pool's ``min`` floor."""
        obs = self._fleet_observation(t)
        plan = self.policy.plan(obs)
        if self.obs is not None:
            # decision log: observation + plan + the policy's Eq. 2-4
            # intermediates, before execution mutates the fleet
            self.obs.on_plan(t, obs, plan, self.policy.last_debug)
        # fleet membership changes only below: settle the cost integral
        # over the closing constant segment first
        self._cost_advance(t)
        for name, pool in self.pools.items():
            if pool.spec.role == "convertible" or name not in plan.targets:
                continue
            startup = 0.0 if name in plan.live \
                else pool.inst.chip.startup_s
            want = min(plan.targets[name], self.max_instances)
            if name in plan.drain:
                self._scale_drain(pool, want, t, startup)
                continue
            while len(pool.instances) < want:
                pool.instances.append(self._spawn(pool, t + startup))
            while len(pool.instances) > max(want, pool.spec.min):
                idle = [i for i in pool.instances if i.idle]
                if not idle:
                    break
                idle[-1].live = False
                pool.instances.remove(idle[-1])
        for src, dst, n in plan.spills:
            self._execute_spill(src, dst, n, t)
        for g in self.fleet.groups.values():
            g._decode_cache = None
            g._prefill_cache = None
        self._after_scale(t)

    def _scale_drain(self, pool: Pool, want: int, t: float, startup: float):
        """Drain-based resize: reap drained-and-idle victims, then close
        the gap to ``want`` counting only *active* (non-draining)
        instances — scale-up cancels drains first (instant capacity, the
        box never left), scale-down marks the idlest actives draining."""
        for i in [x for x in pool.instances if x.draining and x.idle]:
            i.live = False
            pool.instances.remove(i)
        active = [i for i in pool.instances if not i.draining]
        want = max(want, pool.spec.min)
        if len(active) < want:
            for i in pool.instances:
                if i.draining:
                    i.draining = False
                    active.append(i)
                    if len(active) >= want:
                        break
            while len(active) < want:
                i = self._spawn(pool, t + startup)
                pool.instances.append(i)
                active.append(i)
        elif len(active) > want:
            # idle victims first (they reap on the next pass); busy ones
            # keep iterating — and billing — until their residents finish
            excess = len(active) - want
            victims = [i for i in reversed(active) if i.idle][:excess]
            if len(victims) < excess:
                busy = [i for i in reversed(active) if not i.idle]
                victims += busy[:excess - len(victims)]
            for i in victims:
                i.draining = True
                if self.obs is not None:
                    self.obs.on_drain(t, pool.spec.name, i)

    def _execute_spill(self, src: str, dst: str, n: int, t: float):
        """Move up to ``n`` idle instances from convertible pool ``src``
        to ``dst`` (cross-model loan/return): the box is re-imaged with
        the destination model's weights, so it leaves immediately and
        joins the destination pool after its chip's startup latency."""
        sp, dp = self.pools.get(src), self.pools.get(dst)
        if sp is None or dp is None or n <= 0:
            return
        movable = [i for i in sp.instances
                   if i.ready(t) and i.idle and not i.draining]
        moved = movable[:n]
        for i in moved:
            i.live = False
            sp.instances.remove(i)
            dp.instances.append(self._spawn(dp, t + dp.inst.chip.startup_s))
        if moved and self.obs is not None:
            self.obs.on_spill(t, src, dst, len(moved))

    def _cost_advance(self, t: float):
        """Advance the dollar-billing integral to ``t``.  Exact because
        fleet membership only changes inside ``_scale`` (which settles
        the closing segment before touching any pool), ``_report`` (the
        final segment), and the chaos engine's crash/reap transitions
        (``_fault_crash``/``_fault_reap``, which likewise settle before
        mutating): between those points the per-pool cost rate is
        constant, so one multiply per pool per scale interval replaces
        any per-tick/per-event accumulation."""
        dt = t - self._cost_t0
        if dt > 0.0:
            pc = self.pool_cost
            total = 0.0
            for name, pool in self.pools.items():
                rate = sum(i.spec.cost_rate for i in pool.instances)
                if rate > 0.0:
                    c = rate * dt
                    pc[name] += c
                    total += c
            self.cost_dollars += total
        self._cost_t0 = t

    def _after_scale(self, t: float):
        """Engine hook: schedule wake-ups for newly provisioned instances."""

    def _snapshot_every(self, t_end: float) -> float:
        """Timeline snapshot cadence: the explicit ``snapshot_interval``
        knob, else the historical 0.2 s stretched so a run never records
        more than ~4000 rows (multi-hour traces previously grew the
        timeline unboundedly)."""
        si = self.snapshot_interval
        if si is None:
            si = max(0.2, t_end / 4000.0)
        return si

    # ------------------------------------------------------------------
    def _gpu_count(self, t: float) -> int:
        """Billing: every *provisioned* instance — booting or ready — burns
        GPUs; instances removed by scale-down stop billing because they
        leave their pool."""
        del t
        return sum(i.spec.gpus for pool in self.pools.values()
                   for i in pool.instances)

    def _unfinished(self):
        out = []
        for d in self.decoders + self.convertibles:
            out += d.active
            out += [r for r, _ in d.prefill_q]
            out += [r for _, r in d.kv_spill]
        for p in self.prefillers:
            out += [r for r, _ in p.queue]
        out += [r for _, r in self.pending_decode]
        out += self.wait_queue
        return out

    def _snapshot(self, t: float) -> dict:
        prefillers, decoders = self.prefillers, self.decoders
        snap = {
            "t": t,
            "prefillers": len(prefillers),
            "decoders": len(decoders),
            "convertibles": len(self.convertibles),
            "queue": sum(len(p.queue) for p in prefillers),
            "inflight": sum(len(d.active)
                            for d in decoders + self.convertibles),
            "mem_util": float(np.mean([d.mem_util() for d in decoders]))
            if decoders else 0.0,
            "pools": {name: len(pool.instances)
                      for name, pool in self.pools.items()},
        }
        if self.obs is not None:
            # samples the metrics registry on the timeline cadence and
            # adds one additive "obs" key (velocities, occupancy, cost
            # rate) — the stock keys above never change
            self.obs.on_snapshot(snap, self)
        return snap

    def _report(self, t_end: float) -> SimReport:
        self._cost_advance(t_end)      # settle the final billing segment
        requests = self.finished + self._unfinished()
        if self.obs is not None:
            self.obs.finalize(requests, t_end)
        return SimReport(self.policy.name, requests,
                         self.gpu_seconds, t_end, self.timeline,
                         engine=self.engine,
                         preemptions=list(self.preemption_log),
                         kv=self.kv_stats.summary() if self._kv_on else {},
                         gw=self.gw_stats.summary() if self._gw_on else {},
                         faults=self.fault_stats.summary()
                         if self.faults is not None else {},
                         n_events=getattr(self, "n_events", 0),
                         n_deflected=self.n_deflected,
                         cost_dollars=self.cost_dollars,
                         pool_cost=dict(self.pool_cost),
                         obs=self.obs)


def _pred_out(req: SimRequest) -> int:
    return BUCKET_OUTPUT[req.bucket_pred.split("-")[1]]
