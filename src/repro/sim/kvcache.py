"""Tiered KV-cache subsystem: paged blocks, host-DRAM offload, prefix reuse.

The sim's original memory model was a single flat HBM byte counter per
decoder (``Decoder.mem_used``/``mem_cap``), so preemption charged a
synthetic swap delay with no memory hierarchy behind it and conversational
traces got zero benefit from shared prefixes.  This module is the memory
hierarchy (DESIGN.md "KV-tier fidelity"):

  * **Paged block allocator** — KV lives in fixed-size blocks of
    ``block_size`` tokens (vLLM-style paging); a request reserves
    ``ceil(((in_len + out_len) * kv_tok + state_fix) / block_bytes)``
    blocks at admission (conservative full-length reservation, so decode
    never OOMs mid-iteration — the same invariant the legacy byte counter
    checked at admission).
  * **Two-tier store** — an HBM tier (blocks carved out of the decoder's
    usable HBM after weights/reserve) and a host-DRAM offload tier
    (capacity and swap bandwidth per chip, ``core.hardware.ChipSpec
    .host_dram_cap``/``swap_bw``).  Pause-requeue preemption becomes a
    real swap: the victim's owned blocks move to the DRAM tier (swap-out
    overlapped, HBM freed immediately) and the swap-in stall is charged at
    the swap bandwidth; when the tier is full the victim falls back to a
    full recompute, exactly like evict-lowest.
  * **Prefix tree with copy-on-write reuse** — finished requests leave
    their prompt+output blocks cached under their session id (ref-counted;
    reclaimed LRU under pressure, demoted to the DRAM tier when it has
    room).  A same-session follow-up whose prompt extends the cached
    prefix shares those blocks copy-on-write: shared blocks are read-only
    and only ever *referenced* (never written — entries round down to full
    blocks, so the partially-filled tail block is always freshly
    allocated), and the prefiller only computes the uncached suffix.
    Sessions are chains (each follow-up extends one prefix), so the radix
    tree degenerates to one longest-prefix entry per session — the entry
    *is* the radix path.

Bookkeeping is double-entry: every block is either on the free list or in
``ref`` (total references: allocations + pins + cache entries), and a
separate ``hard`` count (allocations + pins only) drives the memory-
pressure signal — cached-but-reclaimable blocks do not count against
admission.  ``check()`` re-derives both from first principles; the
property tests in ``tests/test_kvcache.py`` call it after every operation
and at the end of end-to-end runs on both engines.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field
from typing import Optional


class KVError(RuntimeError):
    """Allocator invariant violation (double admit/free, over-allocation)."""


# ---------------------------------------------------------------------------
# Cluster-wide counters (shared by every decoder's allocator + the engines)
# ---------------------------------------------------------------------------

@dataclass
class KVStats:
    """Aggregated across all decoders of a cluster; ``SimReport.kv``."""
    lookups: int = 0
    hits: int = 0                 # arrivals that reused a cached prefix
    hit_tokens: int = 0           # prompt tokens served from cache
    prompt_tokens: int = 0        # all prompt tokens seen by lookups
    offload_bytes: float = 0.0    # bytes written to the host-DRAM tier
    demotions: int = 0            # prefix entries demoted HBM -> DRAM
    swap_outs: int = 0            # victims swapped to the DRAM tier
    swap_ins: int = 0             # swapped victims restored to HBM
    swap_fallbacks: int = 0       # pause-requeue fell back to recompute
    swap_stall_s: float = 0.0     # stalls charged at swap/interconnect bw
    prefix_migrations: int = 0    # hits admitted away from the owner
    prefix_recomputes: int = 0    # pinned prefix lost before admission
    total_blocks: int = 0         # HBM blocks across all live allocators
    cur_used: int = 0             # hard-used blocks right now (all tiers' HBM)
    peak_used: int = 0            # watermark of cur_used
    peak_frac: float = 0.0        # watermark of any one decoder's used/total

    def on_used_delta(self, delta: int, frac: float):
        self.cur_used += delta
        self.peak_used = max(self.peak_used, self.cur_used)
        self.peak_frac = max(self.peak_frac, frac)

    def summary(self) -> dict:
        return {
            "prefix_hit_rate": self.hit_tokens / max(self.prompt_tokens, 1),
            "prefix_hits": self.hits,
            "hit_tokens": self.hit_tokens,
            "offload_bytes": self.offload_bytes,
            "swap_outs": self.swap_outs,
            "swap_ins": self.swap_ins,
            "swap_fallbacks": self.swap_fallbacks,
            "swap_stall_s": self.swap_stall_s,
            "peak_blocks": self.peak_used,
            "peak_blocks_frac": self.peak_frac,
        }


# ---------------------------------------------------------------------------
# Per-decoder allocator
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class KVTierConfig:
    """Resolved tier geometry for one decoder."""
    block_size: int               # tokens per block
    block_bytes: float            # block_size * kv_bytes_per_token
    n_hbm: int                    # HBM blocks (usable HBM / block_bytes)
    n_dram: int                   # host-DRAM tier blocks (0 = no tier)
    swap_bw: float                # HBM <-> host bytes/s
    prefix_cache: bool = False


@dataclass
class _Allocation:
    """One resident request's blocks: CoW-shared prefix + owned rest."""
    shared: list[int] = field(default_factory=list)
    owned: list[int] = field(default_factory=list)
    shared_tokens: int = 0


@dataclass
class _CacheEntry:
    """Longest cached prefix of one session (the radix path)."""
    ids: tuple[int, ...]          # HBM blocks; empty once demoted to DRAM
    tokens: int                   # full-block tokens covered
    last_use: float
    tier: str = "hbm"             # "hbm" | "dram"
    dram_blocks: int = 0          # DRAM blocks held once demoted
    pins: int = 0                 # in-flight arrivals relying on this entry


@dataclass
class _Pin:
    entry: _CacheEntry
    ids: tuple[int, ...]          # () for DRAM-tier pins
    tokens: int
    tier: str


class KVAllocator:
    """Paged two-tier KV store for one decoder (see module docstring)."""

    def __init__(self, cfg: KVTierConfig, stats: Optional[KVStats] = None):
        if cfg.block_size <= 0 or cfg.n_hbm <= 0:
            raise KVError(f"degenerate tier geometry: {cfg}")
        self.cfg = cfg
        self.stats = stats or KVStats()
        self.stats.total_blocks += cfg.n_hbm
        self.free: list[int] = list(range(cfg.n_hbm - 1, -1, -1))
        self.ref: dict[int, int] = {}          # block -> total references
        self.hard: dict[int, int] = {}         # block -> alloc+pin references
        self.hard_used = 0                     # len({b: hard[b] > 0})
        self.allocs: dict[int, _Allocation] = {}      # rid -> allocation
        # key -> prefix entry; keys are session ids (ints) or gateway
        # shared-prompt aliases (("sys", prompt_id) tuples) — any hashable
        self.sessions: dict = {}
        self.pins: dict[int, _Pin] = {}               # rid -> arrival pin
        # entries dropped (replaced by a longer prefix) while arrival pins
        # still referenced them: kept here until the pins drain so
        # ``check()`` can prove no pin ever dangles (the pin-leak audit)
        self._retired: list[_CacheEntry] = []
        self.dram_free = cfg.n_dram
        self.tickets: dict[int, int] = {}      # rid -> swapped-out blocks
        # ---- admission-path cache (DESIGN.md "Performance") ----
        # ``available()`` walks every cache entry's blocks; on the hot
        # admission path it is probed once per (pending request, candidate
        # decoder) pair.  The allocator state version bumps on any ref/pin
        # /session mutation and keys a memo of the identical recompute.
        self._ver = 0
        self._avail_ver = -1
        self._avail_val = 0

    def _mutated(self):
        self._ver += 1

    # ---- geometry ----------------------------------------------------
    def blocks_for(self, nbytes: float) -> int:
        return max(int(-(-nbytes // self.cfg.block_bytes)), 1)

    def token_bytes(self, tokens: int) -> float:
        """KV bytes behind ``tokens`` cached tokens (block_bytes is
        block_size * kv_bytes_per_token, so this is tokens * kv_tok)."""
        return tokens * self.cfg.block_bytes / self.cfg.block_size

    def migration_stall(self, tokens: int, net_bw: float) -> float:
        """Charge shipping a cached prefix over the owner's interconnect
        to wherever the request was actually admitted; returns the stall.
        One definition for both charge sites (admission-time on-box
        migration and the cluster's penalty-requeue path)."""
        delay = self.token_bytes(tokens) / max(net_bw, 1e-9)
        self.stats.prefix_migrations += 1
        self.stats.swap_stall_s += delay
        return delay

    def need_blocks(self, rid: int, nbytes: float) -> int:
        """Blocks a fresh admission must allocate, net of the arrival pin's
        CoW-shared prefix blocks (if the pin lives on this decoder)."""
        pin = self.pins.get(rid)
        shared = len(pin.ids) if pin and pin.tier == "hbm" else 0
        return max(self.blocks_for(nbytes) - shared, 0)

    def available(self) -> int:
        """Free blocks plus blocks reclaimable from unpinned cache
        entries (cached prefixes never block an admission).  Memoized on
        the allocator state version — the recompute is the identical
        reduction, so the value is bitwise what the seed code returned."""
        if self._avail_ver != self._ver:
            reclaimable = sum(
                1 for e in self.sessions.values()
                if e.tier == "hbm" and e.pins == 0
                for b in e.ids if self.ref[b] == 1)
            self._avail_val = len(self.free) + reclaimable
            self._avail_ver = self._ver
        return self._avail_val

    def can_admit(self, rid: int, nbytes: float) -> bool:
        return self.need_blocks(rid, nbytes) <= self.available()

    def used_bytes(self) -> float:
        """Hard-used bytes (allocations + pins; cached-reclaimable blocks
        excluded) — the decoder's memory-pressure signal."""
        return self.hard_used * self.cfg.block_bytes

    @property
    def busy(self) -> bool:
        """In-flight arrivals rely on this decoder's cached prefixes; it
        must not be scaled down underneath them."""
        return bool(self.pins) or bool(self.allocs)

    # ---- internal ref bookkeeping ------------------------------------
    def _incref(self, b: int):
        self._mutated()
        self.ref[b] = self.ref.get(b, 0) + 1

    def _decref(self, b: int):
        self._mutated()
        n = self.ref.get(b, 0)
        if n <= 0:
            raise KVError(f"double free of block {b}")
        if n == 1:
            del self.ref[b]
            self.free.append(b)
        else:
            self.ref[b] = n - 1

    def _hard_inc(self, b: int):
        n = self.hard.get(b, 0)
        self.hard[b] = n + 1
        if n == 0:
            self.hard_used += 1
            self.stats.on_used_delta(+1, self.hard_used / self.cfg.n_hbm)

    def _hard_dec(self, b: int):
        n = self.hard.get(b, 0)
        if n <= 0:
            raise KVError(f"hard-ref underflow on block {b}")
        if n == 1:
            del self.hard[b]
            self.hard_used -= 1
            self.stats.on_used_delta(-1, self.hard_used / self.cfg.n_hbm)
        else:
            self.hard[b] = n - 1

    def _alloc(self, n: int) -> list[int]:
        while len(self.free) < n:
            if not self._reclaim_one():
                raise KVError(
                    f"out of HBM blocks: need {n}, free {len(self.free)}")
        out = []
        for _ in range(n):
            b = self.free.pop()
            self._incref(b)
            self._hard_inc(b)
            out.append(b)
        return out

    def _drop_entry(self, key):
        self._mutated()
        e = self.sessions.pop(key)
        if e.tier == "hbm":
            for b in e.ids:
                self._decref(b)
        else:
            self.dram_free += e.dram_blocks
        # the entry's own storage is gone either way (HBM refs released,
        # DRAM blocks freed — byte-identical to the historical drop); if
        # arrival pins still reference it, park it on the retired list so
        # the no-stale-pins invariant can account for them until unpin
        e.ids, e.dram_blocks, e.tier = (), 0, "retired"
        if e.pins > 0:
            self._retired.append(e)

    def _reclaim_one(self) -> bool:
        """Reclaim the LRU unpinned HBM cache entry; demote it to the DRAM
        tier when the tier has room, drop it otherwise.  Returns False when
        nothing is reclaimable."""
        cands = [(sid, e) for sid, e in self.sessions.items()
                 if e.tier == "hbm" and e.pins == 0]
        if not cands:
            return False
        # keys mix legacy int session ids with ("sys", pid) tuples; the
        # type flag partitions them so the tie-break never compares across
        # types (ints first, preserving the historical int ordering)
        sid, e = min(cands, key=lambda kv: (
            kv[1].last_use, isinstance(kv[0], tuple),
            kv[0] if isinstance(kv[0], tuple) else (kv[0],)))
        n = len(e.ids)
        if self.dram_free >= n > 0:
            self.dram_free -= n
            self.stats.demotions += 1
            self.stats.offload_bytes += n * self.cfg.block_bytes
            for b in e.ids:
                self._decref(b)
            e.ids, e.tier, e.dram_blocks = (), "dram", n
        else:
            self._drop_entry(sid)
        return True

    # ---- prefix tree -------------------------------------------------
    def lookup(self, key, prefix_len: int) -> tuple[int, str]:
        """Reusable full-block prefix tokens under ``key`` — a session id
        (int) or a gateway shared-prompt alias (tuple) — and the tier they
        live in.  (0, "") on miss."""
        if not self.cfg.prefix_cache or key is None \
                or (isinstance(key, int) and key < 0):
            return 0, ""
        e = self.sessions.get(key)
        if e is None:
            return 0, ""
        bs = self.cfg.block_size
        usable = (min(e.tokens, prefix_len) // bs) * bs
        return (usable, e.tier) if usable > 0 else (0, "")

    def pin(self, rid: int, key, tokens: int, t: float):
        """Reserve a looked-up prefix for ``rid`` until it is admitted (or
        the hit is abandoned): HBM pins take a reference on each shared
        block, DRAM pins just hold the entry against eviction.  ``key`` is
        a session id or a gateway shared-prompt alias."""
        if rid in self.pins:
            raise KVError(f"request {rid} already holds a pin")
        self._mutated()
        e = self.sessions[key]
        e.last_use = t
        e.pins += 1
        if e.tier == "hbm":
            ids = e.ids[:tokens // self.cfg.block_size]
            for b in ids:
                self._incref(b)
                self._hard_inc(b)
            self.pins[rid] = _Pin(e, ids, tokens, "hbm")
        else:
            self.pins[rid] = _Pin(e, (), tokens, "dram")

    def unpin(self, rid: int):
        pin = self.pins.pop(rid, None)
        if pin is None:
            return
        self._mutated()
        self._entry_unpin(pin.entry)
        for b in pin.ids:
            self._hard_dec(b)
            self._decref(b)

    def _entry_unpin(self, e: _CacheEntry):
        e.pins -= 1
        if e.pins == 0 and e.tier == "retired":
            # identity removal: _CacheEntry is a value-comparing dataclass
            # and two drained retired entries can be field-equal
            for i, x in enumerate(self._retired):
                if x is e:
                    del self._retired[i]
                    break

    # ---- admission / release -----------------------------------------
    def admit(self, rid: int, nbytes: float):
        """Allocate ``rid``'s full-length reservation, consuming its pin's
        CoW-shared blocks if the pin lives here.  Callers must have checked
        ``can_admit``; failure raises (a control-plane bug, not
        backpressure)."""
        if rid in self.allocs:
            raise KVError(f"request {rid} admitted twice")
        self._mutated()
        pin = self.pins.pop(rid, None)
        shared: list[int] = []
        shared_tokens = 0
        if pin is not None:
            self._entry_unpin(pin.entry)
            if pin.tier == "hbm":
                # the pin's block+hard references transfer to the allocation
                shared, shared_tokens = list(pin.ids), pin.tokens
            # a DRAM-tier pin must be resolved (penalized) by the cluster
            # before admission; tolerate it here as a plain miss
        n_new = max(self.blocks_for(nbytes) - len(shared), 0)
        owned = self._alloc(n_new)
        self.allocs[rid] = _Allocation(shared, owned, shared_tokens)

    def try_grow(self, rid: int, nbytes: float) -> Optional[int]:
        """Allocate-on-generate paging: extend ``rid``'s allocation so it
        covers ``nbytes`` total.  Returns the number of blocks added (0 if
        already covered), or None when the decoder is out of blocks even
        after reclaiming unpinned cache entries — the mid-decode OOM the
        cluster resolves by preempting (never raises: exhaustion is
        backpressure here, not a control-plane bug)."""
        a = self.allocs.get(rid)
        if a is None:
            raise KVError(f"grow of unknown request {rid}")
        need = self.blocks_for(nbytes) - len(a.shared) - len(a.owned)
        if need <= 0:
            return 0
        if self.available() < need:
            return None
        a.owned.extend(self._alloc(need))
        return need

    def cache_alias(self, key, rid: int, tokens: int, t: float) -> int:
        """Cache the first ``tokens`` (rounded down to full blocks) of
        ``rid``'s *live* allocation under an additional key — the gateway's
        shared-prompt alias, taken just before ``release`` so cross-session
        arrivals can reuse the hot system prompt.  Entry references only
        (reclaimable, no admission pressure).  A shorter or pin-free
        existing alias is replaced; a pinned one is left alone (in-flight
        arrivals rely on it).  Returns the tokens cached (0 if skipped)."""
        if not self.cfg.prefix_cache or tokens <= 0:
            return 0
        a = self.allocs.get(rid)
        if a is None:
            raise KVError(f"alias of unknown request {rid}")
        bs = self.cfg.block_size
        blocks = a.shared + a.owned
        keep = min(tokens // bs, len(blocks))
        if keep <= 0:
            return 0
        old = self.sessions.get(key)
        if old is not None:
            if old.pins > 0 or old.tokens >= keep * bs:
                old.last_use = t
                return 0
            self._drop_entry(key)
        ids = blocks[:keep]
        for b in ids:
            self._incref(b)
        self.sessions[key] = _CacheEntry(tuple(ids), keep * bs, t)
        return keep * bs

    def install(self, key, tokens: int, t: float) -> bool:
        """Hot-prefix replication landing: materialize a ``tokens``-long
        cache entry under ``key`` (the copy shipped over the interconnect
        from the prefix's origin decoder).  Cache-only blocks — entry
        references, never hard — so a replica competes with other cached
        prefixes for space but never reduces admission headroom.  Returns
        False (a no-op) when the blocks can't be found even after
        reclaiming, or when a pinned/longer entry already holds the key."""
        if not self.cfg.prefix_cache:
            return False
        bs = self.cfg.block_size
        n = tokens // bs
        if n <= 0:
            return False
        old = self.sessions.get(key)
        if old is not None:
            if old.pins > 0 or old.tokens >= n * bs:
                old.last_use = t
                return False
            self._drop_entry(key)
        while len(self.free) < n:
            if not self._reclaim_one():
                return False
        ids = []
        for _ in range(n):
            b = self.free.pop()
            self._incref(b)
            ids.append(b)
        self.sessions[key] = _CacheEntry(tuple(ids), n * bs, t)
        return True

    def release(self, rid: int, sid: int, ctx_tokens: int, t: float):
        """Finish: free the reservation, leaving the prompt+output prefix
        cached under ``sid`` (replacing any shorter entry) for same-session
        follow-ups."""
        a = self.allocs.pop(rid, None)
        if a is None:
            raise KVError(f"release of unknown request {rid}")
        blocks = a.shared + a.owned
        if self.cfg.prefix_cache and sid >= 0:
            bs = self.cfg.block_size
            keep_tokens = min((ctx_tokens // bs) * bs, len(blocks) * bs)
            keep = blocks[:keep_tokens // bs]
            if keep:
                for b in keep:           # entry refs before allocation derefs
                    self._incref(b)
                if sid in self.sessions:
                    self._drop_entry(sid)
                self.sessions[sid] = _CacheEntry(tuple(keep), keep_tokens, t)
        for b in blocks:
            self._hard_dec(b)
            self._decref(b)

    def drop(self, rid: int):
        """Evict with KV discarded (recompute on re-admission)."""
        a = self.allocs.pop(rid, None)
        if a is None:
            raise KVError(f"drop of unknown request {rid}")
        for b in a.shared + a.owned:
            self._hard_dec(b)
            self._decref(b)

    # ---- swap flows ---------------------------------------------------
    def owned_blocks(self, rid: int) -> int:
        a = self.allocs.get(rid)
        return len(a.owned) if a else 0

    def swap_out(self, rid: int) -> tuple[str, float]:
        """Pause-requeue: move ``rid``'s owned blocks to the DRAM tier
        (shared prefix blocks just unref — they stay cached for others).
        Returns ("swap", bytes_moved) or, when the tier is full,
        ("drop", bytes_discarded) — the recompute fallback."""
        a = self.allocs.pop(rid, None)
        if a is None:
            raise KVError(f"swap_out of unknown request {rid}")
        for b in a.shared:
            self._hard_dec(b)
            self._decref(b)
        n = len(a.owned)
        nbytes = n * self.cfg.block_bytes
        if 0 < n <= self.dram_free:
            self.dram_free -= n
            self.tickets[rid] = n
            for b in a.owned:
                if self.ref.get(b) != 1:
                    raise KVError(f"owned block {b} has foreign refs")
                self._hard_dec(b)
                self._decref(b)
            self.stats.swap_outs += 1
            self.stats.offload_bytes += nbytes
            return "swap", nbytes
        for b in a.owned:
            self._hard_dec(b)
            self._decref(b)
        return "drop", nbytes

    def swap_in_release(self, rid: int) -> int:
        """The swapped victim was re-admitted (here or elsewhere): release
        its DRAM ticket."""
        n = self.tickets.pop(rid, 0)
        self.dram_free += n
        if n:
            self.stats.swap_ins += 1
        return n

    # ---- crash teardown ------------------------------------------------
    def purge(self):
        """Crash teardown (sim.faults): the box's memory is gone, so every
        allocation, arrival pin, cached prefix, and swap ticket is
        discarded in one sweep.  Leaves the allocator empty-but-consistent
        — ``check()`` passes, ``busy`` releases — so a dead husk audits
        clean while it waits for the health monitor to reap it.  In-flight
        arrivals that pinned a prefix here fall through to the existing
        pin-lost path (``unpin`` tolerates the missing pin)."""
        for rid in list(self.pins):
            self.unpin(rid)
        for rid in list(self.allocs):
            self.drop(rid)
        for key in list(self.sessions):
            self._drop_entry(key)
        self.dram_free += sum(self.tickets.values())
        self.tickets.clear()
        # pins drained first, so no entry can have been parked as retired
        self._retired.clear()
        self._mutated()

    # ---- invariants ----------------------------------------------------
    def check(self):
        """Double-entry audit: re-derive every refcount from allocations +
        pins + cache entries and compare.  Blocks never leak, are never
        double-freed, and the two tiers always sum to their capacities."""
        expect: Counter = Counter()
        hard_expect: Counter = Counter()
        for a in self.allocs.values():
            for b in a.shared + a.owned:
                expect[b] += 1
                hard_expect[b] += 1
        for p in self.pins.values():
            for b in p.ids:
                expect[b] += 1
                hard_expect[b] += 1
        for e in self.sessions.values():
            for b in e.ids:
                expect[b] += 1
        if dict(expect) != self.ref:
            raise KVError(f"ref drift: expected {dict(expect)}, "
                          f"have {self.ref}")
        if dict(hard_expect) != self.hard:
            raise KVError("hard-ref drift")
        if self.hard_used != len(hard_expect):
            raise KVError("hard_used drift")
        if set(self.free) & set(self.ref):
            raise KVError("block both free and referenced")
        if len(self.free) != len(set(self.free)):
            raise KVError("duplicate free-list entry")
        if len(self.free) + len(self.ref) != self.cfg.n_hbm:
            raise KVError(
                f"HBM blocks leaked: {len(self.free)} free + "
                f"{len(self.ref)} referenced != {self.cfg.n_hbm}")
        dram_held = sum(self.tickets.values()) + sum(
            e.dram_blocks for e in self.sessions.values()
            if e.tier == "dram")
        if self.dram_free + dram_held != self.cfg.n_dram:
            raise KVError("DRAM blocks leaked")
        # ---- no-stale-pins invariant (the pin-leak audit): every pin
        # references a tracked entry, every entry's pin count equals the
        # pins actually referencing it, and the retired list holds exactly
        # the dropped-but-still-pinned entries (storage already freed) ----
        live = {id(e): e for e in self.sessions.values()}
        retired = {id(e): e for e in self._retired}
        pin_counts: Counter = Counter()
        for rid, p in self.pins.items():
            eid = id(p.entry)
            if eid not in live and eid not in retired:
                raise KVError(f"stale pin {rid}: entry neither live "
                              f"nor retired")
            pin_counts[eid] += 1
        for eid, e in {**live, **retired}.items():
            if e.pins != pin_counts.get(eid, 0):
                raise KVError(f"entry pin-count drift: {e.pins} recorded, "
                              f"{pin_counts.get(eid, 0)} actual")
        for e in self._retired:
            if e.pins <= 0:
                raise KVError("retired entry with no pins")
            if e.ids or e.dram_blocks:
                raise KVError("retired entry still holds storage")
