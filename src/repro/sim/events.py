"""Discrete-event PD-disaggregated cluster simulator.

Same cluster, same roofline, same *unmodified* control plane as the fluid
engine (``sim.cluster.Cluster``) — but time advances on an event heap
instead of fixed dt ticks, so request-level tail behavior is exact:

  * decode runs per-iteration continuous batching: each decoder iteration
    is one event whose length is the shared roofline ``Decoder.iter_time``;
    every resident request emits exactly one token per iteration, and
    admissions join at iteration boundaries (the mechanism DistServe/
    DynaServe show dominates tail latency);
  * prefill is serialized per prefiller (batch ~1): one completion event
    per request at ``in_len / v_prefill``;
  * KVC transfers complete at interconnect-bandwidth delay events;
  * instance startup/conversion appears as wake events at ``ready_t``;
  * autoscaling fires every ``scale_interval`` as in the fluid engine.

TTFT/TPOT therefore come out strictly per-request (non-smeared): admission
and finish happen at exact event timestamps and ``generated`` advances in
whole tokens.  The differential suite (tests/test_sim_differential.py)
asserts this engine and the fluid engine agree on throughput, mean
TTFT/TPOT, and scaling decisions for every trace x policy; the
heterogeneous/multi-model variants are in tests/test_fleet_api.py.

Pools: every instance this engine wakes, kicks, or completes belongs to
a named pool (``sim.instances.Pool``); per-iteration events carry the
instance, so mixed fleets (different chips/TP per pool, several models)
need no event-engine-specific handling — pool membership and per-model
routing live in the shared ``ClusterBase``.

Fidelity choices and the fluid-vs-event comparison are documented in
DESIGN.md.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Optional

from repro.sim.instances import ClusterBase, Decoder, Prefiller, SimReport, \
    SimRequest
from repro.sim.traces import TraceRequest

# granularity cap for prefill-only convertible iterations: with no decode
# batch resident there is no natural iteration boundary, so progress is
# checkpointed at least this often (the TPOT-SLO-scale chunk cadence)
_CONV_PREFILL_QUANTUM = 0.05


class EventCluster(ClusterBase):
    """Event-driven engine over the shared instance/control-plane layer."""

    engine = "events"

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._heap: list[tuple[float, int, str, tuple]] = []
        self._seq = itertools.count()

    # ------------------------------------------------------------------
    def _push(self, t: float, kind: str, *data):
        heapq.heappush(self._heap, (t, next(self._seq), kind, data))

    # ------------------------------------------------------------------
    def run(self, trace: list[TraceRequest],
            duration: Optional[float] = None) -> SimReport:
        trace = sorted(trace, key=lambda r: r.t)
        t_end = duration or (trace[-1].t + 60.0 if trace else 60.0)
        for tr in trace:
            if tr.t < t_end:
                self._push(tr.t, "arrival", SimRequest(tr))
        self._push(0.0, "scale")
        self._push(0.0, "snapshot")
        t_cur = 0.0
        while self._heap:
            te, _, kind, data = heapq.heappop(self._heap)
            if te >= t_end:
                break
            # integrate GPU-seconds over the piecewise-constant fleet
            self.gpu_seconds += self._gpu_count(t_cur) * (te - t_cur)
            t_cur = te
            getattr(self, "_ev_" + kind)(te, *data)
        self.gpu_seconds += self._gpu_count(t_cur) * (t_end - t_cur)
        return self._report(t_end)

    # ------------------------------------------------------------------
    # event handlers
    # ------------------------------------------------------------------
    def _ev_arrival(self, t: float, req: SimRequest):
        self._on_arrival(req, t)

    def _ev_scale(self, t: float):
        self._scale(t)
        self._drain_wait_queue(t)
        self._admit_pending(t)
        self._push(t + self.scale_interval, "scale")

    def _ev_snapshot(self, t: float):
        self.timeline.append(self._snapshot(t))
        self._push(t + 0.2, "snapshot")

    def _ev_wake(self, t: float, inst):
        """A provisioned instance finished booting."""
        inst._wake_scheduled = False
        if isinstance(inst, Prefiller):
            if inst in self.prefillers:
                self._drain_wait_queue(t)
                self._kick_prefiller(inst, t)
        else:
            if inst in self.decoders + self.convertibles:
                self._drain_wait_queue(t)
                self._admit_pending(t)
                self._kick_decoder(inst, t)

    def _ev_prefill_done(self, t: float, p: Prefiller, req: SimRequest):
        p._busy = False
        if p not in self.prefillers:
            # instance was scaled down mid-flight: requeue its head on the
            # central queue (should not happen — only idle instances are
            # removed — but stay safe)
            self.wait_queue.append(req)
            return
        if p.queue and p.queue[0][0] is req:
            p.queue.pop(0)
        kv_ready_t, _ = self._to_network(req, t)   # sets t_prefill_end
        self._push(kv_ready_t, "kv_ready")
        self._drain_wait_queue(t)          # prefill capacity freed (§IV-E)
        self._kick_prefiller(p, t)

    def _ev_kv_ready(self, t: float):
        self._admit_pending(t)

    def _ev_swap_done(self, t: float):
        """A preempted victim's swap/recompute (or a prefix hit's swap-in /
        migration) completed *exactly now*; retry admission.  The fluid
        engine approximates the same completion at tick granularity via
        its per-tick ``_admit_pending`` ready-time check (DESIGN.md
        "KV-tier fidelity")."""
        self._admit_pending(t)

    def _ev_iter_done(self, t: float, d: Decoder,
                      batch: list[tuple[SimRequest, int]], it: float):
        d._iter_pending = False
        if d not in self.decoders + self.convertibles:
            return
        # one token per resident request for this iteration; requests
        # preempted out of the decoder mid-iteration get no token — the
        # eviction-count stamp catches even a victim that was evicted and
        # re-admitted to this same decoder before the iteration completed
        resident = {id(r) for r in d.active}
        for r, n_ev in batch:
            if r.t_finish >= 0 or id(r) not in resident \
                    or r.n_evictions != n_ev:
                continue
            r.generated += 1.0
            r.decode_time += it
            if r.t_first_token < 0:
                # TTFT is exact: the first token exists when the first
                # decode iteration containing the request *completes*
                r.t_first_token = t
            if r.generated >= r.src.out_len:
                r.t_finish = t
                d._kv_release(r, t)
                self.finished.append(r)
        d.active = [r for r in d.active if r.t_finish < 0]
        # co-scheduled convertible prefill progress (Eq. 5 restricted rate)
        if d.is_convertible and d.prefill_q and d.conv:
            d.advance_prefill(d.conv.v_prefill * it, t)
        self._admit_pending(t)             # memory freed by completions
        self._kick_decoder(d, t)

    # ------------------------------------------------------------------
    # scheduling helpers
    # ------------------------------------------------------------------
    def _kick_prefiller(self, p: Prefiller, t: float):
        if getattr(p, "_busy", False) or not p.queue:
            return
        if not p.ready(t):
            self._schedule_wake(p)
            return
        req, rem = p.queue[0]
        p._busy = True
        self._push(t + rem / max(p.v_p, 1e-9), "prefill_done", p, req)

    def _kick_decoder(self, d: Decoder, t: float):
        if getattr(d, "_iter_pending", False):
            return
        if not d.ready(t):
            self._schedule_wake(d)
            return
        if d.active:
            it = d.iter_time()
            d._iter_pending = True
            self._push(t + it, "iter_done", d,
                       [(r, r.n_evictions) for r in d.active], it)
        elif d.is_convertible and d.prefill_q and d.conv:
            # prefill-only "iteration": no decode batch to pace it, so
            # checkpoint progress at the chunk cadence
            head_rem = d.prefill_q[0][1]
            v = max(d.conv.v_prefill, 1e-9)
            it = min(head_rem / v, _CONV_PREFILL_QUANTUM)
            d._iter_pending = True
            self._push(t + it, "iter_done", d, [], it)

    def _schedule_wake(self, inst):
        if not getattr(inst, "_wake_scheduled", False):
            inst._wake_scheduled = True
            self._push(inst.ready_t, "wake", inst)

    def _after_scale(self, t: float):
        for inst in self.prefillers + self.decoders + self.convertibles:
            if not inst.ready(t):
                self._schedule_wake(inst)

    # ------------------------------------------------------------------
    # control-plane hooks
    # ------------------------------------------------------------------
    def _submit_prefill_work(self, tgt, kind: str, req: SimRequest, t: float):
        super()._submit_prefill_work(tgt, kind, req, t)
        if kind == "prefiller":
            self._kick_prefiller(tgt, t)
        else:
            self._kick_decoder(tgt, t)

    def _after_admit(self, d: Decoder, t: float):
        self._kick_decoder(d, t)           # the request joins the next
                                           # iteration boundary

    def _on_requeue(self, entry):
        # a preempted victim (or penalized prefix hit) re-enters
        # pending_decode; retry admission exactly when its recompute /
        # swap delay elapses — the swap-completion event
        self._push(entry[0], "swap_done")
