"""Discrete-event PD-disaggregated cluster simulator.

Same cluster, same roofline, same *unmodified* control plane as the fluid
engine (``sim.cluster.Cluster``) — but time advances on an event heap
instead of fixed dt ticks, so request-level tail behavior is exact:

  * decode runs per-iteration continuous batching: each decoder iteration
    is one event whose length is the shared roofline ``Decoder.iter_time``;
    every resident request emits exactly one token per iteration, and
    admissions join at iteration boundaries (the mechanism DistServe/
    DynaServe show dominates tail latency);
  * prefill is serialized per prefiller (batch ~1): one completion event
    per request at ``in_len / v_prefill``;
  * KVC transfers complete at interconnect-bandwidth delay events;
  * instance startup/conversion appears as wake events at ``ready_t``;
  * autoscaling fires every ``scale_interval`` as in the fluid engine.

TTFT/TPOT therefore come out strictly per-request (non-smeared): admission
and finish happen at exact event timestamps and ``generated`` advances in
whole tokens.  The differential suite (tests/test_sim_differential.py)
asserts this engine and the fluid engine agree on throughput, mean
TTFT/TPOT, and scaling decisions for every trace x policy; the
heterogeneous/multi-model variants are in tests/test_fleet_api.py.

Pools: every instance this engine wakes, kicks, or completes belongs to
a named pool (``sim.instances.Pool``); per-iteration events carry the
instance, so mixed fleets (different chips/TP per pool, several models)
need no event-engine-specific handling — pool membership and per-model
routing live in the shared ``ClusterBase``.

Performance (DESIGN.md "Performance"): the hot loop is O(1) amortized per
event —

  * arrivals feed lazily from the sorted trace (which may be a streaming
    iterator, ``sim.traces.stream_trace``): the heap holds only *live*
    events, never the whole trace, and ties resolve arrivals-first in
    trace order exactly as the historical eager pre-push did (arrivals
    were pushed before every other event, so their sequence numbers were
    strictly smaller);
  * iteration membership uses admission-generation stamps
    (``SimRequest._res_gen`` vs the ``_iter_gen`` recorded when the
    iteration was scheduled) instead of snapshotting the batch into the
    event and rebuilding an ``id()`` set on completion — a request gets
    this iteration's token iff it was admitted before the iteration
    started and hasn't been evicted (or evicted + re-admitted) since,
    which is the same predicate the (resident, n_evictions) snapshot
    encoded;
  * instance liveness is the O(1) ``Instance.live`` flag, not an
    ``inst in self.decoders + self.convertibles`` list-concat probe;
  * the piecewise-constant GPU integral caches the fleet size between
    scale events (the only place the fleet changes).

Fidelity choices and the fluid-vs-event comparison are documented in
DESIGN.md.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Iterable, Optional

from repro.sim.instances import ClusterBase, Decoder, Prefiller, SimReport, \
    SimRequest
from repro.sim.traces import TraceRequest

# granularity cap for prefill-only convertible iterations: with no decode
# batch resident there is no natural iteration boundary, so progress is
# checkpointed at least this often (the TPOT-SLO-scale chunk cadence)
_CONV_PREFILL_QUANTUM = 0.05


class EventCluster(ClusterBase):
    """Event-driven engine over the shared instance/control-plane layer."""

    engine = "events"

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._heap: list[tuple[float, int, str, tuple]] = []
        self._seq = itertools.count()
        self._snap_every = 0.2
        self.n_events = 0        # processed events (benchmarks/perf.py)

    # ------------------------------------------------------------------
    def _push(self, t: float, kind: str, *data):
        heapq.heappush(self._heap, (t, next(self._seq), kind, data))

    # ------------------------------------------------------------------
    def run(self, trace: "list[TraceRequest] | Iterable[TraceRequest]",
            duration: Optional[float] = None) -> SimReport:
        """Drive the cluster over ``trace``.  A list is sorted here (the
        historical contract); any other iterable is consumed lazily and
        must already be in arrival-time order (streaming traces), in which
        case ``duration`` is required."""
        if isinstance(trace, (list, tuple)):
            trace = sorted(trace, key=lambda r: r.t)
            t_end = duration or (trace[-1].t + 60.0 if trace else 60.0)
        else:
            if duration is None:
                raise ValueError(
                    "streaming traces need an explicit duration")
            t_end = duration
        arrivals = iter(trace)
        nxt = next(arrivals, None)
        self._snap_every = self._snapshot_every(t_end)
        if self.obs is not None:
            self.obs.meta.setdefault("duration", t_end)
        self._push(0.0, "scale")
        self._push(0.0, "snapshot")
        # chaos engine: the pre-drawn schedule becomes exact heap events
        # (faults off: no schedule, no events, byte-identical heap order)
        self._faults_begin(t_end)
        for item in self._fault_work:
            self._push(item[0], "fault", item)
        self._fault_work = []
        t_cur = 0.0
        heap = self._heap
        # the fleet only changes inside scale events: cache the GPU count
        # for the piecewise-constant integral instead of recounting pools
        # on every event
        gpus = self._gpu_count(t_cur)
        while heap or nxt is not None:
            # lazy arrival feed: an arrival fires when it is no later than
            # the earliest heap event (ties arrival-first, in trace order —
            # byte-identical to the historical eager pre-push, whose
            # arrival sequence numbers were strictly smaller than every
            # other event's)
            if nxt is not None and (not heap or nxt.t <= heap[0][0]):
                if nxt.t >= t_end:
                    nxt = None
                    continue
                te = nxt.t
                if te < t_cur:
                    # unreachable for a sorted trace (arrivals fire before
                    # any later heap event); an unsorted streaming
                    # iterator must fail loudly, not corrupt the
                    # piecewise-constant GPU integral
                    raise ValueError(
                        f"trace not sorted by arrival time: request "
                        f"{nxt.rid} at t={te} after t={t_cur}")
                self.gpu_seconds += gpus * (te - t_cur)
                t_cur = te
                self.n_events += 1
                self._ev_arrival(te, SimRequest(nxt))
                nxt = next(arrivals, None)
                continue
            te, _, kind, data = heapq.heappop(heap)
            if te >= t_end:
                break
            # integrate GPU-seconds over the piecewise-constant fleet
            self.gpu_seconds += gpus * (te - t_cur)
            t_cur = te
            self.n_events += 1
            getattr(self, "_ev_" + kind)(te, *data)
            if kind == "scale" or kind == "fault":
                # faults change the fleet outside scale events (crash
                # billing husks, reaps swapping in replacements)
                gpus = self._gpu_count(te)
        self.gpu_seconds += gpus * (t_end - t_cur)
        return self._report(t_end)

    # ------------------------------------------------------------------
    # event handlers
    # ------------------------------------------------------------------
    def _ev_arrival(self, t: float, req: SimRequest):
        self._on_arrival(req, t)

    def _ev_scale(self, t: float):
        self._scale(t)
        self._drain_wait_queue(t)
        self._admit_pending(t)
        self._push(t + self.scale_interval, "scale")

    def _ev_snapshot(self, t: float):
        self.timeline.append(self._snapshot(t))
        self._push(t + self._snap_every, "snapshot")

    def _ev_wake(self, t: float, inst):
        """A provisioned instance finished booting."""
        inst._wake_scheduled = False
        if not inst.live:
            return
        if isinstance(inst, Prefiller):
            self._drain_wait_queue(t)
            self._kick_prefiller(inst, t)
        else:
            self._drain_wait_queue(t)
            self._admit_pending(t)
            self._kick_decoder(inst, t)

    def _ev_prefill_done(self, t: float, p: Prefiller, req: SimRequest):
        p._busy = False
        if not p.live:
            # the instance died mid-flight (chaos-engine crash, or the
            # defensive scale-down path): pull the head off the dead
            # box's queue so it is owned by exactly one place, then
            # requeue it on the central queue — re-prefilled exactly once
            if p.queue and p.queue[0][0] is req:
                p.queue.pop(0)
                p._inflight_cache = None
            self._wait_add(req)
            self._drain_wait_queue(t)
            return
        if p.queue and p.queue[0][0] is req:
            p.queue.pop(0)
            p._inflight_cache = None
        res = self._to_network(req, t, p.pool)   # sets t_prefill_end
        if res is not None:
            self._push(res[0], "kv_ready")
        # res None: KVC link outage exhausted the retry ladder and the
        # prompt fell back to the central queue — re-routed just below
        self._drain_wait_queue(t)          # prefill capacity freed (§IV-E)
        self._kick_prefiller(p, t)

    def _ev_kv_ready(self, t: float):
        self._admit_pending(t)

    def _ev_swap_done(self, t: float):
        """A preempted victim's swap/recompute (or a prefix hit's swap-in /
        migration) completed *exactly now*; retry admission.  The fluid
        engine approximates the same completion at tick granularity via
        its per-tick ``_admit_pending`` ready-time check (DESIGN.md
        "KV-tier fidelity")."""
        self._admit_pending(t)

    def _ev_replica_done(self, t: float):
        """A hot-prefix replication's interconnect transfer completed
        *exactly now*: install the copy on its target (the fluid engine
        approximates the same completion at tick granularity)."""
        self._service_gateway(t)

    def _ev_fault(self, t: float, item: tuple):
        """One chaos-engine work item fires *exactly now* (injection,
        straggler/swap window end, or husk reap).  Derived items go back
        on the heap as further fault events; work the fault displaced
        (crash requeues) re-enters the pipeline immediately."""
        for derived in self._fault_fire(t, item):
            self._push(derived[0], "fault", derived)
        self._drain_wait_queue(t)
        self._admit_pending(t)

    def _ev_iter_done(self, t: float, d: Decoder, it: float):
        d._iter_pending = False
        if not d.live:
            return
        # one token per request resident *since the iteration started*:
        # the admission-generation stamp (set by Decoder.admit, monotonic
        # per decoder) filters both mid-iteration admissions and victims
        # evicted-and-re-admitted before the iteration completed — the
        # predicate the historical (batch snapshot, n_evictions) pair
        # encoded, without materializing a list per iteration
        gen = d._iter_gen
        finished = []
        if d.active:
            d._invalidate()                # resident lengths advance
            fin_append = self.finished.append
            granted = 0
            for r in d.active:
                if r.t_finish >= 0 or r._res_gen > gen:
                    continue
                g_new = r.generated + 1.0
                r.generated = g_new
                r.decode_time += it
                granted += 1
                if r.t_first_token < 0:
                    # TTFT is exact: the first token exists when the first
                    # decode iteration containing the request *completes*
                    r.t_first_token = t
                if g_new >= r.src.out_len:
                    r.t_finish = t
                    d._kv_release(r, t)
                    fin_append(r)
                    finished.append(r)
            # one whole token per granted request: keeps the decoder's
            # exact-integer context sum in step with the batch
            d._ctx_sum += granted
            if self.obs is not None:
                # exact decode-token odometer (the fluid engine's
                # counterpart is the Decoder.tick pre-pass)
                self.obs.decode_tokens_done += granted
        if finished:
            d.active = [r for r in d.active if r.t_finish < 0]
            for r in finished:
                d._count_remove(r)
        # co-scheduled prefill progress
        if d.chunking:
            # chunked mode: the iteration executed exactly the chunk that
            # was planned when it was scheduled — the queue advances by
            # that budget and nothing else, so every chunk boundary is an
            # exact event timestamp
            chunk = d._iter_chunk
            d._iter_chunk = 0.0
            if chunk > 0 and d.prefill_q:
                if self.obs is not None:
                    # exact chunk boundary: this iteration advanced the
                    # co-scheduled prompt queue by precisely ``chunk``
                    self.obs.on_chunk(t, d, chunk)
                d.advance_prefill(chunk, t)
        elif d.is_convertible and d.prefill_q and d.conv:
            # legacy wholesale conversion (Eq. 5 restricted rate)
            d.advance_prefill(d.conv.v_prefill * it, t)
        if d.lazy and d.active:
            # allocate-on-generate: each surviving resident's next token
            # needs a backed block before the next iteration is scheduled;
            # failures land in oom_pending and are resolved inside the
            # _admit_pending call below (exact mid-decode OOM preemption)
            d.grow_lazy(t)
        self._admit_pending(t)             # memory freed by completions
        self._kick_decoder(d, t)

    # ------------------------------------------------------------------
    # scheduling helpers
    # ------------------------------------------------------------------
    def _kick_prefiller(self, p: Prefiller, t: float):
        if getattr(p, "_busy", False) or not p.queue:
            return
        if not p.ready(t):
            self._schedule_wake(p)
            return
        req, rem = p.queue[0]
        p._busy = True
        self._push(t + rem / max(p.v_p, 1e-9), "prefill_done", p, req)

    def _kick_decoder(self, d: Decoder, t: float):
        if getattr(d, "_iter_pending", False):
            return
        if not d.ready(t):
            self._schedule_wake(d)
            return
        if d.active:
            if d.chunking and d.prefill_q:
                # mixed iteration: plan the chunk that fits Eq. 5's TPOT
                # headroom *now* and stretch this iteration by exactly its
                # roofline cost — the chunk lands at the iteration boundary
                chunk = d.plan_chunk()
                d._iter_chunk = chunk
                it = d.mixed_iter_time(chunk) if chunk > 0 else d.iter_time()
            else:
                it = d.iter_time()
            d._iter_pending = True
            d._iter_gen = d._admit_seq     # membership cutoff stamp
            self._push(t + it, "iter_done", d, it)
        elif d.chunking and d.prefill_q:
            # chunk-only iteration: no decode batch, so the chunk itself
            # paces the event — each boundary is exact (no quantum)
            chunk = d.plan_chunk()
            d._iter_chunk = chunk
            it = d.mixed_iter_time(chunk)
            d._iter_pending = True
            d._iter_gen = d._admit_seq
            self._push(t + it, "iter_done", d, it)
        elif d.is_convertible and d.prefill_q and d.conv:
            # prefill-only "iteration": no decode batch to pace it, so
            # checkpoint progress at the chunk cadence
            head_rem = d.prefill_q[0][1]
            v = max(d.conv.v_prefill, 1e-9)
            it = min(head_rem / v, _CONV_PREFILL_QUANTUM)
            d._iter_pending = True
            d._iter_gen = d._admit_seq
            self._push(t + it, "iter_done", d, it)

    def _schedule_wake(self, inst):
        if not getattr(inst, "_wake_scheduled", False):
            inst._wake_scheduled = True
            self._push(inst.ready_t, "wake", inst)

    def _after_scale(self, t: float):
        for pool in self.pools.values():
            for inst in pool.instances:
                if not inst.ready(t):
                    self._schedule_wake(inst)

    # ------------------------------------------------------------------
    # control-plane hooks
    # ------------------------------------------------------------------
    def _submit_prefill_work(self, tgt, kind: str, req: SimRequest, t: float):
        super()._submit_prefill_work(tgt, kind, req, t)
        if kind == "prefiller":
            self._kick_prefiller(tgt, t)
        else:
            self._kick_decoder(tgt, t)

    def _after_admit(self, d: Decoder, t: float):
        self._kick_decoder(d, t)           # the request joins the next
                                           # iteration boundary

    def _on_requeue(self, entry):
        # a preempted victim (or penalized prefix hit) re-enters
        # pending_decode; retry admission exactly when its recompute /
        # swap delay elapses — the swap-completion event
        self._push(entry[0], "swap_done")

    def _on_replication(self, job):
        # hot-prefix copy completes exactly when its interconnect
        # transfer does
        self._push(job.t_done, "replica_done")
