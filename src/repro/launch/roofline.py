"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (all per-chip — the
optimized HLO module is the per-device program after SPMD partitioning):

  compute    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = collective_bytes_per_chip / link_bw

``compiled.cost_analysis()`` counts every while body ONCE (verified
empirically), so scanned depth would be undercounted ~num_blocks-fold.  We
therefore parse the optimized HLO ourselves:

  * instructions are attributed to their computation; a call graph is built
    from while ``body=``/``condition=``, fusion ``calls=``, and
    ``to_apply=`` edges; while trip counts come from the
    ``known_trip_count`` backend_config the scan lowering emits;
  * FLOPs  = sum over ``dot`` ops of 2 * |out| * K (K = product of the lhs
    contracting dims, resolved through the operand-definition map), times
    the enclosing computation's execution multiplier;
  * HBM bytes = sum of output+operand bytes of executed data ops (fusions,
    dots, copies, slices, collectives excluded) — an HBM-traffic estimate;
  * collective bytes = sum of collective output bytes x multiplier.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "iota",
    # `copy` of while-carried buffers is a CPU-backend aliasing artifact:
    # TPU memory-space assignment updates caches in place.  Genuine data
    # movement surfaces through fusion I/O, which we do count.
    "copy", "copy-start", "copy-done",
} | set(COLLECTIVE_OPS) | {f"{c}-start" for c in COLLECTIVE_OPS} \
  | {f"{c}-done" for c in COLLECTIVE_OPS}

_SHAPE_TOKEN = re.compile(r"^\(?([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{$")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OP_NAME = re.compile(r"^\s*([\w\-]+)\(")


def _parse_instr(line: str):
    """Parse `[ROOT] %name = SHAPE op(...)...` robustly.

    Tuple shapes embed `/*index=N*/` comments (which contain '=' and
    defeat naive regexes), so the shape is scanned with paren balancing."""
    s = line
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq].strip()
    rest = s[eq + 3:]
    if rest.startswith("("):          # tuple shape: scan to matching paren
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape = rest[:end + 1]
        tail = rest[end + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape = rest[:sp]
        tail = rest[sp:]
    m = _OP_NAME.match(tail)
    if not m:
        return None
    op = m.group(1)
    body = tail[m.end():]
    return name, shape, op, body


def _tuple_shapes(shape_str: str) -> list[str]:
    return re.findall(r"[a-z0-9]+\[[\d,]*\]", shape_str)


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for tok in _tuple_shapes(shape_str):
        m = _SHAPE_TOKEN.match(tok)
        if not m or m.group(1) not in _DTYPE_BYTES:
            continue
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_TOKEN.match(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str          # raw text after the opening paren
    is_root: bool = False


@dataclass
class HLOModule:
    comps: dict[str, list[Instr]]
    entry: Optional[str]
    defs: dict[str, str]                       # %name -> shape str
    edges: dict[str, list[tuple[str, float]]]  # comp -> [(callee, times)]
    fused: set = field(default_factory=set)    # fusion/to_apply targets:
                                               # internal instrs are not
                                               # separate HBM transactions

    def multipliers(self) -> dict[str, float]:
        mult: dict[str, float] = {}

        def visit(c: str, m: float):
            if mult.get(c, 0.0) >= m:
                return
            mult[c] = m
            for callee, times in self.edges.get(c, []):
                visit(callee, m * times)

        if self.entry:
            visit(self.entry, 1.0)
        return mult


def parse_hlo(text: str) -> HLOModule:
    comps: dict[str, list[Instr]] = {}
    defs: dict[str, str] = {}
    edges: dict[str, list[tuple[str, float]]] = {}
    fused: set = set()
    entry = None
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.strip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                edges[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if line == "}":
            cur = None
            continue
        parsed = _parse_instr(line)
        if not parsed:
            continue
        name, shape, op, rest = parsed
        comps[cur].append(Instr(name, shape, op, rest,
                                is_root=line.startswith("ROOT ")))
        defs[name] = shape
        # call-graph edges
        if op == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", rest)
            trips = 1.0
            tm = _TRIP.search(rest)
            if tm:
                trips = float(tm.group(1))
            if bm:
                edges[cur].append((bm.group(1), trips))
            cm = re.search(r"condition=%?([\w\.\-]+)", rest)
            if cm:
                edges[cur].append((cm.group(1), trips))
        else:
            for attr in ("calls", "to_apply", "body", "condition"):
                am = re.search(rf"{attr}=%?([\w\.\-]+)", rest)
                if am:
                    edges[cur].append((am.group(1), 1.0))
                    if attr in ("calls", "to_apply"):
                        fused.add(am.group(1))
    return HLOModule(comps, entry, defs, edges, fused)


def _operand_names(rest: str) -> list[str]:
    # operands are inside the call parens, referenced as %name
    depth, end = 1, 0
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return re.findall(r"%([\w\.\-]+)", rest[:end])


@dataclass
class RooflineCounts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict[str, float] = field(default_factory=dict)
    collective_counts: dict[str, float] = field(default_factory=dict)
    detail: Optional[dict] = None      # (op, shape, mult) -> bytes


_SLICE_OPS = {"dynamic-slice", "slice", "gather"}


@dataclass
class _CompIO:
    """Effective read behavior of a (fused) computation.

    param_read[i] = bytes actually read from parameter i (None = all of
    it).  A parameter consumed ONLY by slice/gather ops is charged the
    slice outputs, not the full buffer — this is what keeps scanned
    stacked-weight reads from being charged num_blocks times over."""
    param_read: dict[int, Optional[float]]


_PASSTHRU = ("bitcast", "copy", "convert", "reshape", "transpose")


def _comp_io(instrs: list[Instr]) -> _CompIO:
    params: dict[str, int] = {}
    for ins in instrs:
        if ins.op == "parameter":
            m = re.match(r"\s*(\d+)", ins.rest)
            if m:
                params[ins.name] = int(m.group(1))

    def uses_of(name: str) -> list[Instr]:
        return [i for i in instrs if i.op != "parameter"
                and re.search(rf"%{re.escape(name)}\b", i.rest)]

    def charge(name: str, depth: int = 0) -> Optional[float]:
        """Bytes read through `name`; None = treat as full read."""
        if depth > 4:
            return None
        total = 0.0
        used_by = uses_of(name)
        if not used_by:
            return 0.0
        for u in used_by:
            if u.op in _SLICE_OPS:
                total += _shape_bytes(u.shape)
            elif u.op in ("dynamic-update-slice", "scatter"):
                ops = _operand_names(u.rest)
                if ops and ops[0] == name:
                    # destination of an in-place cache update: aliased,
                    # only the updated region moves (charged at the root)
                    continue
                return None
            elif u.op in _PASSTHRU:
                sub = charge(u.name, depth + 1)
                if sub is None:
                    return None
                total += sub
            elif u.op == "tuple":
                continue      # repackaging, typically aliased
            else:
                return None
        return total

    reads: dict[int, Optional[float]] = {}
    for pname, idx in params.items():
        reads[idx] = charge(pname)
    return _CompIO(reads)


def analyze_hlo(text: str, detail: bool = False) -> RooflineCounts:
    mod = parse_hlo(text)
    mult = mod.multipliers()
    io_cache: dict[str, _CompIO] = {}

    def io_of(comp: str) -> Optional[_CompIO]:
        if comp not in mod.comps:
            return None
        if comp not in io_cache:
            io_cache[comp] = _comp_io(mod.comps[comp])
        return io_cache[comp]

    def _resolve(instrs: list[Instr], name: str) -> Optional[Instr]:
        for i2 in instrs:
            if i2.name == name:
                return i2
        return None

    def _chain_bytes(instrs, ins: Instr, depth: int = 0) -> Optional[float]:
        """Effective bytes written through `ins` as a computation output:
        dynamic-update-slice / scatter chains write only their update
        region (the buffer is aliased in place); tuples sum their parts.
        None = could not prove in-place-ness, charge the full shape."""
        if depth > 6:
            return None
        if ins.op in ("dynamic-update-slice", "scatter"):
            ops = _operand_names(ins.rest)
            idx = 1 if ins.op == "dynamic-update-slice" else 2
            if len(ops) > idx:
                return 2.0 * _shape_bytes(mod.defs.get(ops[idx], ""))
            return None
        if ins.op in ("bitcast", "copy", "convert"):
            ops = _operand_names(ins.rest)
            nxt = _resolve(instrs, ops[0]) if ops else None
            if nxt is None:
                return None
            return _chain_bytes(instrs, nxt, depth + 1)
        if ins.op == "tuple":
            total = 0.0
            for o in _operand_names(ins.rest):
                nxt = _resolve(instrs, o)
                sub = _chain_bytes(instrs, nxt, depth + 1) \
                    if nxt is not None else None
                if sub is None:
                    total += _shape_bytes(mod.defs.get(o, ""))
                else:
                    total += sub
            return total
        return None

    def dus_write_bytes(instrs: list[Instr]) -> Optional[float]:
        root = next((i for i in instrs if i.is_root), None)
        if root is None:
            return None
        return _chain_bytes(instrs, root)

    out = RooflineCounts(detail={} if detail else None)
    for cname, instrs in mod.comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0.0:
            continue
        in_fusion = cname in mod.fused
        for ins in instrs:
            if ins.op == "dot":
                k = 1
                cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
                ops = _operand_names(ins.rest)
                if cd and ops:
                    dims = _shape_dims(mod.defs.get(ops[0], ""))
                    for d in cd.group(1).split(","):
                        if d and int(d) < len(dims):
                            k *= dims[int(d)]
                n_out = _shape_bytes(ins.shape) / max(
                    _dtype_size(ins.shape), 1)
                out.flops += 2.0 * n_out * k * m
            base_op = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base_op in COLLECTIVE_OPS:
                nbytes = _shape_bytes(ins.shape)
                out.collective_bytes += nbytes * m
                out.collectives[base_op] = \
                    out.collectives.get(base_op, 0.0) + nbytes * m
                out.collective_counts[base_op] = \
                    out.collective_counts.get(base_op, 0.0) + m
                continue
            if ins.op in _SKIP_BYTES_OPS or in_fusion:
                continue   # fusion internals live in VMEM/registers
            operands = _operand_names(ins.rest)
            if ins.op == "fusion":
                callee = re.search(r"calls=%?([\w\.\-]+)", ins.rest)
                cio = io_of(callee.group(1)) if callee else None
                wb = dus_write_bytes(mod.comps[callee.group(1)]) \
                    if callee and callee.group(1) in mod.comps else None
                nbytes = wb if wb is not None else _shape_bytes(ins.shape)
                for j, opnd in enumerate(operands):
                    full = _shape_bytes(mod.defs.get(opnd, ""))
                    if cio is not None and j in cio.param_read \
                            and cio.param_read[j] is not None:
                        nbytes += min(cio.param_read[j], full)
                    else:
                        nbytes += full
            elif ins.op in _SLICE_OPS:
                nbytes = 2.0 * _shape_bytes(ins.shape)  # read + write slice
            elif ins.op == "dynamic-update-slice":
                upd = _shape_bytes(mod.defs.get(operands[1], "")) \
                    if len(operands) >= 2 else _shape_bytes(ins.shape)
                nbytes = 2.0 * upd
            else:
                nbytes = _shape_bytes(ins.shape)
                for opnd in operands:
                    nbytes += _shape_bytes(mod.defs.get(opnd, ""))
            out.hbm_bytes += nbytes * m
            if out.detail is not None:
                key = (ins.op, ins.shape[:60], int(m))
                out.detail[key] = out.detail.get(key, 0.0) + nbytes * m
    return out


def _dtype_size(shape_str: str) -> int:
    m = _SHAPE_TOKEN.match(shape_str)
    return _DTYPE_BYTES.get(m.group(1), 4) if m else 4


# ---------------------------------------------------------------------------

@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops: float               # 6·N_active·D (train) / 2·N_active·D
    memory_stats: Optional[dict] = None
    collectives: Optional[dict] = None
    cost_analysis_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO flops) — remat/redundancy waste."""
        return self.model_flops / max(self.flops_per_chip * self.chips, 1.0)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops_global": self.flops_per_chip * self.chips,
            "useful_ratio": self.useful_flops_ratio,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "collectives": self.collectives,
            "memory": self.memory_stats,
        }


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active·D for training, 2·N_active·D per forward."""
    n_act = cfg.param_counts()["active"]
    if shape.kind == "train":
        return 6.0 * n_act * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len
    return 2.0 * n_act * shape.global_batch      # decode: one token
