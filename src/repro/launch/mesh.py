"""Production mesh + sharding-rule tables.

Importing this module never touches jax device state: meshes are built by
FUNCTIONS, and the dry-run sets XLA_FLAGS before any jax import.

Mesh shapes (prescribed):
  single-pod  (16, 16)        axes ("data", "model")   = 256 chips
  multi-pod   (2, 16, 16)     axes ("pod", "data", "model") = 512 chips

Rule tables map the models' logical axes to mesh axes per run kind:

  * ACT rules   — activations + decode/prefill state.  Batch shards over
    (pod, data); tensor-parallel dims over model; decode caches shard their
    sequence axis over model (GQA KV-head counts < 16 cannot split the
    model axis, the cache would otherwise replicate 16x and OOM); the
    batch=1 long-context shape context-shards the cache over (data, model).
  * PARAM rules — weights.  TP dims over model; optionally FSDP: the embed
    (d_model) axis over (pod, data) when TP-only residency would overflow
    HBM (always on for training, where grads + moments triple the bytes).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.configs.base import InputShape, ModelConfig
from repro.core.hardware import V5E, weight_bytes


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` across JAX versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer releases; older ones
    default to Auto semantics anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def compat_set_mesh(mesh):
    """``jax.set_mesh`` across JAX versions.  Older releases spell it
    ``jax.sharding.use_mesh`` or simply use the Mesh as a context manager."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def batch_axes(multi_pod: bool) -> tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def act_rules(shape: InputShape, multi_pod: bool) -> dict:
    b = batch_axes(multi_pod)
    rules = {
        "batch": b,
        "seq": (),
        "ctx": ("model",),          # cache sequence axis (see module doc)
        "embed": (),
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "experts": ("model",),
        "expert_ff": (),
        "vocab": ("model",),
        "kv_lora": (),
        "state": (),
    }
    if shape.kind == "decode":
        # decode: weight-stationary MoE (ops._moe_ep_path S==1 path) keeps
        # expert d_ff sharded over the FSDP axes instead of re-gathering
        # weights every token
        rules["expert_ff"] = b
    if shape.kind == "decode" and shape.global_batch == 1:
        # long-context decode: context parallelism replaces data parallelism
        rules["batch"] = ()
        rules["ctx"] = ("data", "model") if not multi_pod \
            else ("pod", "data", "model")
    return rules


def param_rules(cfg: ModelConfig, shape: InputShape, multi_pod: bool,
                fsdp: Optional[bool] = None) -> dict:
    if fsdp is None:
        fsdp = needs_fsdp(cfg, shape)
    f = batch_axes(multi_pod) if fsdp else ()
    # decode: dense/attention weights always fit TP-resident (even kimi-k2's
    # non-expert ~60 GB / 16 = 3.75 GB/chip), so never FSDP them — FSDP'd
    # weights would be re-gathered every decoded token.  Expert weights stay
    # sharded over the FSDP axes and are consumed in place by the
    # weight-stationary S==1 MoE path (ops._moe_ep_path).
    embed_f = () if shape.kind == "decode" else f
    return {
        "embed": embed_f,           # FSDP axis (d_model rows)
        "expert_ff": f,             # FSDP axis for expert d_ff (see params)
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "experts": ("model",),
        "vocab": ("model",),
        "kv_lora": (),
        "batch": (), "seq": (), "ctx": (), "state": (),
    }


def needs_fsdp(cfg: ModelConfig, shape: InputShape,
               model_shards: int = 16) -> bool:
    """TP-only residency check against the v5e HBM budget.

    Training counts params(bf16) + grads(bf16) + AdamW moments(f32) =
    12 bytes/param; if that fits TP-only we skip FSDP entirely — FSDP'd
    weights re-gather inside the depth scan every step, which measured
    as the dominant collective for <=20B dense trains (§Perf, yi-9b)."""
    wb = weight_bytes(cfg)
    if shape.kind == "train":
        n = cfg.param_counts()["total"]
        train_bytes = 12.0 * n / model_shards
        return train_bytes > 0.8 * V5E.hbm_cap
    return wb / model_shards > 0.35 * V5E.hbm_cap
