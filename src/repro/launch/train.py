"""Training launcher.

CPU-scale real run (smoke configs) or production-mesh lowering check:

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 50 --batch 8 --seq-len 128
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config
from repro.training import AdamWConfig, save, train


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default=None,
                    help="directory to save the final checkpoint")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 5))
    params, res = train(cfg, steps=args.steps, batch=args.batch,
                        seq_len=args.seq_len, opt_cfg=opt_cfg,
                        seed=args.seed)
    if args.checkpoint:
        save(args.checkpoint, params, step=res.steps)
        print(f"checkpoint -> {args.checkpoint}")
    print(json.dumps({"arch": cfg.name, "steps": res.steps,
                      "loss_first": res.losses[0],
                      "loss_last": res.losses[-1],
                      "wall_s": round(res.wall_s, 2)}))


if __name__ == "__main__":
    main()
