from repro.launch.mesh import (  # noqa: F401
    act_rules, batch_axes, make_production_mesh, needs_fsdp, param_rules,
)
