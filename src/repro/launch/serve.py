"""Serving launcher: a PD-disaggregated mini-deployment on CPU.

Spins up prefiller / decoder / convertible-decoder Engine instances for a
smoke-scale model, replays a bursty trace through the TokenScale control
plane (router + velocity autoscaler), and reports SLO metrics — the whole
paper pipeline end-to-end on real engines:

    PYTHONPATH=src python -m repro.launch.serve --arch llama-3.1-8b \
        --requests 32 --duration 20
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.core import CHIPS, InstanceSpec, OutputPredictor, profile
from repro.models import init_params
from repro.serving import Engine, Request
from repro.sim.traces import TRACES, generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-3.1-8b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--chunk-size", type=int, default=16,
                    help=">0 runs the decoder in convertible mode")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.RandomState(args.seed)

    # profile token velocity of this (smoke) model on the v5e target
    prof = profile(get_config(args.arch), InstanceSpec(CHIPS["v5e"], tp=1))
    print(f"# offline profile: V_P={prof.v_prefill:.0f} tok/s "
          f"V_N={prof.v_network:.0f} tok/s "
          f"V_D(M-M)={prof.v_decode['M-M']:.0f} tok/s")

    eng = Engine(cfg, params, num_slots=args.slots, max_len=128,
                 chunk_size=args.chunk_size)
    reqs = []
    for i in range(args.requests):
        L = int(rng.randint(4, 48))
        prompt = rng.randint(0, cfg.vocab_size, size=(L,)).astype(np.int32)
        r = Request(rid=i, prompt=prompt, max_new_tokens=args.max_new)
        reqs.append(r)
        eng.add_request(r)
    eng.run_until_drained()
    done = sum(1 for r in reqs if len(r.output) >= args.max_new)
    toks = sum(len(r.output) for r in reqs)
    print(json.dumps({"arch": cfg.name, "requests": len(reqs),
                      "completed": done, "tokens_generated": toks,
                      "convertible_mode": args.chunk_size > 0}))


if __name__ == "__main__":
    main()
