import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import: jax locks the device count at first
# init, and the production meshes below need 512 host placeholder devices.

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers, SPMD-partitions and compiles, and extract the roofline
terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results.jsonl

No arrays are ever allocated: parameters, optimizer state, caches and
inputs are ShapeDtypeStructs; .lower().compile() exercises the full XLA
SPMD pipeline (sharding propagation, collective insertion, memory
assignment) without touching device memory.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, INPUT_SHAPES, InputShape, ModelConfig,
                           get_config, input_specs, long_context_variant)
from repro.launch.mesh import (act_rules, batch_axes, compat_set_mesh,
                               make_production_mesh, needs_fsdp, param_rules)
from repro.launch.roofline import (Roofline, analyze_hlo,
                                   model_flops_estimate)
from repro.models import decode_step, prefill
from repro.models.params import (abstract_params, abstract_state, param_axes,
                                 state_axes)
from repro.sharding import axis_rules, pspec_tree_from_logical
from repro.training import AdamWConfig, adamw_init, make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P


def _named(tree_axes, tree_abs, mesh, rules):
    specs = pspec_tree_from_logical(tree_axes, rules, tree_abs, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _input_shardings(specs: dict, mesh, b_axes) -> dict:
    out = {}
    for k, v in specs.items():
        if v.ndim == 0 or v.shape[0] % max(_axsize(mesh, b_axes), 1) != 0:
            out[k] = NamedSharding(mesh, P())
        else:
            out[k] = NamedSharding(mesh, P(b_axes))
    return out


def _axsize(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def opt_config_for(cfg: ModelConfig) -> AdamWConfig:
    n = cfg.param_counts()["total"]
    if n > 2e11:
        # frontier-scale: bf16 + factored second moment (DESIGN.md)
        return AdamWConfig(moment_dtype="bfloat16", factored=True)
    return AdamWConfig()


def build_dryrun(arch: str, shape_name: str, multi_pod: bool,
                 fsdp: Optional[bool] = None,
                 rules_override: Optional[dict] = None,
                 remat: bool = True, kv8: bool = False):
    """Returns (fn, args_abstract, in_shardings, cfg, mesh) or None if the
    (arch, shape) pair is skipped (long_500k on pure full-attention)."""
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    if kv8:
        cfg = cfg.replace(kv_cache_dtype="int8")
    if shape_name == "long_500k":
        cfg = long_context_variant(cfg)
        if cfg is None:
            return None
    mesh = make_production_mesh(multi_pod=multi_pod)
    b_axes = batch_axes(multi_pod)
    rules_a = act_rules(shape, multi_pod)
    rules_p = param_rules(cfg, shape, multi_pod, fsdp=fsdp)
    if rules_override:
        rules_a = {**rules_a, **rules_override.get("act", {})}
        rules_p = {**rules_p, **rules_override.get("param", {})}

    params_abs = abstract_params(cfg)
    params_sh = _named(param_axes(cfg), params_abs, mesh, rules_p)
    ins = input_specs(cfg, shape)
    ins_sh = _input_shardings(ins, mesh, b_axes)
    if shape.kind == "decode" and shape.global_batch == 1:
        ins_sh = {k: NamedSharding(mesh, P()) for k in ins}

    if shape.kind == "train":
        ocfg = opt_config_for(cfg)
        opt_abs = jax.eval_shape(lambda p: adamw_init(p, ocfg), params_abs)
        # moments shard like their parameters (factored leaves: drop the
        # reduced axis from the param spec)
        opt_sh = _opt_shardings(params_sh, params_abs, opt_abs, mesh)
        step = make_train_step(cfg, ocfg, donate=False, remat=remat)

        def fn(params, opt, tokens, labels, image_embeds=None):
            if image_embeds is None:
                return step(params, opt, tokens, labels)
            return step(params, opt, tokens, labels, image_embeds)

        args = [params_abs, opt_abs, ins["tokens"], ins["labels"]]
        shards = [params_sh, opt_sh, ins_sh["tokens"], ins_sh["labels"]]
        if "image_embeds" in ins:
            args.append(ins["image_embeds"])
            shards.append(ins_sh["image_embeds"])
        return (fn, tuple(args), tuple(shards), cfg, mesh, rules_a, shape)

    # serving shapes need the cache/state
    max_len = shape.seq_len
    state_abs = abstract_state(cfg, shape.global_batch, max_len)
    state_sh = _named(state_axes(cfg, shape.global_batch, max_len),
                      state_abs, mesh, rules_a)
    if shape.kind == "prefill":
        def fn(params, state, tokens, lengths, image_embeds=None):
            return prefill(cfg, params, state, tokens, lengths,
                           image_embeds=image_embeds)

        args = [params_abs, state_abs, ins["tokens"], ins["lengths"]]
        shards = [params_sh, state_sh, ins_sh["tokens"], ins_sh["lengths"]]
        if "image_embeds" in ins:
            args.append(ins["image_embeds"])
            shards.append(ins_sh["image_embeds"])
        return (fn, tuple(args), tuple(shards), cfg, mesh, rules_a, shape)

    def fn(params, state, last_tokens, cur_lens):
        return decode_step(cfg, params, state, last_tokens, cur_lens)

    args = (params_abs, state_abs, ins["last_tokens"], ins["cur_lens"])
    shards = (params_sh, state_sh, ins_sh["last_tokens"],
              ins_sh["cur_lens"])
    return (fn, args, shards, cfg, mesh, rules_a, shape)


def _opt_shardings(params_sh, params_abs, opt_abs, mesh):
    """Moments shard like their params; factored (tuple) leaves drop the
    last / second-to-last spec entry respectively.  Specs are padded to
    the parameter rank first (canonical PartitionSpecs trim trailing
    Nones, which would break positional slicing)."""
    def _padded(psh, rank):
        spec = list(psh.spec) + [None] * (rank - len(psh.spec))
        return spec

    def v_like(psh, pabs, leaf):
        if isinstance(leaf, tuple):
            spec = _padded(psh, len(pabs.shape))
            row = P(*spec[:-1])
            col = P(*(spec[:-2] + spec[-1:]))
            return (NamedSharding(mesh, row), NamedSharding(mesh, col))
        return psh

    import repro.training.optimizer as _o
    return _o.AdamWState(
        step=NamedSharding(mesh, P()),
        m=jax.tree.map(lambda psh, _: psh, params_sh, opt_abs.m),
        v=jax.tree.map(v_like, params_sh, params_abs, opt_abs.v,
                       is_leaf=lambda x: isinstance(x, NamedSharding)))


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            fsdp: Optional[bool] = None, verbose: bool = True,
            rules_override: Optional[dict] = None,
            remat: bool = True, kv8: bool = False) -> Optional[dict]:
    built = build_dryrun(arch, shape_name, multi_pod, fsdp, rules_override,
                         remat=remat, kv8=kv8)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if built is None:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped",
               "reason": "long_500k requires sub-quadratic attention "
                         "(pure full-attention arch, see DESIGN.md)"}
        if verbose:
            print(json.dumps(rec))
        return rec
    fn, args, shards, cfg, mesh, rules_a, shape = built
    t0 = time.time()
    try:
        with compat_set_mesh(mesh):
            with axis_rules(rules_a, mesh):
                lowered = jax.jit(fn, in_shardings=shards).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):     # older JAX: list of one dict
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        counts = analyze_hlo(hlo)
        chips = mesh.devices.size
        rf = Roofline(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
            flops_per_chip=counts.flops,
            hbm_bytes_per_chip=counts.hbm_bytes,
            collective_bytes_per_chip=counts.collective_bytes,
            model_flops=model_flops_estimate(cfg, shape),
            memory_stats={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
            } if mem else None,
            collectives={k: {"bytes": v,
                             "count": counts.collective_counts[k]}
                         for k, v in counts.collectives.items()},
            cost_analysis_flops=float(cost.get("flops", 0.0)),
        )
        rec = {"status": "ok", "t_lower_s": round(t_lower, 2),
               "t_compile_s": round(t_compile, 2),
               "hlo_bytes": len(hlo), **rf.row()}
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    if verbose:
        slim = {k: v for k, v in rec.items() if k != "trace"}
        print(json.dumps(slim, default=str))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id")
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 (512 chips) instead of 16x16 (256)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) pair")
    ap.add_argument("--fsdp", default=None, choices=["on", "off", None])
    ap.add_argument("--no-remat", action="store_true",
                    help="disable activation checkpointing (train shapes)")
    ap.add_argument("--kv8", action="store_true",
                    help="int8 KV cache (beyond-paper decode optimization)")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    fsdp = {"on": True, "off": False, None: None}[args.fsdp]

    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_one(arch, shape, multi_pod=mp, fsdp=fsdp,
                              remat=not args.no_remat, kv8=args.kv8)
                records.append(rec)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec, default=str) + "\n")
    n_err = sum(1 for r in records if r["status"] == "error")
    print(f"# {len(records)} runs, {n_err} errors", file=sys.stderr)
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
