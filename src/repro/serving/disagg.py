"""PD-disaggregated serving runtime on REAL JAX engines (paper Fig. 1 + 8).

Separate prefiller instances compute prompt KVC and hand it to decoder
instances through ``kvtransfer`` (the explicit network stage); a Gateway
records arrivals and predicted buckets; the Router runs Alg. 1 (regular
prefillers first, Convertible Decoders for bursts/overflow); the Scaler
periodically evaluates the TokenScale policy against live Observations and
boots/retires instances.  Everything is the same `repro.core` control-plane
code the simulator drives — here it orchestrates actual model execution.

This is the CPU-scale twin of the production deployment: instances share a
process (and weights) instead of owning TPU slices, and the virtual clock
advances by measured wall time of each engine step.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.autoscaler import Observation, Policy
from repro.core.predictor import OutputPredictor
from repro.core.router import BurstDetector, Router, ttft_slo
from repro.core.velocity import bucket_of
from repro.models import init_state, prefill
from repro.serving import kvtransfer
from repro.serving.engine import Engine, Request
from repro.serving.kvtransfer import TransferStats


class PrefillerInstance:
    """One prefiller: serializes whole-prompt prefills (batch ~1, §II-C1)."""

    def __init__(self, iid: int, cfg: ModelConfig, params, max_len: int):
        self.iid = iid
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.queue: list[Request] = []
        self.tokens_done = 0
        self.wall_s = 1e-9
        self._fn = jax.jit(
            lambda p, s, t, ln: prefill(cfg, p, s, t, ln))

    # Alg. 1 interface -------------------------------------------------
    def inflight_tokens(self) -> float:
        return float(sum(len(r.prompt) for r in self.queue))

    def prefill_velocity(self) -> float:
        """MEASURED velocity (tokens prefilled per wall second)."""
        if self.tokens_done < 64:        # cold: fall back to a large prior
            return 1e9
        return self.tokens_done / self.wall_s

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def step(self) -> Optional[tuple[Request, kvtransfer.KVPayload, int]]:
        """Prefill one queued request; return (req, payload, first_token)."""
        if not self.queue:
            return None
        req = self.queue.pop(0)
        L = len(req.prompt)
        assert L <= self.max_len, (L, self.max_len)
        pad = min(max(8, 1 << (L - 1).bit_length()), self.max_len)
        toks = np.zeros((1, pad), np.int32)
        toks[0, :L] = req.prompt
        st = init_state(self.cfg, 1, self.max_len)
        t0 = time.perf_counter()
        logits, st = self._fn(self.params, st, jnp.asarray(toks),
                              jnp.array([L], jnp.int32))
        logits.block_until_ready()
        self.wall_s += time.perf_counter() - t0
        self.tokens_done += L
        payload = kvtransfer.extract(self.cfg, st, L, slot=0)
        return req, payload, req.pick(np.asarray(logits[0]))

    @property
    def idle(self) -> bool:
        return not self.queue


class DecoderAdapter:
    """Router-facing view of a decoder Engine (per-bucket load, memory)."""

    def __init__(self, eng: Engine, convertible: bool = False):
        self.eng = eng
        self.is_convertible = convertible
        self.bucket_of_slot: dict[int, str] = {}

    def inflight_of_bucket(self, bucket: str) -> int:
        return sum(1 for s, b in self.bucket_of_slot.items()
                   if b == bucket and self.eng.active[s])

    def mem_util(self) -> float:
        cap = self.eng.num_slots * self.eng.max_len
        return self.eng.memory_tokens_used() / max(cap, 1)

    # convertible decoders also accept raw prefill work (Alg.1 round 2)
    def inflight_tokens(self) -> float:
        pc = self.eng.pending_chunked
        rem = (len(pc.prompt) - pc.prefill_done) if pc else 0
        return float(rem + sum(len(r.prompt) for r in self.eng.waiting))

    def prefill_velocity(self) -> float:
        return float(self.eng.chunk_size) * 20.0 if self.eng.chunk_size \
            else 0.0   # chunk/iteration x ~20 engine iterations/s prior


@dataclass
class GatewayStats:
    arrivals: list = field(default_factory=list)   # (t, in_len, bucket)

    def observe(self, t, in_len, bucket):
        self.arrivals.append((t, in_len, bucket))
        self.arrivals = [a for a in self.arrivals if t - a[0] <= 5.0]

    def rates(self, t, window=1.0):
        win = [a for a in self.arrivals if t - a[0] <= window]
        tok = sum(a[1] for a in win) / window
        by_bucket: dict[str, float] = {}
        for _, n, b in win:
            by_bucket[b] = by_bucket.get(b, 0.0) + n / window
        return tok, by_bucket, len(win) / window


class PDCluster:
    """A miniature PD-disaggregated deployment with live autoscaling."""

    def __init__(self, cfg: ModelConfig, params, policy: Optional[Policy],
                 n_prefillers: int = 1, n_decoders: int = 1,
                 n_convertible: int = 1, slots_per_decoder: int = 4,
                 max_len: int = 128, chunk_size: int = 16,
                 predictor: Optional[OutputPredictor] = None,
                 max_instances: int = 8):
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.max_len = max_len
        self.slots = slots_per_decoder
        self.chunk_size = chunk_size
        self.max_instances = max_instances
        self.router = Router(BurstDetector())
        self.predictor = predictor or OutputPredictor(0.85, 0)
        self.transfers = TransferStats()
        self.gateway = GatewayStats()
        self._iid = 0
        self.prefillers = [self._new_prefiller()
                           for _ in range(n_prefillers)]
        self.decoders = [self._new_decoder() for _ in range(n_decoders)]
        self.convertibles = [self._new_decoder(convertible=True)
                             for _ in range(n_convertible)]
        self.pending: list[tuple[Request, kvtransfer.KVPayload, int]] = []
        self.finished: list[Request] = []
        self.now = 0.0

    def _new_prefiller(self) -> PrefillerInstance:
        self._iid += 1
        return PrefillerInstance(self._iid, self.cfg, self.params,
                                 self.max_len)

    def _new_decoder(self, convertible: bool = False) -> DecoderAdapter:
        self._iid += 1
        eng = Engine(self.cfg, self.params, num_slots=self.slots,
                     max_len=self.max_len,
                     chunk_size=self.chunk_size if convertible else 0)
        return DecoderAdapter(eng, convertible)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.arrival_t = self.now
        bucket = self.predictor.predict_bucket(len(req.prompt),
                                               req.max_new_tokens)
        req.bucket = bucket
        self.router.burst.observe(self.now, float(len(req.prompt)))
        self.gateway.observe(self.now, len(req.prompt), bucket)
        burst = self.convertibles and self.router.burst.is_burst(self.now)
        if burst:
            tgt, kind = self.router.route_prefill(
                len(req.prompt), [], self.convertibles, self.now)
            if tgt is not None:
                tgt.eng.add_request(req)
                if req.slot >= 0:
                    tgt.bucket_of_slot[req.slot] = bucket
                return
        tgt, kind = self.router.route_prefill(
            len(req.prompt), self.prefillers, self.convertibles, self.now)
        if kind == "prefiller":
            tgt.submit(req)
        elif kind == "convertible":
            tgt.eng.add_request(req)
            if req.slot >= 0:
                tgt.bucket_of_slot[req.slot] = bucket
        else:
            min(self.prefillers,
                key=lambda p: p.inflight_tokens()).submit(req)

    # ------------------------------------------------------------------
    def step(self):
        t0 = time.perf_counter()
        # 1. prefillers produce payloads
        for p in self.prefillers:
            out = p.step()
            if out is not None:
                self.pending.append(out)
        # 2. network -> decode admission (per-bucket least-loaded, §IV-E2)
        still = []
        for req, payload, tok in self.pending:
            d = self.router.route_decode(
                getattr(req, "bucket", "M-M"),
                [x for x in self.decoders + self.convertibles
                 if x.eng.free_slots() > 0])
            if d is None:
                still.append((req, payload, tok))
                continue
            ok = d.eng.insert_prefilled(req, payload, tok, self.transfers)
            if ok:
                d.bucket_of_slot[req.slot] = getattr(req, "bucket", "M-M")
            else:
                still.append((req, payload, tok))
        self.pending = still
        # 3. decoders step (requests record their own completion times)
        for d in self.decoders + self.convertibles:
            d.eng.now = self.now
            d.eng.step()
        self.now += time.perf_counter() - t0

    # ------------------------------------------------------------------
    def _observation(self) -> Observation:
        tok, by_bucket, rps = self.gateway.rates(self.now)
        utils = [d.mem_util() for d in self.decoders]
        return Observation(
            t=self.now, token_rate_in=tok, token_rate_by_bucket=by_bucket,
            rps=rps,
            prefill_queue=sum(len(p.queue) for p in self.prefillers),
            decode_inflight=sum(int(d.eng.active.sum())
                                for d in self.decoders + self.convertibles),
            mem_util=float(np.mean(utils)) if utils else 0.0,
            cur_prefillers=len(self.prefillers),
            cur_decoders=len(self.decoders))

    def autoscale(self):
        """One Scaler tick: policy -> boot/retire instances (§IV-C)."""
        if self.policy is None:
            return
        dec = self.policy.decide(self._observation())
        while len(self.prefillers) < min(dec.prefillers, self.max_instances):
            self.prefillers.append(self._new_prefiller())
        while len(self.prefillers) > max(dec.prefillers, 1):
            idle = [p for p in self.prefillers if p.idle]
            if not idle:
                break
            self.prefillers.remove(idle[-1])
        while len(self.decoders) < min(dec.decoders, self.max_instances):
            self.decoders.append(self._new_decoder())
        while len(self.decoders) > max(dec.decoders, 1):
            idle = [d for d in self.decoders if d.eng.free_slots()
                    == d.eng.num_slots]
            if not idle:
                break
            self.decoders.remove(idle[-1])

    # ------------------------------------------------------------------
    def run_until_drained(self, max_steps: int = 2000,
                          autoscale_every: int = 10):
        steps = 0
        while self._busy():
            self.step()
            steps += 1
            if steps % autoscale_every == 0:
                self.autoscale()
            if steps > max_steps:
                raise RuntimeError("PD cluster did not drain")

    def _busy(self) -> bool:
        if self.pending:
            return True
        if any(p.queue for p in self.prefillers):
            return True
        for d in self.decoders + self.convertibles:
            if d.eng.active.any() or d.eng.waiting \
                    or d.eng.pending_chunked is not None:
                return True
        return False

    # ------------------------------------------------------------------
    def measured_network_velocity(self, link_bw: float = 50e9) -> float:
        return self.transfers.measured_network_velocity(link_bw)
