from repro.serving.engine import Engine, Request  # noqa: F401
from repro.serving.disagg import (  # noqa: F401
    DecoderAdapter, PDCluster, PrefillerInstance,
)
from repro.serving.kvtransfer import (  # noqa: F401
    KVPayload, TransferStats, extract, insert, payload_bytes, transfer,
)
