"""Paged KV cache: vLLM's block-table idea adapted to TPU.

The default Engine preallocates a contiguous per-slot cache (XLA-static,
simple).  Production memory efficiency wants vLLM-style paging: a global
pool of fixed-size blocks, per-request block tables, allocation on demand —
no fragmentation between short and long requests.  TPU adaptation: the
block size is 128 tokens (lane-width aligned, so one block = one MXU-shaped
tile per head) instead of vLLM's 16.

Components:
  * ``BlockAllocator``  — free-list allocation with explicit OOM signaling
    (backpressure: this is exactly the memory-release dynamic TokenScale's
    decode velocity V_D measures);
  * ``PagedKV``         — (layers-stacked) pooled K/V + block tables;
  * ``paged_decode_attention_ref`` — pure-jnp oracle (gather + masked
    attention over the request's pages);
  * the Pallas kernel lives in ``kernels/paged_decode_attention.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

BLOCK_SIZE = 128


class OutOfBlocks(Exception):
    """Allocation failure == decoder backpressure (§III-B)."""


@dataclass
class BlockAllocator:
    num_blocks: int
    _free: list = field(default_factory=list)
    _owner: dict = field(default_factory=dict)     # block -> rid

    def __post_init__(self):
        self._free = list(range(self.num_blocks - 1, -1, -1))

    def alloc(self, rid: int) -> int:
        if not self._free:
            raise OutOfBlocks(f"no free blocks for request {rid}")
        b = self._free.pop()
        self._owner[b] = rid
        return b

    def free_request(self, rid: int) -> int:
        blocks = [b for b, r in self._owner.items() if r == rid]
        for b in blocks:
            del self._owner[b]
            self._free.append(b)
        return len(blocks)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def utilization(self) -> float:
        return 1.0 - self.n_free / max(self.num_blocks, 1)


class PagedKV:
    """One layer-stacked paged pool + per-slot block tables.

    pool_k/pool_v : (L, num_blocks, BLOCK_SIZE, Hkv, Dh)
    tables        : (num_slots, max_blocks) int32, -1 = unallocated
    lens          : (num_slots,) tokens currently cached per slot
    """

    def __init__(self, num_layers: int, num_blocks: int, num_slots: int,
                 max_blocks_per_slot: int, n_kv_heads: int, head_dim: int,
                 dtype=jnp.bfloat16):
        self.block_size = BLOCK_SIZE
        self.alloc = BlockAllocator(num_blocks)
        self.pool_k = jnp.zeros(
            (num_layers, num_blocks, BLOCK_SIZE, n_kv_heads, head_dim),
            dtype)
        self.pool_v = jnp.zeros_like(self.pool_k)
        self.tables = np.full((num_slots, max_blocks_per_slot), -1,
                              np.int32)
        self.lens = np.zeros((num_slots,), np.int32)

    def ensure_capacity(self, slot: int, rid: int, n_tokens: int):
        """Allocate blocks so slot can hold `n_tokens`; raises OutOfBlocks."""
        need = -(-n_tokens // self.block_size)
        have = int((self.tables[slot] >= 0).sum())
        for i in range(have, need):
            self.tables[slot, i] = self.alloc.alloc(rid)

    def write_tokens(self, slot: int, layer_k, layer_v, start: int):
        """Write (L, n, Hkv, Dh) new tokens at position `start`."""
        n = layer_k.shape[1]
        for off in range(n):
            pos = start + off
            blk = int(self.tables[slot, pos // self.block_size])
            assert blk >= 0, "write into unallocated block"
            i = pos % self.block_size
            self.pool_k = self.pool_k.at[:, blk, i].set(
                layer_k[:, off].astype(self.pool_k.dtype))
            self.pool_v = self.pool_v.at[:, blk, i].set(
                layer_v[:, off].astype(self.pool_v.dtype))
        self.lens[slot] = max(self.lens[slot], start + n)

    def release(self, slot: int, rid: int):
        self.alloc.free_request(rid)
        self.tables[slot] = -1
        self.lens[slot] = 0


def paged_decode_attention_ref(q, pool_k, pool_v, table, cur_len,
                               scale: Optional[float] = None):
    """Oracle: single-layer paged decode attention for ONE request.

    q: (Hq, D); pool_k/v: (num_blocks, BS, Hkv, D); table: (max_blocks,)
    int32 (-1 = unallocated); attend to positions 0..cur_len (inclusive —
    the current token's KV is already written)."""
    BS = pool_k.shape[1]
    Hq, D = q.shape
    Hkv = pool_k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    safe = jnp.maximum(table, 0)
    k = pool_k[safe]                       # (max_blocks, BS, Hkv, D)
    v = pool_v[safe]
    MB = table.shape[0]
    k = k.reshape(MB * BS, Hkv, D)
    v = v.reshape(MB * BS, Hkv, D)
    pos = jnp.arange(MB * BS)
    valid = (pos <= cur_len) & (jnp.repeat(table, BS) >= 0)
    qg = q.reshape(Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("kgd,lkd->kgl", qg, k.astype(jnp.float32)) * scale
    s = jnp.where(valid[None, None], s, -2.0 ** 30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("kgl,lkd->kgd", p, v.astype(jnp.float32))
    return o.reshape(Hq, D).astype(q.dtype)
