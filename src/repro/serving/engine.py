"""Slot-based continuous-batching inference engine.

One ``Engine`` == one prefiller / decoder / convertible-decoder *instance*
in TokenScale terms.  It wraps (cfg, params) with a fixed pool of request
slots backed by a preallocated per-slot cache/state (the TPU analogue of
vLLM's paged KV pool — slot-contiguous rather than paged, page granularity
traded for XLA-static shapes; see DESIGN.md).

Three jitted programs:

  * ``_prefill``      whole-prompt prefill of one request (batch-1 state)
  * ``_decode``       one token for every active slot
  * ``_mixed``        the Convertible-Decoder step: decode for active slots
                      FUSED with one restricted prefill chunk (XLA compiles
                      a single program — decode's idle MXU cycles absorb the
                      chunk, the TPU analogue of the paper's chunked-prefill
                      co-location)

The SLO-aware chunk size / memory reservation policy that *drives* ``_mixed``
lives in ``repro.core.convertible``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_state, prefill


@dataclass(frozen=True)
class SamplingParams:
    """temperature == 0 -> greedy (the default keeps decoding exact)."""
    temperature: float = 0.0
    top_k: int = 0                     # 0 = no top-k truncation
    top_p: float = 1.0                 # 1.0 = no nucleus truncation
    seed: int = 0


def sample_token(logits: np.ndarray, sp: SamplingParams,
                 rng: np.random.RandomState) -> int:
    """Temperature -> top-k -> top-p -> categorical, on one logits row."""
    if sp.temperature <= 0.0:
        return int(np.argmax(logits))
    z = logits.astype(np.float64) / sp.temperature
    if sp.top_k:
        kth = np.partition(z, -sp.top_k)[-sp.top_k]
        z = np.where(z < kth, -np.inf, z)
    p = np.exp(z - z.max())
    p /= p.sum()
    if sp.top_p < 1.0:
        order = np.argsort(-p)
        csum = np.cumsum(p[order])
        cut = int(np.searchsorted(csum, sp.top_p) + 1)
        mask = np.zeros_like(p)
        mask[order[:cut]] = 1.0
        p = p * mask
        p /= p.sum()
    return int(rng.choice(len(p), p=p))


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (L,) int32
    max_new_tokens: int
    arrival_t: float = 0.0
    image_embeds: Optional[np.ndarray] = None
    sampling: SamplingParams = SamplingParams()
    # filled by the engine:
    slot: int = -1
    first_token_t: float = -1.0
    finish_t: float = -1.0
    output: list = field(default_factory=list)
    prefill_done: int = 0              # tokens prefilled so far (chunked)

    def __post_init__(self):
        self._rng = np.random.RandomState(
            (self.sampling.seed * 1009 + self.rid) % (2 ** 31 - 1))

    def pick(self, logits_row: np.ndarray) -> int:
        return sample_token(logits_row, self.sampling, self._rng)


def _state_batch_axis(path) -> int:
    """State leaves are (B, ...) under `prefix` and (num_blocks, B, ...)
    under `blocks` (stacked for the depth scan) — see params.state_leaves."""
    key = path[0].key if hasattr(path[0], "key") else str(path[0])
    return 1 if key == "blocks" else 0


def _write_slot(pool, one, slot):
    """Copy a batch-1 state tree into slot `slot` of the pooled state."""
    def per_leaf(path, c, u):
        ax = _state_batch_axis(path)
        return jax.lax.dynamic_update_slice_in_dim(
            c, u.astype(c.dtype), slot, axis=ax)
    return jax.tree_util.tree_map_with_path(per_leaf, pool, one)


def _read_slot(pool, slot):
    """Extract a batch-1 view of `slot` from the pooled state tree."""
    def per_leaf(path, c):
        ax = _state_batch_axis(path)
        return jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=ax)
    return jax.tree_util.tree_map_with_path(per_leaf, pool)


class Engine:
    """A single inference instance with `num_slots` concurrent requests."""

    def __init__(self, cfg: ModelConfig, params, num_slots: int = 8,
                 max_len: int = 256, chunk_size: int = 0):
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.chunk_size = chunk_size          # >0 enables convertible mode
        self.state = init_state(cfg, num_slots, max_len)
        self.cur_lens = np.zeros((num_slots,), np.int32)
        self.active = np.zeros((num_slots,), bool)
        self.last_tokens = np.zeros((num_slots,), np.int32)
        self.slot_req: list[Optional[Request]] = [None] * num_slots
        self.waiting: list[Request] = []
        self.pending_chunked: Optional[Request] = None
        self.now = 0.0                        # virtual clock (tests/sim)
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        cfg = self.cfg

        def _prefill_one(params, state1, tokens, length, image_embeds, start):
            return prefill(cfg, params, state1, tokens, length,
                           image_embeds=image_embeds, start=start)

        def _decode_all(params, state, last_tokens, cur_lens):
            return decode_step(cfg, params, state, last_tokens, cur_lens)

        def _mixed(params, state, last_tokens, cur_lens,
                   chunk_state, chunk_tokens, chunk_len, chunk_start):
            """Fused decode + restricted prefill chunk (single XLA program)."""
            logits, new_state = decode_step(cfg, params, state,
                                            last_tokens, cur_lens)
            clog, new_cstate = prefill(cfg, params, chunk_state, chunk_tokens,
                                       chunk_len, start=chunk_start)
            return logits, new_state, clog, new_cstate

        self._prefill = jax.jit(_prefill_one)
        self._decode = jax.jit(_decode_all)
        self._mixed = jax.jit(_mixed)

    # ------------------------------------------------------------------
    def free_slots(self) -> int:
        return int((~self.active).sum())

    def memory_tokens_used(self) -> int:
        return int(self.cur_lens[self.active].sum())

    def insert_prefilled(self, req: Request, payload, first_token: int,
                         stats=None) -> bool:
        """PD-disaggregation entry point: admit a request whose prefill ran
        on ANOTHER instance; `payload` is the kvtransfer.KVPayload."""
        from repro.serving import kvtransfer
        if self.free_slots() == 0:
            return False
        slot = self._alloc_slot(req)
        import time as _t
        t0 = _t.perf_counter()
        nbytes = kvtransfer.payload_bytes(payload)
        self.state = kvtransfer.insert(self.cfg, self.state, payload, slot)
        if stats is not None:
            stats.record(nbytes, payload.length, _t.perf_counter() - t0)
        self.last_tokens[slot] = first_token
        self.cur_lens[slot] = payload.length
        req.prefill_done = payload.length
        if req.first_token_t < 0:
            req.first_token_t = self.now
        req.output.append(first_token)
        return True

    def add_request(self, req: Request) -> bool:
        """Admit a request; prefill immediately (or queue for chunking)."""
        if self.free_slots() == 0:
            self.waiting.append(req)
            return False
        if self.chunk_size and self.pending_chunked is None \
                and len(req.prompt) > self.chunk_size:
            # convertible decoder: long prompts prefill chunk-by-chunk
            req.slot = self._alloc_slot(req)
            self.pending_chunked = req
            return True
        self._prefill_now(req)
        return True

    def _alloc_slot(self, req: Request) -> int:
        slot = int(np.argmax(~self.active))
        self.active[slot] = True
        self.slot_req[slot] = req
        self.cur_lens[slot] = 0
        return slot

    def _prefill_now(self, req: Request):
        slot = self._alloc_slot(req)
        L = len(req.prompt)
        assert L <= self.max_len, (L, self.max_len)
        pad = min(max(8, int(2 ** np.ceil(np.log2(max(L, 1))))),
                  self.max_len)
        toks = np.zeros((1, pad), np.int32)
        toks[0, :L] = req.prompt
        st1 = init_state(self.cfg, 1, self.max_len)
        ie = None
        if req.image_embeds is not None:
            ie = jnp.asarray(req.image_embeds[None])
        logits, st1 = self._prefill(
            self.params, st1, jnp.asarray(toks),
            jnp.array([L], jnp.int32), ie, jnp.zeros((1,), jnp.int32))
        self.state = _write_slot(self.state, st1, slot)
        tok = req.pick(np.asarray(logits[0]))
        self.last_tokens[slot] = tok
        self.cur_lens[slot] = L
        req.prefill_done = L
        req.first_token_t = self.now
        req.output.append(tok)

    # ------------------------------------------------------------------
    def step(self) -> list[tuple[int, int]]:
        """One engine iteration.  Returns [(rid, token)] emitted."""
        emitted: list[tuple[int, int]] = []
        if not self.active.any() and self.pending_chunked is None:
            self._drain_waiting()
            return emitted

        if self.pending_chunked is not None:
            emitted += self._step_mixed()
        elif self.active.any():
            emitted += self._step_decode()
        self._drain_waiting()
        return emitted

    def _drain_waiting(self):
        while self.waiting and self.free_slots() > 0:
            self.add_request(self.waiting.pop(0))

    def _step_decode(self) -> list[tuple[int, int]]:
        logits, self.state = self._decode(
            self.params, self.state,
            jnp.asarray(self.last_tokens), jnp.asarray(self.cur_lens))
        return self._commit_decode(logits)

    def _step_mixed(self) -> list[tuple[int, int]]:
        req = self.pending_chunked
        C = self.chunk_size
        start = req.prefill_done
        L = len(req.prompt)
        chunk = np.zeros((1, C), np.int32)
        n = min(C, L - start)
        chunk[0, :n] = req.prompt[start:start + n]
        slot = req.slot
        st1 = _read_slot(self.state, slot)
        logits, self.state, clog, st1 = self._mixed(
            self.params, self.state,
            jnp.asarray(self.last_tokens), jnp.asarray(self.cur_lens),
            st1, jnp.asarray(chunk),
            jnp.array([min(L, start + n)], jnp.int32),
            jnp.array([start], jnp.int32))
        self.state = _write_slot(self.state, st1, slot)
        req.prefill_done += n
        out = self._commit_decode(logits, skip_slot=slot)
        if req.prefill_done >= L:
            tok = req.pick(np.asarray(clog[0]))
            self.last_tokens[slot] = tok
            self.cur_lens[slot] = L
            req.first_token_t = self.now
            req.output.append(tok)
            self.pending_chunked = None
        return out

    def _commit_decode(self, logits, skip_slot: int = -1):
        emitted = []
        lg = np.asarray(logits)
        for s in range(self.num_slots):
            if not self.active[s] or s == skip_slot:
                continue
            req = self.slot_req[s]
            if req is None or req.prefill_done < len(req.prompt):
                continue
            tok = req.pick(lg[s])
            self.cur_lens[s] += 1
            self.last_tokens[s] = tok
            req.output.append(tok)
            emitted.append((req.rid, tok))
            if len(req.output) >= req.max_new_tokens \
                    or self.cur_lens[s] + 1 >= self.max_len:
                req.finish_t = self.now
                self.active[s] = False
                self.slot_req[s] = None
        return emitted

    # ------------------------------------------------------------------
    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while (self.active.any() or self.waiting
               or self.pending_chunked is not None):
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("engine did not drain")


