"""KV-Cache / recurrent-state transfer between PD instances.

The paper moves KVC from prefillers to decoders over NVLink/RDMA (LMCache +
NIXL, §IV-F); on the TPU target this is an ICI point-to-point transfer.  On
this CPU host the "wire" is a device-local buffer donation, but the
*interface* is the production one:

    payload = extract(cfg, state, length)      # prefiller side
    nbytes  = payload_bytes(payload)           # what would cross the wire
    state   = insert(cfg, pool_state, payload, slot)   # decoder side

``extract`` trims the cache to the request's actual length (the only part
worth shipping) and keeps O(1) recurrent states whole — this is why
attention-free architectures have near-infinite network velocity (§III-C /
DESIGN.md): ``payload_bytes`` for RWKV is KBs where Llama's is MBs/request.

The transfer ledger (`TransferStats`) is the measured source for the
network-stage Token Velocity the Offline Profiler reports.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

from repro.serving.engine import _read_slot, _state_batch_axis


@dataclass
class KVPayload:
    """One request's transferable state (batch-1 tree, length-trimmed)."""
    tree: dict
    length: int
    seq_axes: dict          # path-str -> axis that was trimmed (re-pad info)


_SEQ_LEAVES = ("k", "v", "k_scale", "v_scale", "c_kv", "k_rope")


def _leaf_key(path) -> str:
    return path[-1].key if hasattr(path[-1], "key") else str(path[-1])


def extract(cfg: ModelConfig, state, length: int, slot: int = 0) -> KVPayload:
    """Pull slot `slot` out of a pooled state and trim cache leaves to
    `length` tokens (round up to 128 for TPU-aligned transfers)."""
    one = _read_slot(state, slot)
    pad_len = min(max(int(math.ceil(length / 128.0)) * 128, 8), 1 << 30)
    seq_axes = {}

    def trim(path, leaf):
        key = _leaf_key(path)
        if key in _SEQ_LEAVES:
            ax = _state_batch_axis(path) + 1     # seq is right after batch
            n = min(pad_len, leaf.shape[ax])
            seq_axes[jax.tree_util.keystr(path)] = ax
            return jax.lax.slice_in_dim(leaf, 0, n, axis=ax)
        return leaf

    return KVPayload(
        tree=jax.tree_util.tree_map_with_path(trim, one),
        length=length, seq_axes=seq_axes)


def insert(cfg: ModelConfig, pool_state, payload: KVPayload, slot: int):
    """Write a payload into slot `slot` of a decoder's pooled state."""
    def put(path, pool_leaf, one_leaf):
        ax = _state_batch_axis(path)
        key = jax.tree_util.keystr(path)
        if key in payload.seq_axes:
            sax = payload.seq_axes[key]
            pad = pool_leaf.shape[sax] - one_leaf.shape[sax]
            if pad > 0:
                widths = [(0, 0)] * one_leaf.ndim
                widths[sax] = (0, pad)
                one_leaf = jnp.pad(one_leaf, widths)
        return jax.lax.dynamic_update_slice_in_dim(
            pool_leaf, one_leaf.astype(pool_leaf.dtype), slot, axis=ax)

    return jax.tree_util.tree_map_with_path(put, pool_state, payload.tree)


def payload_bytes(payload: KVPayload) -> int:
    return int(sum(np.prod(x.shape) * x.dtype.itemsize
                   for x in jax.tree.leaves(payload.tree)))


@dataclass
class TransferStats:
    """Ledger of prefiller->decoder transfers (drives measured V_N)."""
    n_transfers: int = 0
    total_bytes: int = 0
    total_tokens: int = 0
    total_wall_s: float = 0.0

    def record(self, nbytes: int, tokens: int, wall_s: float):
        self.n_transfers += 1
        self.total_bytes += nbytes
        self.total_tokens += tokens
        self.total_wall_s += wall_s

    def bytes_per_token(self) -> float:
        return self.total_bytes / max(self.total_tokens, 1)

    def measured_network_velocity(self, link_bw: float) -> float:
        """tok/s the link could sustain at the observed bytes/token."""
        return link_bw / max(self.bytes_per_token(), 1e-9)


def transfer(cfg: ModelConfig, src_state, dst_state, length: int,
             src_slot: int, dst_slot: int,
             stats: TransferStats | None = None):
    """extract -> (wire) -> insert, with ledger accounting."""
    t0 = time.perf_counter()
    payload = extract(cfg, src_state, length, src_slot)
    nbytes = payload_bytes(payload)
    new_dst = insert(cfg, dst_state, payload, dst_slot)
    if stats is not None:
        stats.record(nbytes, length, time.perf_counter() - t0)
    return new_dst
