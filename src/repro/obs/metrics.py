"""Streaming metrics registry: counters, gauges, log-bucketed histograms.

The flight recorder (``obs.recorder``) owns one registry per run and
samples it on the engines' existing timeline cadence (every
``ClusterBase._snapshot``), so the metrics plane shares the snapshot
clock instead of inventing its own.  Nothing here is wired into the hot
path unless telemetry is on: the engines only touch the registry through
``FlightRecorder`` hooks that are guarded by ``cluster.obs is not None``.

All three instrument kinds are append-only and allocation-light:

  * ``Counter``   — monotonic float total (token velocities, drain counts);
  * ``Gauge``     — last-write-wins level (queue depth, KV occupancy);
  * ``Histogram`` — log2-bucketed value distribution (per-request TTFT,
    span durations) with exact count/sum, so means stay exact while the
    shape is O(#buckets) regardless of run length.
"""
from __future__ import annotations

import math


class Counter:
    """Monotonic total.  ``rate(t)`` windows are the caller's business:
    the registry snapshots raw totals and the sampler derives deltas."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, by: float = 1.0):
        self.value += by


class Gauge:
    """Last-write-wins level."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: float):
        self.value = float(value)


class Histogram:
    """Log2-bucketed distribution over positive values.

    Bucket ``i`` covers ``[base * 2**i, base * 2**(i+1))``; values at or
    below ``base`` land in bucket 0's underflow.  Exact ``count``/``sum``
    ride along so means are not quantized."""

    __slots__ = ("base", "buckets", "count", "sum")

    def __init__(self, base: float = 1e-3):
        if base <= 0:
            raise ValueError("histogram base must be > 0")
        self.base = base
        self.buckets: dict[int, int] = {}
        self.count = 0
        self.sum = 0.0

    def observe(self, value: float):
        self.count += 1
        self.sum += value
        i = 0
        if value > self.base:
            i = int(math.log2(value / self.base)) + 1
        self.buckets[i] = self.buckets.get(i, 0) + 1

    def quantile(self, q: float) -> float:
        """Upper edge of the bucket holding the q-quantile (0 with no
        observations) — a bounded-error order statistic, good enough for
        dashboards; exact tails come from the span records."""
        if not self.count:
            return 0.0
        target = max(int(math.ceil(q * self.count)), 1)
        seen = 0
        for i in sorted(self.buckets):
            seen += self.buckets[i]
            if seen >= target:
                return self.base * (2.0 ** i)
        return self.base * (2.0 ** max(self.buckets))

    def to_dict(self) -> dict:
        return {"count": self.count, "sum": self.sum,
                "base": self.base,
                "buckets": {str(k): v
                            for k, v in sorted(self.buckets.items())}}


class MetricsRegistry:
    """Name-keyed instruments + the timeline sampler.

    ``sample(t)`` appends one row per call: every counter's running total
    and every gauge's level, keyed by instrument name.  Rows are plain
    dicts so the exporter can stream them to JSONL unchanged."""

    def __init__(self):
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}
        self.samples: list[dict] = []

    # ---- instrument accessors (create-on-first-use) -------------------
    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(self, name: str, base: float = 1e-3) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(base)
        return h

    # ---- convenience mutators -----------------------------------------
    def inc(self, name: str, by: float = 1.0):
        self.counter(name).inc(by)

    def set(self, name: str, value: float):
        self.gauge(name).set(value)

    def observe(self, name: str, value: float):
        self.histogram(name).observe(value)

    # ---- sampling ------------------------------------------------------
    def sample(self, t: float) -> dict:
        row: dict = {"t": t}
        for name, c in self.counters.items():
            row[name] = c.value
        for name, g in self.gauges.items():
            row[name] = g.value
        self.samples.append(row)
        return row

    def totals(self) -> dict:
        """Final counter totals + histogram summaries (the run-level
        rollup the exporter appends after the last sample)."""
        out: dict = {name: c.value for name, c in self.counters.items()}
        for name, h in self.histograms.items():
            out[name] = h.to_dict()
        return out
