"""``python -m repro.obs TRACE.jsonl`` — the explainer CLI (same flags
as ``repro.obs.explain``; this alias avoids runpy's package-reimport
warning when the package is already imported)."""
from .explain import main

if __name__ == "__main__":
    raise SystemExit(main())
