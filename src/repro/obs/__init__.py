"""Flight-recorder observability layer (PR 9).

Default-off: engines carry a single ``obs = None`` attribute and every
hook is guarded by an ``is not None`` test, so disabled telemetry is
byte-identical and effectively zero-overhead.  Enable per run with
``ExperimentSpec(telemetry=True)`` or ``benchmarks/run.py
--trace-out=PATH``; see README "Observability" for the quickstart.
"""
from .explain import explain, render_report
from .export import (chrome_trace, load_jsonl, trace_records,
                     validate_jsonl, validate_trace_lines,
                     write_chrome_trace, write_jsonl)
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .recorder import (SPAN_ORDER, TTFT_STAGE_LABELS, FlightRecorder,
                       jsonable, request_spans)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "FlightRecorder", "SPAN_ORDER", "TTFT_STAGE_LABELS",
    "jsonable", "request_spans",
    "trace_records", "write_jsonl", "load_jsonl",
    "chrome_trace", "write_chrome_trace",
    "validate_jsonl", "validate_trace_lines",
    "explain", "render_report",
]
