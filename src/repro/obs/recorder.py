"""Flight recorder: per-request spans, point events, scaling decisions.

One ``FlightRecorder`` instance is attached to a cluster engine via
``ClusterBase.attach_obs`` when ``ExperimentSpec.telemetry`` is on.  Every
engine-side hook is guarded by ``self.obs is not None`` so the default-off
path costs a single attribute test and never touches RNG, float math, or
event ordering — goldens stay byte-identical by construction.

The recorder is a pure *observer*: it reads simulation state (timestamps
already stamped on ``SimRequest``, plans already produced by the policy)
and never feeds anything back into the engines.

Span model
----------
A request's life is covered by a gap-free chain of spans derived from the
timestamps the engines already maintain:

    queue_wait    arrival          -> prefill start
    prefill       prefill start    -> prefill end (chunk boundaries are
                                      point events, exact on the event
                                      engine)
    kvc_transfer  prefill end      -> KV ready on the decode side
                                      (zero-width for on-box prefill)
    decode_wait   KV ready         -> decode admission
    decode_first  decode admission -> first token
    decode_rest   first token      -> done

Adjacent spans share a boundary timestamp, so for every finished request
the span durations sum *exactly* (same floats, no re-derivation) to its
recorded TTFT (first five spans) and E2E (all six) — the conservation
property pinned by ``tests/test_obs.py``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from .metrics import MetricsRegistry

#: span names in lifecycle order; the first five sum to TTFT.
SPAN_ORDER = ("queue_wait", "prefill", "kvc_transfer",
              "decode_wait", "decode_first", "decode_rest")

#: spans that can dominate a TTFT violation, mapped to the attribution
#: label the explainer reports (§ queueing vs prefill vs transfer vs
#: decode backpressure).
TTFT_STAGE_LABELS = {
    "queue_wait": "queueing",
    "prefill": "prefill",
    "kvc_transfer": "transfer",
    "decode_wait": "decode-backpressure",
    "decode_first": "decode",
}


def jsonable(obj):
    """Best-effort conversion of recorder payloads to strict-JSON values:
    dataclasses -> dicts, sets/tuples -> sorted/ordinary lists, non-finite
    floats -> None, non-string dict keys -> str."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return jsonable(dataclasses.asdict(obj))
    if isinstance(obj, dict):
        return {str(k): jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonable(v) for v in obj]
    if isinstance(obj, (set, frozenset)):
        return sorted(str(v) for v in obj)
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, (int, str, bool)) or obj is None:
        return obj
    return str(obj)


def request_spans(req) -> list[dict]:
    """Build the span chain for one ``SimRequest`` from its timestamps.
    Unreached stages (-1 sentinels) truncate the chain, so in-flight
    requests yield a valid prefix instead of negative-duration garbage."""
    marks = (("queue_wait", req.src.t, req.t_prefill_start),
             ("prefill", req.t_prefill_start, req.t_prefill_end),
             ("kvc_transfer", req.t_prefill_end, req.t_kv_ready),
             ("decode_wait", req.t_kv_ready, req.t_decode_start),
             ("decode_first", req.t_decode_start, req.t_first_token),
             ("decode_rest", req.t_first_token, req.t_finish))
    spans = []
    for name, a, b in marks:
        if a < 0 or b < 0:
            break
        spans.append({"name": name, "t0": a, "t1": b, "dur": b - a})
    return spans


class FlightRecorder:
    """Collects spans, point events, metrics samples, and scaling
    decisions for one engine run.  See module docstring for the span
    model; ``obs.export`` turns the collected state into JSONL and
    Chrome-trace JSON."""

    def __init__(self, meta: Optional[dict] = None):
        self.meta: dict = dict(meta or {})
        self.engine: str = ""
        self.t_end: float = 0.0
        self.metrics = MetricsRegistry()
        self.requests: list[dict] = []    # finished-request records
        self.events: list[dict] = []      # point events (preempt/oom/...)
        self.decisions: list[dict] = []   # FleetPlan + Eq. 2-4 inputs
        # per-rid routing annotations (arrival decision + requeues)
        self.routes: dict[int, list[dict]] = {}
        # hot-path token odometers (mirrored into the registry on sample)
        self.prefill_tokens_done = 0.0    # prompt tokens fully prefilled
        self.decode_tokens_done = 0.0     # decode tokens granted
        # previous-sample state for rate derivation
        self._last_sample_t: Optional[float] = None
        self._last_prefill = 0.0
        self._last_decode = 0.0
        self._last_cost = 0.0

    # ------------------------------------------------------------------
    # request lifecycle hooks (called from ClusterBase, obs-guarded)
    # ------------------------------------------------------------------
    def on_arrival(self, req, t: float, burst: bool = False):
        self.metrics.inc("arrivals")
        if burst:
            self.metrics.inc("burst_arrivals")
        self.routes[req.src.rid] = [{"t": t, "step": "arrival",
                                     "burst": burst}]

    def on_routed(self, req, t: float, kind: Optional[str], target):
        """One routing decision for ``req``: ``kind`` is the Alg. 1 round
        that won ("prefiller"/"convertible"/"deflect") or "queue" when no
        capacity was found and the request joined the wait queue."""
        kind = kind or "queue"
        steps = self.routes.setdefault(req.src.rid, [])
        steps.append({"t": t, "step": "route", "kind": kind,
                      "target": getattr(target, "iid", None)})
        self.metrics.inc("route_" + kind)

    def on_transfer(self, req, t: float, delay: float):
        self.metrics.inc("kvc_transfers")
        self.metrics.inc("kvc_transfer_s", delay)

    def on_preempt(self, req, t: float, decoder, mode: str,
                   delay: float = 0.0):
        """A resident was evicted: ``mode`` is "swap" (DRAM ticket held,
        restore pays swap-in) or "recompute" (KV dropped)."""
        self.event(t, "preempt", rid=req.src.rid, priority=req.priority,
                   decoder=getattr(decoder, "iid", None), mode=mode,
                   delay=delay)
        self.metrics.inc("preemptions")
        if mode == "swap":
            self.metrics.inc("swap_outs")

    def on_oom(self, req, t: float, decoder):
        self.event(t, "oom", rid=req.src.rid,
                   decoder=getattr(decoder, "iid", None))
        self.metrics.inc("oom_preemptions")

    def on_deflect(self, req, t: float, target):
        self.event(t, "deflect", rid=req.src.rid,
                   target=getattr(target, "iid", None))
        self.metrics.inc("deflections")

    def on_chunk(self, t: float, decoder, tokens: float):
        """One chunked-prefill iteration boundary on a decode box (exact
        on the event engine; the fluid engine reports per-tick totals)."""
        self.event(t, "chunk", decoder=getattr(decoder, "iid", None),
                   tokens=tokens)

    def on_replication(self, t: float, kind: str, **fields):
        """Gateway replication lifecycle: kind is "planned" / "dispatch"
        / "done"."""
        self.event(t, "replication_" + kind, **fields)
        self.metrics.inc("replication_" + kind)

    def on_drain(self, t: float, pool: str, instance):
        self.event(t, "drain", pool=pool,
                   instance=getattr(instance, "iid", None))
        self.metrics.inc("drains")

    def on_spill(self, t: float, src: str, dst: str, n: int):
        self.event(t, "spill", src=src, dst=dst, n=n)
        self.metrics.inc("spills", n)

    def on_fault(self, t: float, kind: str, **fields):
        """One chaos-engine injection (sim.faults): kind is "crash" /
        "straggler" / "swap_degrade" / "link_down" / "kvc_fallback"."""
        self.event(t, "fault_" + kind, **fields)
        self.metrics.inc("fault_" + kind)

    def on_recovery(self, t: float, kind: str, **fields):
        """One self-healing action: kind is "restart" (husk reaped, warm
        replacement provisioned) / "straggler_end" / "swap_restore"."""
        self.event(t, "recovery_" + kind, **fields)
        self.metrics.inc("recovery_" + kind)

    def event(self, t: float, kind: str, **fields):
        """Generic point event."""
        rec = {"type": "event", "t": t, "kind": kind}
        rec.update(fields)
        self.events.append(rec)

    # ------------------------------------------------------------------
    # scaling decisions (the explainer's raw material)
    # ------------------------------------------------------------------
    def on_plan(self, t: float, obs, plan, debug: Optional[dict]):
        """Record one planner interval: the full ``FleetObservation``
        (per-pool snapshots + per-model gateway windows), the resulting
        ``FleetPlan``, and the policy's ``last_debug`` Eq. 2-4
        intermediates (rates, effective velocities, cost ranking,
        convertible absorption)."""
        self.decisions.append({
            "type": "decision", "t": t,
            "observation": jsonable(obs),
            "plan": jsonable(plan),
            "inputs": jsonable(debug) if debug is not None else {},
        })
        self.metrics.inc("plans")

    # ------------------------------------------------------------------
    # timeline sampling (piggybacks on ClusterBase._snapshot)
    # ------------------------------------------------------------------
    def on_snapshot(self, snap: dict, cluster) -> dict:
        """Sample the registry on the engines' snapshot cadence and add
        the per-stage velocity / occupancy / cost-rate block to the
        timeline row under a single additive ``"obs"`` key."""
        t = snap["t"]
        m = self.metrics
        m.set("queue_depth", snap.get("queue", 0))
        m.set("inflight", snap.get("inflight", 0))
        m.set("mem_util", snap.get("mem_util", 0.0))
        m.set("deflected_total", getattr(cluster, "n_deflected", 0))
        draining = sum(1 for pool in cluster.pools.values()
                       for i in pool.instances if i.draining)
        m.set("draining", draining)
        cost = getattr(cluster, "cost_dollars", 0.0)
        prefill_rate = decode_rate = cost_rate = 0.0
        if self._last_sample_t is not None and t > self._last_sample_t:
            dt = t - self._last_sample_t
            prefill_rate = (self.prefill_tokens_done
                            - self._last_prefill) / dt
            decode_rate = (self.decode_tokens_done - self._last_decode) / dt
            cost_rate = (cost - self._last_cost) / dt * 3600.0
        self._last_sample_t = t
        self._last_prefill = self.prefill_tokens_done
        self._last_decode = self.decode_tokens_done
        self._last_cost = cost
        m.set("prefill_tok_rate", prefill_rate)
        m.set("decode_tok_rate", decode_rate)
        m.set("cost_rate_per_hour", cost_rate)
        m.counter("prefill_tokens").value = self.prefill_tokens_done
        m.counter("decode_tokens").value = self.decode_tokens_done
        row = m.sample(t)
        # additive: the stock snapshot keys are untouched; telemetry-on
        # runs gain exactly one new key
        snap["obs"] = {k: v for k, v in row.items() if k != "t"}
        return snap

    # ------------------------------------------------------------------
    # router / gateway hook factories
    # ------------------------------------------------------------------
    def router_hook(self, model: str):
        """Build the ``Router.trace_hook`` callable for one model group:
        aggregate routing-outcome counters + SLO-budget histogram."""
        def hook(t, kind, target, in_len, priority, slo):
            self.metrics.inc("route_eval_" + (kind or "queue"))
            self.metrics.observe("route_slo_budget", slo)
        return hook

    def gateway_hook(self, model: str):
        """Build the ``Gateway.trace_hook`` callable: replication-plan
        point events tagged with the owning model group."""
        def hook(t, kind, **fields):
            self.on_replication(t, kind, model=model, **fields)
        return hook

    # ------------------------------------------------------------------
    # finalization
    # ------------------------------------------------------------------
    def request_record(self, req, ttft_slo_fn=None) -> dict:
        spans = request_spans(req)
        rec = {
            "type": "request",
            "rid": req.src.rid,
            "model": req.model,
            "priority": req.priority,
            "t_arrival": req.src.t,
            "in_len": req.src.in_len,
            "out_len": req.src.out_len,
            "generated": req.generated,
            "kv_hit_tokens": req.kv_hit_tokens,
            "n_evictions": req.n_evictions,
            "deflected": req.deflect_tgt is not None,
            "finished": req.t_finish >= 0,
            "ttft": req.ttft if req.t_first_token >= 0 else None,
            "tpot": req.tpot,
            "e2e": (req.t_finish - req.src.t) if req.t_finish >= 0 else None,
            "route": self.routes.get(req.src.rid, []),
            "spans": spans,
        }
        if ttft_slo_fn is not None:
            rec["ttft_slo"] = ttft_slo_fn(req.src.in_len, req.priority)
        return rec

    def finalize(self, requests, t_end: float):
        """Emit one record per finished request and the final registry
        sample.  Called once from ``ClusterBase._report``."""
        from repro.core.router import ttft_slo
        self.t_end = t_end
        for req in requests:
            self.requests.append(self.request_record(req, ttft_slo))
            if req.t_first_token >= 0:
                self.metrics.observe("ttft", req.ttft)
            for s in request_spans(req):
                self.metrics.observe("span_" + s["name"], s["dur"])
        self.metrics.counter("prefill_tokens").value = \
            self.prefill_tokens_done
        self.metrics.counter("decode_tokens").value = self.decode_tokens_done
