"""Trace exporters + the hand-rolled JSONL schema validator.

Two output shapes from one ``FlightRecorder``:

  * JSONL (``write_jsonl``) — one strict-JSON object per line, typed by a
    ``"type"`` field: ``meta``, ``decision``, ``event``, ``request``,
    ``metrics``, ``totals``.  This is the machine-readable form the
    explainer and the tests consume.
  * Chrome trace-event JSON (``write_chrome_trace``) — ``{"traceEvents":
    [...]}`` with complete ("X") span slices per request, instant ("i")
    point events, and counter ("C") tracks from the metrics samples.
    Loadable directly in Perfetto (https://ui.perfetto.dev) or
    ``chrome://tracing``; timestamps are microseconds of sim time.

``validate_jsonl`` / ``validate_trace_lines`` implement the schema check
without third-party dependencies (no jsonschema in the image): required
keys, value types, span-name vocabulary, per-request span-chain
contiguity (adjacent spans share their boundary timestamp).
"""
from __future__ import annotations

import json
import os
from typing import Iterable

from .recorder import SPAN_ORDER, FlightRecorder

TRACE_TYPES = ("meta", "decision", "event", "request", "metrics", "totals")


# ---------------------------------------------------------------------------
# JSONL
# ---------------------------------------------------------------------------

def trace_records(rec: FlightRecorder) -> list[dict]:
    """The full, deterministic record stream for one run: meta first,
    then decisions, point events, per-request records (arrival order),
    metrics samples, and the final totals rollup."""
    meta = {"type": "meta", "engine": rec.engine, "t_end": rec.t_end}
    meta.update(rec.meta)
    out = [meta]
    out.extend(rec.decisions)
    out.extend(rec.events)
    out.extend(sorted(rec.requests, key=lambda r: (r["t_arrival"],
                                                   r["rid"])))
    for row in rec.metrics.samples:
        m = {"type": "metrics"}
        m.update(row)
        out.append(m)
    out.append({"type": "totals", **rec.metrics.totals()})
    return out


def write_jsonl(rec: FlightRecorder, path: str) -> int:
    """Write the JSONL trace; returns the number of lines written."""
    records = trace_records(rec)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        for r in records:
            f.write(json.dumps(r, allow_nan=False) + "\n")
    return len(records)


def load_jsonl(path: str) -> list[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# ---------------------------------------------------------------------------
# Chrome trace-event JSON (Perfetto)
# ---------------------------------------------------------------------------

_US = 1e6          # sim seconds -> trace microseconds

# process ids grouping the trace tracks in the Perfetto UI
_PID_REQUESTS = 1
_PID_EVENTS = 2
_PID_DECISIONS = 3
_PID_METRICS = 4


def chrome_trace(rec: FlightRecorder) -> dict:
    ev: list[dict] = []
    for pid, name in ((_PID_REQUESTS, "requests"),
                      (_PID_EVENTS, "point events"),
                      (_PID_DECISIONS, "scaling decisions"),
                      (_PID_METRICS, "metrics")):
        ev.append({"ph": "M", "name": "process_name", "pid": pid,
                   "args": {"name": name}})
    for r in rec.requests:
        for s in r["spans"]:
            ev.append({"ph": "X", "name": s["name"], "cat": "request",
                       "ts": s["t0"] * _US, "dur": s["dur"] * _US,
                       "pid": _PID_REQUESTS, "tid": r["rid"],
                       "args": {"rid": r["rid"], "model": r["model"],
                                "priority": r["priority"]}})
    for e in rec.events:
        args = {k: v for k, v in e.items() if k not in ("type", "t",
                                                        "kind")}
        ev.append({"ph": "i", "name": e["kind"], "cat": "event",
                   "ts": e["t"] * _US, "pid": _PID_EVENTS, "tid": 0,
                   "s": "g", "args": args})
    for d in rec.decisions:
        ev.append({"ph": "i", "name": "fleet_plan", "cat": "decision",
                   "ts": d["t"] * _US, "pid": _PID_DECISIONS, "tid": 0,
                   "s": "g", "args": {"plan": d["plan"]}})
    for row in rec.metrics.samples:
        for k, v in row.items():
            if k == "t" or not isinstance(v, (int, float)):
                continue
            ev.append({"ph": "C", "name": k, "ts": row["t"] * _US,
                       "pid": _PID_METRICS, "args": {k: v}})
    return {"traceEvents": ev, "displayTimeUnit": "ms"}


def write_chrome_trace(rec: FlightRecorder, path: str) -> int:
    doc = chrome_trace(rec)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, allow_nan=False)
    return len(doc["traceEvents"])


# ---------------------------------------------------------------------------
# schema validation (hand-rolled; no external deps)
# ---------------------------------------------------------------------------

def _num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _check_request(r: dict, where: str, errors: list[str]):
    for key, pred in (("rid", _num), ("t_arrival", _num),
                      ("in_len", _num), ("out_len", _num),
                      ("spans", lambda v: isinstance(v, list)),
                      ("finished", lambda v: isinstance(v, bool))):
        if key not in r or not pred(r[key]):
            errors.append(f"{where}: request missing/invalid {key!r}")
            return
    prev_t1 = None
    prev_idx = -1
    for s in r["spans"]:
        if (not isinstance(s, dict) or s.get("name") not in SPAN_ORDER
                or not _num(s.get("t0")) or not _num(s.get("t1"))):
            errors.append(f"{where}: malformed span {s!r}")
            return
        if s["t1"] < s["t0"]:
            errors.append(f"{where}: span {s['name']} has t1 < t0")
        idx = SPAN_ORDER.index(s["name"])
        if idx <= prev_idx:
            errors.append(f"{where}: span {s['name']} out of "
                          f"lifecycle order")
        if prev_t1 is not None and s["t0"] != prev_t1:
            errors.append(f"{where}: span chain gap before {s['name']} "
                          f"({s['t0']} != {prev_t1})")
        prev_t1, prev_idx = s["t1"], idx


def validate_trace_lines(records: Iterable[dict]) -> list[str]:
    """Validate parsed JSONL records; returns a list of human-readable
    schema violations (empty = valid)."""
    errors: list[str] = []
    records = list(records)
    if not records:
        return ["empty trace"]
    if records[0].get("type") != "meta":
        errors.append("line 1: first record must be type 'meta'")
    for i, r in enumerate(records):
        where = f"line {i + 1}"
        if not isinstance(r, dict):
            errors.append(f"{where}: not a JSON object")
            continue
        t = r.get("type")
        if t not in TRACE_TYPES:
            errors.append(f"{where}: unknown type {t!r}")
            continue
        if t == "meta":
            if not isinstance(r.get("engine"), str) or not _num(
                    r.get("t_end")):
                errors.append(f"{where}: meta needs engine:str, "
                              f"t_end:number")
        elif t == "decision":
            if (not _num(r.get("t"))
                    or not isinstance(r.get("plan"), dict)
                    or not isinstance(r.get("observation"), dict)
                    or not isinstance(r.get("inputs"), dict)):
                errors.append(f"{where}: decision needs t:number + "
                              f"plan/observation/inputs dicts")
        elif t == "event":
            if not _num(r.get("t")) or not isinstance(r.get("kind"), str):
                errors.append(f"{where}: event needs t:number, kind:str")
        elif t == "request":
            _check_request(r, where, errors)
        elif t == "metrics":
            if not _num(r.get("t")):
                errors.append(f"{where}: metrics sample needs t:number")
            else:
                bad = [k for k, v in r.items()
                       if k != "type" and not _num(v)]
                if bad:
                    errors.append(f"{where}: non-numeric metrics {bad}")
    return errors


def validate_jsonl(path: str) -> list[str]:
    """Parse + validate a JSONL trace file; returns schema violations
    (empty = valid).  JSON parse errors are reported per line instead of
    raising."""
    records: list[dict] = []
    errors: list[str] = []
    with open(path) as f:
        for i, line in enumerate(f):
            if not line.strip():
                continue
            try:
                records.append(json.loads(line))
            except ValueError as e:
                errors.append(f"line {i + 1}: invalid JSON ({e})")
    return errors + validate_trace_lines(records)
