"""Scaling-decision explainer + SLO-violation attribution.

Consumes a flight-recorder JSONL trace (``obs.export.write_jsonl``) and
answers the two questions the paper's evaluation keeps asking:

  1. *Why did the planner scale pool P to N at time t?*  Every recorded
     decision carries the full Eq. 2-4 inputs (observed token rates,
     deflected rate, effective velocities, per-bucket decode needs,
     convertible loans, cost ranking), so the report reconstructs the
     arithmetic instead of guessing from aggregates.
  2. *Which stage made request R miss its TTFT SLO?*  Each violating
     request is attributed to its dominant TTFT-side span: queueing vs
     prefill vs KVC transfer vs decode backpressure.

Usage:

    PYTHONPATH=src python -m repro.obs.explain trace.jsonl
    PYTHONPATH=src python -m repro.obs.explain trace.jsonl --json
"""
from __future__ import annotations

import argparse
import json
import math
from typing import Optional

from .recorder import TTFT_STAGE_LABELS


# ---------------------------------------------------------------------------
# machine-readable report
# ---------------------------------------------------------------------------

def _model_inputs(inputs: dict, model: str) -> dict:
    """The Eq. 2-4 debug block for one model, from a policy
    ``last_debug`` payload (flat and coordinated policies both nest
    per-model blocks under "models")."""
    models = inputs.get("models")
    if isinstance(models, dict):
        return models.get(model, models.get("", {})) or {}
    return inputs


def scale_changes(records: list[dict]) -> list[dict]:
    """Every pool whose planned target differs from its observed
    provisioned count, with the decision's Eq. 2-4 inputs attached."""
    out = []
    for d in records:
        if d.get("type") != "decision":
            continue
        pools = d.get("observation", {}).get("pools", {})
        inputs = d.get("inputs", {})
        for pool, target in d.get("plan", {}).get("targets", {}).items():
            snap = pools.get(pool, {})
            cur = snap.get("count")
            if cur is None or target == cur:
                continue
            model = snap.get("model", "")
            out.append({
                "t": d["t"], "pool": pool, "model": model,
                "role": snap.get("role", ""),
                "from": cur, "to": target,
                "direction": "up" if target > cur else "down",
                "live": pool in d.get("plan", {}).get("live", []),
                "drain": pool in d.get("plan", {}).get("drain", []),
                "spills": d.get("plan", {}).get("spills", []),
                "inputs": _model_inputs(inputs, model),
            })
    return out


def ttft_violations(records: list[dict]) -> list[dict]:
    """Finished requests whose TTFT exceeds their SLO, attributed to the
    dominant TTFT-side span."""
    out = []
    for r in records:
        if r.get("type") != "request" or not r.get("finished"):
            continue
        ttft, slo = r.get("ttft"), r.get("ttft_slo")
        if ttft is None or slo is None or ttft <= slo:
            continue
        ttft_spans = {s["name"]: s["dur"] for s in r["spans"]
                      if s["name"] in TTFT_STAGE_LABELS}
        if not ttft_spans:
            continue
        dominant = max(ttft_spans, key=lambda k: ttft_spans[k])
        out.append({"rid": r["rid"], "model": r.get("model", ""),
                    "priority": r.get("priority"),
                    "t_arrival": r["t_arrival"],
                    "ttft": ttft, "slo": slo,
                    "dominant": dominant,
                    "stage": TTFT_STAGE_LABELS[dominant],
                    "spans": ttft_spans})
    return out


def explain(records: list[dict]) -> dict:
    meta = records[0] if records and records[0].get("type") == "meta" \
        else {}
    changes = scale_changes(records)
    violations = ttft_violations(records)
    by_stage: dict[str, int] = {}
    for v in violations:
        by_stage[v["stage"]] = by_stage.get(v["stage"], 0) + 1
    n_req = sum(1 for r in records if r.get("type") == "request")
    return {
        "engine": meta.get("engine", ""),
        "t_end": meta.get("t_end"),
        "n_decisions": sum(1 for r in records
                           if r.get("type") == "decision"),
        "n_requests": n_req,
        "scale_ups": [c for c in changes if c["direction"] == "up"],
        "scale_downs": [c for c in changes if c["direction"] == "down"],
        "violations": violations,
        "violations_by_stage": by_stage,
    }


# ---------------------------------------------------------------------------
# human-readable rendering
# ---------------------------------------------------------------------------

def _fmt(v, nd=1) -> str:
    if isinstance(v, float):
        return f"{v:.{nd}f}" if math.isfinite(v) else "nan"
    return str(v)


def _render_eq_inputs(inputs: dict, lines: list[str], indent="    "):
    eq2 = inputs.get("eq2")
    if eq2:
        lines.append(
            f"{indent}Eq.2  rate = in {_fmt(eq2.get('token_rate_in'))} - "
            f"deflected {_fmt(eq2.get('deflected_rate'))} = "
            f"{_fmt(eq2.get('rate'))} tok/s; "
            f"v_eff = min(v_prefill {_fmt(eq2.get('v_prefill'))}, "
            f"v_network {_fmt(eq2.get('v_network'))}) = "
            f"{_fmt(eq2.get('v_eff'))} -> i_p = {eq2.get('i_p')}")
    eq3 = inputs.get("eq3")
    if eq3:
        per_b = ", ".join(
            f"{b}:{_fmt(r)}" for b, r in sorted(
                (eq3.get("rate_by_bucket") or {}).items()))
        lines.append(f"{indent}Eq.3  per-bucket rates [{per_b}] over "
                     f"v_decode -> i_d = {eq3.get('i_d')}")
    eq4 = inputs.get("eq4")
    if eq4:
        lines.append(
            f"{indent}Eq.4  convertible loan {eq4.get('convertible')} "
            f"absorbs burst -> regular decoders = "
            f"{eq4.get('i_d_regular')}")
    if inputs.get("burst") is not None:
        lines.append(f"{indent}burst detector: "
                     f"{'ACTIVE' if inputs['burst'] else 'inactive'}")
    rank = inputs.get("prefill_rank") or inputs.get("rank")
    if rank:
        order = " > ".join(f"{name} ({_fmt(v, 2)} tok/s/$)"
                           for name, v in rank)
        lines.append(f"{indent}cost ranking (prefill): {order}")


def render_report(report: dict, max_rows: int = 10) -> str:
    lines = [f"# flight-recorder explainer "
             f"(engine={report['engine'] or '?'}, "
             f"t_end={_fmt(report.get('t_end') or 0.0)}s)",
             f"decisions recorded: {report['n_decisions']}; requests "
             f"traced: {report['n_requests']}", ""]
    ups = report["scale_ups"]
    lines.append(f"## scale-ups ({len(ups)})")
    for c in ups[:max_rows]:
        tag = " [live]" if c["live"] else ""
        lines.append(f"  t={_fmt(c['t'])}s pool={c['pool']} "
                     f"(model={c['model'] or 'default'}, role={c['role']})"
                     f": {c['from']} -> {c['to']}{tag}")
        _render_eq_inputs(c.get("inputs", {}), lines)
    if len(ups) > max_rows:
        lines.append(f"  ... {len(ups) - max_rows} more")
    downs = report["scale_downs"]
    lines.append("")
    lines.append(f"## scale-downs ({len(downs)})")
    for c in downs[:max_rows]:
        tag = " [drain]" if c["drain"] else ""
        lines.append(f"  t={_fmt(c['t'])}s pool={c['pool']}: "
                     f"{c['from']} -> {c['to']}{tag}")
    if len(downs) > max_rows:
        lines.append(f"  ... {len(downs) - max_rows} more")
    lines.append("")
    vio = report["violations"]
    lines.append(f"## TTFT SLO violations ({len(vio)})")
    for stage, n in sorted(report["violations_by_stage"].items(),
                           key=lambda kv: -kv[1]):
        lines.append(f"  dominant stage {stage}: {n}")
    for v in vio[:max_rows]:
        spans = ", ".join(f"{k}={_fmt(d, 3)}s"
                          for k, d in v["spans"].items())
        lines.append(f"  rid={v['rid']} ttft={_fmt(v['ttft'], 3)}s "
                     f"(slo {_fmt(v['slo'], 3)}s) <- {v['stage']} "
                     f"[{spans}]")
    if len(vio) > max_rows:
        lines.append(f"  ... {len(vio) - max_rows} more")
    return "\n".join(lines) + "\n"


def main(argv: Optional[list] = None) -> int:
    from .export import load_jsonl, validate_trace_lines
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="flight-recorder JSONL trace path")
    ap.add_argument("--json", action="store_true",
                    help="emit the machine-readable report instead of "
                         "the text rendering")
    ap.add_argument("--validate", action="store_true",
                    help="schema-validate the trace first; exit 1 on "
                         "violations")
    args = ap.parse_args(argv)
    records = load_jsonl(args.trace)
    if args.validate:
        errors = validate_trace_lines(records)
        if errors:
            for e in errors:
                print("schema:", e)
            return 1
    report = explain(records)
    if args.json:
        print(json.dumps(report, indent=2, allow_nan=False))
    else:
        print(render_report(report), end="")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
