"""Sharding helpers shared by models, serving, training and launch.

We use a MaxText-style *logical axis* scheme: model code annotates
activations/params with logical axis names ("batch", "seq", "model_heads",
"model_ff", "experts", "vocab", ...) and a rules table maps logical names to
physical mesh axes.  With no mesh active every annotation is a no-op, so the
same model code runs single-device (CPU smoke tests) and on the production
mesh (dry-run / multi-pod) unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical -> physical rules
# ---------------------------------------------------------------------------

# Default production rules.  "batch" maps to both the pod axis and the data
# axis (pod-major); tensor-parallel dims map to "model".
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),            # sequence unsharded by default (overridden for 500k)
    "ctx": ("data",),     # context parallelism for long-context decode caches
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "experts": ("model",),
    "expert_ff": (),      # FSDP axis for expert weights (launch overrides)
    "vocab": ("model",),
    "kv_lora": (),
    "state": (),
}

_tls = threading.local()


def _ctx():
    if not hasattr(_tls, "stack"):
        _tls.stack = []
    return _tls.stack


@contextlib.contextmanager
def axis_rules(rules: dict[str, tuple[str, ...]], mesh: Optional[Mesh] = None):
    """Activate a logical->physical mapping (and optionally a mesh)."""
    _ctx().append((dict(rules), mesh))
    try:
        yield
    finally:
        _ctx().pop()


def current_rules() -> Optional[dict[str, tuple[str, ...]]]:
    stack = _ctx()
    return stack[-1][0] if stack else None


def current_mesh() -> Optional[Mesh]:
    stack = _ctx()
    return stack[-1][1] if stack else None


def logical_to_pspec(axes: tuple[Optional[str], ...],
                     rules: Optional[dict] = None,
                     shape: Optional[tuple[int, ...]] = None,
                     mesh: Optional[Mesh] = None) -> P:
    """Map a tuple of logical axis names (or None) to a PartitionSpec.

    If `shape` and `mesh` are given, any mapping whose mesh-axis product does
    not divide the corresponding dim is dropped (e.g. 4 GQA KV heads cannot
    shard over a 16-way model axis -> replicate instead)."""
    rules = rules if rules is not None else (current_rules() or {})
    parts = []
    used: set[str] = set()
    for i, name in enumerate(axes):
        if name is None:
            parts.append(None)
            continue
        phys = tuple(a for a in rules.get(name, ()) if a not in used)
        if shape is not None and mesh is not None and phys:
            n = 1
            for a in phys:
                n *= mesh.shape[a]
            if n == 0 or shape[i] % n != 0:
                parts.append(None)
                continue
        used.update(phys)
        if len(phys) == 0:
            parts.append(None)
        elif len(phys) == 1:
            parts.append(phys[0])
        else:
            parts.append(phys)
    # trim trailing Nones (canonical form)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def compat_shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` across JAX versions: the top-level spelling (with
    ``check_vma``) only exists on newer releases; older ones ship it as
    ``jax.experimental.shard_map.shard_map`` with ``check_rep``."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:       # top-level spelling but pre-check_vma kwarg
            pass
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def shard(x, *axes: Optional[str]):
    """Annotate an activation with logical axes; no-op without rules/mesh."""
    rules = current_rules()
    mesh = current_mesh()
    if rules is None or mesh is None:
        return x
    spec = logical_to_pspec(axes, rules, shape=x.shape, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, spec)


def named_sharding(mesh: Mesh, *axes: Optional[str],
                   rules: Optional[dict] = None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_pspec(axes, rules or DEFAULT_RULES))


def pspec_tree_from_logical(logical_tree, rules: Optional[dict] = None,
                            shape_tree=None, mesh: Optional[Mesh] = None):
    """Map a pytree whose leaves are tuples of logical axis names to pspecs.

    With `shape_tree` (matching pytree of ShapeDtypeStructs/arrays) and
    `mesh`, indivisible mappings are dropped per-leaf."""
    rules = rules or DEFAULT_RULES
    is_leaf = lambda x: isinstance(x, tuple)  # noqa: E731
    if shape_tree is None:
        return jax.tree.map(lambda axes: logical_to_pspec(axes, rules),
                            logical_tree, is_leaf=is_leaf)
    return jax.tree.map(
        lambda axes, arr: logical_to_pspec(axes, rules, tuple(arr.shape), mesh),
        logical_tree, shape_tree, is_leaf=is_leaf)


def sharding_tree(logical_tree, shape_tree, mesh: Mesh,
                  rules: Optional[dict] = None):
    """NamedSharding pytree for jit in_shardings / device_put."""
    specs = pspec_tree_from_logical(logical_tree, rules or DEFAULT_RULES,
                                    shape_tree, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
