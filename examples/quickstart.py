"""Quickstart: the TokenScale pipeline in ~60 lines.

1. offline-profile Token Velocity for a (model, chip) pair,
2. plan the Convertible-Decoder restriction (chunk size, Eq.5-6),
3. serve a burst through a real JAX engine in convertible mode,
4. compare autoscaling policies on a bursty trace.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import (CHIPS, InstanceSpec, plan_convertible, profile)
from repro.models import init_params
from repro.serving import Engine, Request
from repro.sim import compare_policies

# -- 1. Token Velocity profile (the paper's Table II methodology) ----------
cfg_full = get_config("llama-3.1-8b")
inst = InstanceSpec(CHIPS["v5e"], tp=4)
prof = profile(cfg_full, inst)
print(f"V_P = {prof.v_prefill:,.0f} tok/s   V_N = {prof.v_network:,.0f} tok/s")
print("V_D per bucket:",
      {b: f"{v:,.0f}" for b, v in sorted(prof.v_decode.items())})

# -- 2. Convertible-Decoder planning (Eq. 5-6) ------------------------------
conv = plan_convertible(cfg_full, inst, expected_decode_batch=32,
                        avg_ctx=1200.0, burst_ratio=0.2, max_decoders=8)
print(f"\nconvertible: chunk={conv.chunk_size} tokens, "
      f"V_D^P'={conv.v_prefill:,.0f} tok/s, "
      f"reserved={conv.mem_reserved / 1e9:.2f} GB, pool={conv.pool_size}")

# -- 3. a real engine in convertible mode (CPU smoke model) -----------------
cfg = get_config("llama-3.1-8b", smoke=True)
params = init_params(cfg, jax.random.PRNGKey(0))
eng = Engine(cfg, params, num_slots=3, max_len=96, chunk_size=8)
rng = np.random.RandomState(0)
reqs = [Request(rid=i,
                prompt=rng.randint(0, cfg.vocab_size,
                                   size=(L,)).astype(np.int32),
                max_new_tokens=8)
        for i, L in enumerate([5, 7, 40])]    # 40 = the "burst" prompt
for r in reqs:
    eng.add_request(r)
eng.run_until_drained()
print("\nengine outputs:", {r.rid: r.output[:4] for r in reqs})

# -- 4. policies head-to-head on a bursty trace ------------------------------
print("\npolicy comparison (mixed trace, 60 s):")
for name, rep in compare_policies("mixed", duration=60.0, rps=8.0).items():
    print(f"  {name:12s} SLO={rep.slo_attainment() * 100:5.1f}%  "
          f"avg_gpus={rep.avg_gpus():.2f}")
