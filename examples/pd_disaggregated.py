"""PD-disaggregated serving on real JAX engines (paper Fig. 1 + 8).

Prefillers compute prompt KVC, the network stage ships it to decoders
(kvtransfer), the Router runs Alg. 1, bursts hit the Convertible Decoder,
and the Scaler reacts to live Observations — the whole TokenScale
architecture, executing actual models:

    PYTHONPATH=src python examples/pd_disaggregated.py
    PYTHONPATH=src python examples/pd_disaggregated.py --engine=events

After the real-engine run, the same PD architecture is cross-checked at
cluster scale on the analytic simulator; ``--engine`` picks the fluid or
the discrete-event implementation (DESIGN.md).
"""
import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.core import CHIPS, InstanceSpec, TokenScalePolicy, profile
from repro.models import init_params
from repro.serving import PDCluster, Request


def sim_crosscheck(engine: str):
    """The same PD-disaggregated scenario shape at cluster scale, on the
    analytic simulator (which engine is selectable), plus the
    heterogeneous variant the pool-centric control plane enables:
    a100-TP2 prefillers feeding h100-TP1 decoders via one declarative
    ExperimentSpec (core.fleet)."""
    from repro.sim.runner import hetero_demo_spec, run_policy, run_spec
    rep = run_policy("tokenscale", "azure_conv", duration=30.0, rps=6.0,
                     seed=0, engine=engine)
    print(f"\n[{engine} sim cross-check] {len(rep.requests)} requests, "
          f"SLO = {rep.slo_attainment() * 100:.1f}%, "
          f"p99 TTFT = {rep.percentile('ttft', 99) * 1e3:.0f} ms, "
          f"avg GPUs = {rep.avg_gpus():.2f}")
    het = run_spec(hetero_demo_spec(duration=30.0, rps=6.0, engine=engine))
    print(f"[{engine} hetero fleet: a100-TP2 prefill -> h100-TP1 decode] "
          f"SLO = {het.slo_attainment() * 100:.1f}%, "
          f"p99 TTFT = {het.percentile('ttft', 99) * 1e3:.0f} ms, "
          f"avg GPUs = {het.avg_gpus():.2f}")


def parse_engine(argv):
    """Validate --engine up front: the real-engine demo takes minutes, so
    a typo'd engine name must fail before it, not after."""
    from repro.sim.runner import get_engine
    engine = "fluid"
    for a in argv:
        if a.startswith("--engine="):
            engine = a.split("=", 1)[1]
    get_engine(engine)
    return engine


def main():
    engine = parse_engine(sys.argv[1:])
    cfg = get_config("llama-3.1-8b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prof = profile(get_config("llama-3.1-8b"), InstanceSpec(CHIPS["v5e"], 4))
    cl = PDCluster(cfg, params, TokenScalePolicy(prof, convertible=1),
                   n_prefillers=1, n_decoders=1, n_convertible=1,
                   max_len=96, chunk_size=16)

    rng = np.random.RandomState(0)
    # steady trickle ...
    reqs = [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       size=(int(rng.randint(5, 15)),)
                                       ).astype(np.int32),
                    max_new_tokens=8) for i in range(4)]
    # ... then a token burst (few requests, long prompts — Fig.6's T2 case)
    reqs += [Request(rid=100 + i,
                     prompt=rng.randint(0, cfg.vocab_size,
                                        size=(48,)).astype(np.int32),
                     max_new_tokens=8) for i in range(2)]
    for r in reqs:
        cl.submit(r)
    cl.run_until_drained()

    done = sum(1 for r in reqs if len(r.output) == r.max_new_tokens)
    print(f"completed {done}/{len(reqs)} requests")
    print(f"prefillers={len(cl.prefillers)} decoders={len(cl.decoders)} "
          f"convertibles={len(cl.convertibles)}")
    t = cl.transfers
    print(f"KVC transfers: {t.n_transfers}  "
          f"{t.total_bytes / 1e6:.2f} MB total, "
          f"{t.bytes_per_token():.0f} B/token")
    print(f"measured network velocity @50 GB/s ICI: "
          f"{cl.measured_network_velocity():,.0f} tok/s")
    for r in reqs[:3] + reqs[-1:]:
        print(f"  req{r.rid}: {r.output}")

    sim_crosscheck(engine)


if __name__ == "__main__":
    main()
