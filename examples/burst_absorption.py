"""Reproduce the paper's Fig. 10 experiment as a runnable scenario:
a 20x RPS burst hits at t=10 s; compare TTFT with and without the
Convertible Decoder (and against the three baseline autoscalers).

    PYTHONPATH=src python examples/burst_absorption.py
    PYTHONPATH=src python examples/burst_absorption.py --engine=events

``--engine=events`` runs the discrete-event simulator (exact per-request
tails) instead of the default dt-stepped fluid model; see DESIGN.md.
"""
import sys

import numpy as np

from repro.configs import get_config
from repro.core import (CHIPS, InstanceSpec, OutputPredictor,
                        plan_convertible, profile)
from repro.sim import step_trace
from repro.sim.runner import get_engine, make_policy


def run(policy_name: str, n_convertible: int, engine: str = "fluid"):
    cfg = get_config("llama-3.1-8b")
    inst = InstanceSpec(CHIPS["a100"], tp=1)
    prof = profile(cfg, inst)
    trace = step_trace(30.0, base_rps=1.0, burst_rps=20.0,
                       burst_start=10.0, burst_len=4.0, seed=3)
    # baseline thresholds calibrated from the actual trace's size stats
    policy = make_policy(policy_name, prof, n_convertible, trace=trace)
    conv = plan_convertible(cfg, inst, 32, 1200.0, 0.2, 8)
    cl = get_engine(engine)(cfg, inst, prof, policy, OutputPredictor(0.85, 3),
                            conv_cfg=conv, n_convertible=n_convertible)
    rep = cl.run(trace, 30.0)
    burst = [r.ttft * 1e3 for r in rep.requests
             if 10.0 <= r.src.t < 14.0 and r.t_first_token >= 0]
    return rep, float(np.percentile(burst, 99)) if burst else float("nan")


def main():
    engine = "fluid"
    for a in sys.argv[1:]:
        if a.startswith("--engine="):
            engine = a.split("=", 1)[1]
    print(f"[{engine} engine] 20x burst at t=10s for 4s; "
          "p99 TTFT of in-burst requests:")
    for name, n_conv in [("tokenscale", 1), ("tokenscale", 0),
                         ("blitzscale", 0), ("distserve", 0),
                         ("aibrix", 0)]:
        rep, p99 = run(name, n_conv, engine)
        label = f"{name}{' +convertible' if n_conv else ''}"
        print(f"  {label:26s} burst p99 TTFT = {p99:8.0f} ms   "
              f"SLO = {rep.slo_attainment() * 100:5.1f}%")
    print("\nThe convertible decoder absorbs what instance startup latency"
          " (5 s) cannot: the burst is over before a new prefiller boots.")


if __name__ == "__main__":
    main()
