"""End-to-end training driver: train a reduced model for a few hundred
steps on the synthetic packed corpus, checkpoint, restore, and keep going.

    PYTHONPATH=src python examples/train_small_model.py [--steps 200]
"""
import argparse
import tempfile

import jax

from repro.configs import get_config
from repro.training import (AdamWConfig, restore, save, train)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    opt_cfg = AdamWConfig(lr=3e-4, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 5))
    params, res = train(cfg, steps=args.steps // 2, batch=args.batch,
                        seq_len=args.seq_len, opt_cfg=opt_cfg,
                        log_every=20)
    print(f"[phase 1] loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}")

    with tempfile.TemporaryDirectory() as d:
        save(d, params, step=res.steps)
        params2, step = restore(d, like=params)
        print(f"[checkpoint] round-trip at step {step} OK")

    params3, res2 = train(cfg, steps=args.steps - args.steps // 2,
                          batch=args.batch, seq_len=args.seq_len,
                          opt_cfg=opt_cfg, params=params2, log_every=20)
    print(f"[phase 2] loss {res2.losses[0]:.3f} -> {res2.losses[-1]:.3f}")
    assert res2.losses[-1] < res.losses[0], "training did not improve"
    print("done: loss improved across checkpoint boundary")


if __name__ == "__main__":
    main()
