"""Regenerate the golden-policy regression fixtures (tests/golden/).

    PYTHONPATH=src python scripts/regen_golden.py            # rewrite
    PYTHONPATH=src python scripts/regen_golden.py --check    # CI dry run

Run the rewrite ONLY when a PR changes control-plane behavior on purpose;
the diff of the JSON files is part of the review surface.  ``--check``
regenerates in memory and verifies every committed fixture reproduces
byte-identically without touching the files (exit 1 + a diff summary
otherwise) — scripts/check.sh runs it so CI catches both accidental
control-plane drift and stale fixtures.
"""
import argparse
import json
import math
import os
import sys

REPO = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, REPO)     # the kvtiers fixture shares benchmarks.run

from repro.sim.runner import (hetero_demo_spec, run_policy,  # noqa: E402
                              run_spec)
from repro.sim.traces import DEFAULT_PRIORITY_MIX  # noqa: E402

HERE = os.path.join(REPO, "tests", "golden")


def regen_tokenscale_azure_conv():
    spec = {"trace": "azure_conv", "duration": 40.0, "rps": 8.0, "seed": 0,
            "policy": "tokenscale"}
    engines = {}
    for eng in ["fluid", "events"]:
        rep = run_policy(spec["policy"], spec["trace"],
                         duration=spec["duration"], rps=spec["rps"],
                         seed=spec["seed"], engine=eng)
        engines[eng] = rep.summary()     # one schema, shared with the test
    spec["engines"] = engines
    return "tokenscale_azure_conv.json", spec


def regen_priority_preemption():
    """Per-priority-class golden on the contended tails-bench fleet."""
    spec = {"trace": "burstgpt2", "model": "qwen25_32b", "tp": 2,
            "duration": 30.0, "rps": 8.0, "seed": 0, "policy": "tokenscale",
            "preemption": "evict-lowest", "max_instances": 2,
            "priority_mix": {str(k): v
                             for k, v in DEFAULT_PRIORITY_MIX.items()}}
    engines = {}
    for eng in ["fluid", "events"]:
        rep = run_policy(
            spec["policy"], spec["trace"], model=spec["model"],
            tp=spec["tp"], duration=spec["duration"], rps=spec["rps"],
            seed=spec["seed"], engine=eng, preemption=spec["preemption"],
            max_instances=spec["max_instances"],
            priority_mix=DEFAULT_PRIORITY_MIX)
        engines[eng] = {
            "n_requests": len(rep.requests),
            "n_preemptions": len(rep.preemptions),
            "classes": {str(c): rep.class_summary(c)
                        for c in rep.priority_classes()},
        }
    spec["engines"] = engines
    return "priority_preemption_burstgpt2.json", spec


def regen_hetero_fleet():
    """Heterogeneous-fleet golden: the canonical a100-TP2 prefill ->
    h100-TP1 decode spec through both engines.  The recorded experiment
    is the ExperimentSpec's own JSON form, so the regression test replays
    it through the declarative path (ExperimentSpec.from_dict ->
    run_spec)."""
    out = {"spec": None, "engines": {}}
    for eng in ["fluid", "events"]:
        spec = hetero_demo_spec(duration=30.0, rps=6.0, seed=0, engine=eng)
        rep = run_spec(spec)
        out["engines"][eng] = rep.summary()  # schema shared with the test
        if out["spec"] is None:
            d = spec.to_dict()
            d.pop("engine")          # per-engine; the test sets it
            out["spec"] = d
    return "hetero_fleet.json", out


def regen_kvtiers():
    """Tiered-KV golden on the kvtiers contention fleet (benchmarks.run
    .run_kvtiers_variant, so the fixture and the bench share one recipe):
    per-variant kv_summary through both engines, pinning the acceptance
    gradients — swap strictly beats recompute on preempted p99 TTFT/TPOT,
    prefix reuse yields a nonzero hit rate and a lower prefill-token
    load."""
    from benchmarks.run import (KVTIERS_BLOCK, KVTIERS_CFG, KVTIERS_SESSIONS,
                                KVTIERS_TRACE, KVTIERS_VARIANTS,
                                run_kvtiers_variant)
    out = {"trace": KVTIERS_TRACE, "block_size": KVTIERS_BLOCK,
           "session_prob": KVTIERS_SESSIONS,
           "priority_mix": {str(k): v
                            for k, v in DEFAULT_PRIORITY_MIX.items()},
           "fleet": dict(KVTIERS_CFG),
           "variants": {v: list(mv) for v, mv in KVTIERS_VARIANTS.items()},
           "engines": {}}
    for eng in ["fluid", "events"]:
        rows = {}
        for variant in KVTIERS_VARIANTS:
            rep = run_kvtiers_variant(variant, engine=eng)
            # non-finite percentiles (no preempted requests) become null so
            # the fixture stays strict RFC 8259 JSON
            kv = {k: (None if isinstance(v, float) and not math.isfinite(v)
                      else v)
                  for k, v in rep.kv_summary().items()}
            rows[variant] = {
                "n_requests": len(rep.requests),
                "n_preemptions": len(rep.preemptions),
                "prefill_tokens": sum(r.src.in_len - r.kv_hit_tokens
                                      for r in rep.requests),
                "kv": kv,                 # schema shared with the test
            }
        out["engines"][eng] = rows
    return "kvtiers_session.json", out


def regen_gateway():
    """KV-locality gateway golden on the hot-system-prompt session trace
    (benchmarks.run.run_gateway_variant, so the fixture and the bench
    share one recipe): per-variant summary + routing/replication/paging
    counters through both engines, pinning the acceptance gradient — the
    hashtrie gateway strictly beats owner-steering on p99 TTFT at
    equal-or-lower GPU count, with a strictly higher prefix hit rate."""
    from benchmarks.run import (GATEWAY_BLOCK, GATEWAY_CFG, GATEWAY_SESSIONS,
                                GATEWAY_SHARED, GATEWAY_TRACE,
                                GATEWAY_VARIANTS, run_gateway_variant)
    out = {"trace": GATEWAY_TRACE, "block_size": GATEWAY_BLOCK,
           "session_prob": GATEWAY_SESSIONS,
           "shared_prefix": dict(GATEWAY_SHARED),
           "fleet": dict(GATEWAY_CFG),
           "variants": {v: list(gv) for v, gv in GATEWAY_VARIANTS.items()},
           "engines": {}}
    for eng in ["fluid", "events"]:
        rows = {}
        for variant in GATEWAY_VARIANTS:
            rep = run_gateway_variant(variant, engine=eng)
            kv = {k: (None if isinstance(v, float) and not math.isfinite(v)
                      else v)
                  for k, v in rep.kv_summary().items()}
            rows[variant] = {
                "n_requests": len(rep.requests),
                "ttft_p99": rep.percentile("ttft", 99),
                "slo_attainment": rep.slo_attainment(),
                "avg_gpus": rep.avg_gpus(),
                "kv": kv,                 # schema shared with the test
                "gw": rep.gw_summary(),   # routing/replication/paging
            }
        out["engines"][eng] = rows
    return "gateway_locality.json", out


def regen_deflect():
    """Chunked-deflection golden on the saturated burst fleet
    (benchmarks.run.run_deflect_variant, so the fixture and the bench
    share one recipe): per-variant summary through both engines, pinning
    the acceptance gradient — chunked deflection beats wholesale
    conversion on p99 TTFT while resident p99 TPOT stays inside the
    TPOT SLO."""
    from benchmarks.run import DEFLECT_CFG, DEFLECT_VARIANTS, \
        run_deflect_variant
    duration = 30.0                       # reduced horizon for CI budget
    trace = "burstgpt1"
    out = {"trace": trace, "duration": duration,
           "fleet": dict(DEFLECT_CFG),
           "variants": dict(DEFLECT_VARIANTS),
           "engines": {}}
    out["fleet"]["duration"] = duration
    for eng in ["fluid", "events"]:
        rows = {}
        for variant in DEFLECT_VARIANTS:
            rep = run_deflect_variant(variant, trace, duration=duration,
                                      engine=eng)
            s = rep.summary()             # schema shared with the test
            s["tpot_p99"] = rep.percentile("tpot", 99)
            s["n_deflected"] = rep.n_deflected
            rows[variant] = s
        out["engines"][eng] = rows
    return "deflect_burst.json", out


def regen_pareto():
    """Coordinated-planner golden on the mixed-chip two-model pareto fleet
    (benchmarks.run.run_pareto_variant, so the fixture and the bench share
    one recipe): per-variant summary + cost accounting through both
    engines, pinning the acceptance gradient — the coordinated planner
    matches or beats the per-model baseline's SLO attainment at strictly
    lower cost_dollars."""
    from benchmarks.run import (PARETO_CFG, PARETO_VARIANTS,
                                run_pareto_variant)
    duration = 40.0                       # reduced horizon for CI budget
    trace = "burstgpt2"
    out = {"trace": trace, "duration": duration,
           "fleet": dict(PARETO_CFG),
           "variants": {v: list(pv) for v, pv in PARETO_VARIANTS.items()},
           "engines": {}}
    out["fleet"]["duration"] = duration
    for eng in ["fluid", "events"]:
        rows = {}
        for variant in PARETO_VARIANTS:
            rep = run_pareto_variant(variant, trace, duration=duration,
                                     engine=eng)
            s = rep.summary()             # schema shared with the test
            s["cost"] = rep.cost_summary()
            rows[variant] = s
        out["engines"][eng] = rows
    return "pareto_coord.json", out


def regen_chaos():
    """Chaos-recovery golden on the fault-injected burst fleet
    (benchmarks.run.run_chaos_variant, so the fixture and the bench share
    one recipe): per-variant summary + class-0 tails + fault/recovery
    odometers through both engines, pinning the acceptance gradient —
    recovery-on strictly beats recovery-off on class-0 SLO attainment
    AND p99 TTFT on both engines.  The gradient is asserted here too, so
    a regeneration that loses it fails instead of silently pinning a
    regression."""
    from benchmarks.run import (CHAOS_CFG, CHAOS_FAULTS, CHAOS_MIX,
                                CHAOS_TRACE, CHAOS_VARIANTS,
                                run_chaos_variant)
    out = {"trace": CHAOS_TRACE, "fleet": dict(CHAOS_CFG),
           "priority_mix": {str(k): v for k, v in CHAOS_MIX.items()},
           "faults": dict(CHAOS_FAULTS),
           "variants": dict(CHAOS_VARIANTS),
           "engines": {}}
    for eng in ["fluid", "events"]:
        rows = {}
        for variant in CHAOS_VARIANTS:
            rep = run_chaos_variant(variant, engine=eng)
            s = rep.summary()             # schema shared with the test
            s["class0"] = rep.class_summary(0)
            s["faults"] = rep.fault_summary()
            rows[variant] = s
        rec, blind = rows["recovery"], rows["norecovery"]
        assert rec["class0"]["slo_attainment"] \
            > blind["class0"]["slo_attainment"], (eng, "class-0 SLO")
        assert rec["ttft_p99"] < blind["ttft_p99"], (eng, "p99 TTFT")
        out["engines"][eng] = rows
    return "chaos_recovery.json", out


def render(spec: dict) -> str:
    return json.dumps(spec, indent=2) + "\n"


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="dry run: verify the committed fixtures reproduce "
                         "byte-identically; write nothing")
    args = ap.parse_args(argv)
    stale = []
    for name, spec in [regen_tokenscale_azure_conv(),
                       regen_priority_preemption(),
                       regen_hetero_fleet(),
                       regen_kvtiers(),
                       regen_gateway(),
                       regen_deflect(),
                       regen_pareto(),
                       regen_chaos()]:
        path = os.path.join(HERE, name)
        text = render(spec)
        if args.check:
            on_disk = open(path).read() if os.path.exists(path) else ""
            if on_disk == text:
                print("ok   ", os.path.normpath(path))
            else:
                stale.append(name)
                print("STALE", os.path.normpath(path))
        else:
            with open(path, "w") as f:
                f.write(text)
            print("wrote", os.path.normpath(path))
    if stale:
        sys.exit(f"golden fixtures do not reproduce byte-identically: "
                 f"{stale}; regenerate on purpose with "
                 f"scripts/regen_golden.py and review the diff")


if __name__ == "__main__":
    main()
