"""Regenerate the golden-policy regression fixtures (tests/golden/).

    PYTHONPATH=src python scripts/regen_golden.py

Run this ONLY when a PR changes control-plane behavior on purpose; the
diff of the JSON files is part of the review surface.
"""
import json
import os

from repro.sim.runner import hetero_demo_spec, run_policy, run_spec
from repro.sim.traces import DEFAULT_PRIORITY_MIX

HERE = os.path.join(os.path.dirname(__file__), "..", "tests", "golden")


def regen_tokenscale_azure_conv():
    spec = {"trace": "azure_conv", "duration": 40.0, "rps": 8.0, "seed": 0,
            "policy": "tokenscale"}
    engines = {}
    for eng in ["fluid", "events"]:
        rep = run_policy(spec["policy"], spec["trace"],
                         duration=spec["duration"], rps=spec["rps"],
                         seed=spec["seed"], engine=eng)
        engines[eng] = rep.summary()     # one schema, shared with the test
    spec["engines"] = engines
    return "tokenscale_azure_conv.json", spec


def regen_priority_preemption():
    """Per-priority-class golden on the contended tails-bench fleet."""
    spec = {"trace": "burstgpt2", "model": "qwen25_32b", "tp": 2,
            "duration": 30.0, "rps": 8.0, "seed": 0, "policy": "tokenscale",
            "preemption": "evict-lowest", "max_instances": 2,
            "priority_mix": {str(k): v
                             for k, v in DEFAULT_PRIORITY_MIX.items()}}
    engines = {}
    for eng in ["fluid", "events"]:
        rep = run_policy(
            spec["policy"], spec["trace"], model=spec["model"],
            tp=spec["tp"], duration=spec["duration"], rps=spec["rps"],
            seed=spec["seed"], engine=eng, preemption=spec["preemption"],
            max_instances=spec["max_instances"],
            priority_mix=DEFAULT_PRIORITY_MIX)
        engines[eng] = {
            "n_requests": len(rep.requests),
            "n_preemptions": len(rep.preemptions),
            "classes": {str(c): rep.class_summary(c)
                        for c in rep.priority_classes()},
        }
    spec["engines"] = engines
    return "priority_preemption_burstgpt2.json", spec


def regen_hetero_fleet():
    """Heterogeneous-fleet golden: the canonical a100-TP2 prefill ->
    h100-TP1 decode spec through both engines.  The recorded experiment
    is the ExperimentSpec's own JSON form, so the regression test replays
    it through the declarative path (ExperimentSpec.from_dict ->
    run_spec)."""
    out = {"spec": None, "engines": {}}
    for eng in ["fluid", "events"]:
        spec = hetero_demo_spec(duration=30.0, rps=6.0, seed=0, engine=eng)
        rep = run_spec(spec)
        out["engines"][eng] = rep.summary()  # schema shared with the test
        if out["spec"] is None:
            d = spec.to_dict()
            d.pop("engine")          # per-engine; the test sets it
            out["spec"] = d
    return "hetero_fleet.json", out


def main():
    for name, spec in [regen_tokenscale_azure_conv(),
                       regen_priority_preemption(),
                       regen_hetero_fleet()]:
        path = os.path.join(HERE, name)
        with open(path, "w") as f:
            json.dump(spec, f, indent=2)
            f.write("\n")
        print("wrote", os.path.normpath(path))


if __name__ == "__main__":
    main()
