#!/usr/bin/env bash
# One-command pre-push gate: tier-1 tests + a ~10 s benchmark smoke.
#
#   scripts/check.sh          # tier-1 (fast default: -m "not slow") + smoke
#   scripts/check.sh --slow   # additionally run the slow marker set
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ "${1:-}" == "--slow" ]]; then
    echo "== slow tests =="
    python -m pytest -x -q -m slow
fi

echo "== benchmark smoke (both sim engines + tails/preemption + hetero fleet + kvtiers + gateway + deflect + pareto + chaos rows) =="
python -m benchmarks.run --bench=smoke

echo "== golden fixtures reproduce byte-identically (regen dry run) =="
python scripts/regen_golden.py --check

# budget sized at ~3-4x the measured wall on a loaded dev box (~2.5-4 s):
# loose enough for slow CI runners, still far below what any O(batch)
# hot-path regression produces (the seed code took minutes on this row)
echo "== perfscale smoke (wall-clock budget gate; see benchmarks/perf.py) =="
python -m benchmarks.perf --smoke --budget 12.0

echo "== obs smoke (flight recorder: record + schema-validate + explain a burst trace on both engines) =="
obs_tmp="$(mktemp -d)"
trap 'rm -rf "$obs_tmp"' EXIT
python -m benchmarks.run --bench=obs --trace-out="$obs_tmp/trace.jsonl"
python -m repro.obs "$obs_tmp/trace-events.jsonl" --validate > /dev/null

echo "== obs overhead guard (telemetry-off tails replay within 3% of BENCH_sim.json) =="
python -m benchmarks.perf --guard

echo "OK: all checks passed"
