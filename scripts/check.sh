#!/usr/bin/env bash
# One-command pre-push gate: tier-1 tests + a ~10 s benchmark smoke.
#
#   scripts/check.sh          # tier-1 (fast default: -m "not slow") + smoke
#   scripts/check.sh --slow   # additionally run the slow marker set
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ "${1:-}" == "--slow" ]]; then
    echo "== slow tests =="
    python -m pytest -x -q -m slow
fi

echo "== benchmark smoke (both sim engines + tails/preemption + hetero fleet + kvtiers rows) =="
python -m benchmarks.run --bench=smoke

echo "== golden fixtures reproduce byte-identically (regen dry run) =="
python scripts/regen_golden.py --check

echo "OK: all checks passed"
