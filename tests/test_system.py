"""End-to-end behaviour tests: the TokenScale pipeline top to bottom.

profile -> plan convertible pool -> run the control plane against a bursty
trace (simulated cluster) AND against real Engines (CPU smoke model).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (CHIPS, InstanceSpec, OutputPredictor,
                        TokenScalePolicy, plan_convertible, profile)
from repro.models import init_params
from repro.serving import Engine, Request
from repro.sim import Cluster, get_trace


def test_full_pipeline_sim():
    """Offline profile feeds the policy; the policy + router + convertible
    pool serve a bursty trace with high SLO attainment."""
    cfg = get_config("llama31_8b")
    inst = InstanceSpec(CHIPS["a100"], tp=1)
    prof = profile(cfg, inst)
    conv = plan_convertible(cfg, inst, expected_decode_batch=32,
                            avg_ctx=1200.0, burst_ratio=0.2, max_decoders=8)
    assert conv.chunk_size > 0 and conv.pool_size >= 1
    policy = TokenScalePolicy(prof, convertible=1)
    cl = Cluster(cfg, inst, prof, policy,
                 predictor=OutputPredictor(0.85, 0),
                 conv_cfg=conv, n_convertible=1)
    trace = get_trace("azure_conv", duration_s=60.0, rps=8.0, seed=0)
    rep = cl.run(trace, 80.0)
    assert rep.slo_attainment() > 0.75
    assert rep.avg_gpus() < 32


@pytest.mark.slow
def test_full_pipeline_real_engines():
    """The same control-plane concepts on real JAX engines (smoke scale):
    a convertible decoder absorbs a prompt burst without corrupting any
    decode stream."""
    cfg = get_config("llama31_8b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    eng = Engine(cfg, params, num_slots=3, max_len=96, chunk_size=8)

    # steady decode load
    steady = [Request(rid=i,
                      prompt=rng.randint(0, cfg.vocab_size,
                                         size=(6,)).astype(np.int32),
                      max_new_tokens=8) for i in range(2)]
    for r in steady:
        eng.add_request(r)
    for _ in range(3):
        eng.step()
    # burst: a long prompt arrives; chunked prefill co-schedules with decode
    burst = Request(rid=99,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       size=(40,)).astype(np.int32),
                    max_new_tokens=8)
    eng.add_request(burst)
    eng.run_until_drained()
    assert len(burst.output) == 8
    for r in steady:
        assert len(r.output) == 8
    # decode streams match an isolated reference run
    from repro.models import greedy_generate
    import jax.numpy as jnp
    for r in steady + [burst]:
        ref = greedy_generate(cfg, params, jnp.asarray(r.prompt[None]),
                              jnp.array([len(r.prompt)], jnp.int32), 8)
        assert np.array_equal(np.array(r.output), np.asarray(ref[0])), r.rid
