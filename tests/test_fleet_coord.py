"""Coordinated cross-pool planner (tentpole PR 7).

Covers the fleet-native TokenScale generalization and its satellites:

  * golden replay of ``tests/golden/pareto_coord.json`` (both engines x
    permodel/coord variants on the mixed-chip two-model fleet);
  * the acceptance gradient — the coordinated planner Pareto-dominates
    the per-model baseline at event fidelity: SLO attainment at least as
    high at strictly lower ``cost_dollars``;
  * fluid-vs-events differential band (<= 15%) for the coordinated
    planner;
  * plan properties on a synthetic observation grid: targets never
    violate per-pool floors/caps, every planned pool drains on
    scale-down, spills only move idle convertibles between
    spill-compatible pools and never take the donor's last box;
  * cost-ranked placement prefers the cheaper chip at equal velocity;
  * drain-based scale-down never strands a resident request;
  * chunk-deflected prompts decode on their deflection target (on-box
    admission affinity), never re-entering bucket-aware balancing.
"""
import json
import os
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, REPO)          # the pareto fixture shares benchmarks.run

from benchmarks.run import run_pareto_variant  # noqa: E402
from repro.core.fleet import (CoordinatedTokenScalePolicy,  # noqa: E402
                              FLEET_POLICY_REGISTRY, FleetObservation,
                              FleetSpec, GatewayStats, PoolSnapshot,
                              PoolSpec, TraceRoute, build_fleet_policy)
from repro.core.velocity import (VelocityProfile,  # noqa: E402
                                 decode_tokens_per_dollar, profile_for)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN_PARETO = json.load(open(os.path.join(GOLDEN_DIR,
                                            "pareto_coord.json")))

REL_TOL = 0.15          # same band as tests/test_sim_differential.py
ABS_TTFT = 0.020
ABS_TPOT = 0.005


def _close(a, b, rel, abs_tol=0.0):
    return abs(a - b) <= max(rel * max(abs(a), abs(b)), abs_tol)


@pytest.fixture(scope="module")
def pareto_reports():
    g = GOLDEN_PARETO
    return {(eng, v): run_pareto_variant(v, g["trace"],
                                         duration=g["duration"], engine=eng)
            for eng in g["engines"] for v in g["variants"]}


# ---------------------------------------------------------------------------
# golden replay
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", list(GOLDEN_PARETO["engines"]))
@pytest.mark.parametrize("variant", list(GOLDEN_PARETO["variants"]))
def test_pareto_matches_golden(pareto_reports, engine, variant):
    rep = pareto_reports[(engine, variant)]
    want = GOLDEN_PARETO["engines"][engine][variant]
    got = rep.summary()                  # same schema as the regenerator
    got["cost"] = rep.cost_summary()
    assert set(got) == set(want), (engine, variant)
    assert got["n_requests"] == want["n_requests"]
    for key, expect in want.items():
        if key == "cost":
            assert got["cost"]["cost_dollars"] == \
                pytest.approx(expect["cost_dollars"], rel=1e-6)
            assert got["cost"]["pool_cost"] == \
                pytest.approx(expect["pool_cost"], rel=1e-6)
        else:
            assert got[key] == pytest.approx(expect, rel=1e-6), \
                (engine, variant, key)


# ---------------------------------------------------------------------------
# acceptance gradient: Pareto dominance at event fidelity
# ---------------------------------------------------------------------------

def test_coord_pareto_dominates_per_model(pareto_reports):
    """On the burst trace the coordinated planner serves at least the
    baseline's SLO attainment while billing strictly fewer dollars — the
    frontier point ``--bench=pareto`` plots (the ISSUE acceptance
    criterion)."""
    pm = pareto_reports[("events", "permodel")]
    co = pareto_reports[("events", "coord")]
    assert co.slo_attainment() >= pm.slo_attainment()
    assert co.cost_summary()["cost_dollars"] < \
        pm.cost_summary()["cost_dollars"]
    # the win comes from cost-ranked placement: the elastic l40s pool
    # actually absorbed decode scale-out (nonzero billing)
    assert co.cost_summary()["pool_cost"]["dec-ll-l40s"] > 0.0


def test_cost_accounting_consistency(pareto_reports):
    """The exact billing integral decomposes over pools and is bounded
    by pricing the peak fleet for the whole horizon."""
    for rep in pareto_reports.values():
        cs = rep.cost_summary()
        assert cs["cost_dollars"] == \
            pytest.approx(sum(cs["pool_cost"].values()))
        assert cs["cost_dollars"] > 0.0
        assert cs["cost_per_hour"] == \
            pytest.approx(cs["cost_dollars"] / rep.duration * 3600.0)


# ---------------------------------------------------------------------------
# fluid vs events differential band
# ---------------------------------------------------------------------------

def test_coord_differential_band(pareto_reports):
    """Both engines agree on the coordinated planner's aggregates within
    the established band (DESIGN.md "Coordinated planning fidelity").
    As in tests/test_sim_differential.py the fluid engine runs at half
    its default tick: it converges toward the event engine as dt -> 0
    and the default 25 ms leaves ~1.5 ticks of TTFT smearing."""
    g = GOLDEN_PARETO
    fl = run_pareto_variant("coord", g["trace"], duration=g["duration"],
                            engine="fluid", dt=0.0125)
    ev = pareto_reports[("events", "coord")]
    assert len(fl.requests) == len(ev.requests)      # same arrivals
    assert _close(fl.throughput(), ev.throughput(), REL_TOL, 0.1)
    assert _close(fl.mean("ttft"), ev.mean("ttft"), REL_TOL, ABS_TTFT)
    assert _close(fl.mean("tpot"), ev.mean("tpot"), REL_TOL, ABS_TPOT)
    assert _close(fl.cost_summary()["cost_dollars"],
                  ev.cost_summary()["cost_dollars"], REL_TOL)


# ---------------------------------------------------------------------------
# plan properties on a synthetic observation grid
# ---------------------------------------------------------------------------

def _grid_fleet() -> FleetSpec:
    return FleetSpec(
        pools=(
            PoolSpec("pre-ll", "prefill", "llama31_8b", "a100", 1, init=1),
            PoolSpec("dec-ll", "decode", "llama31_8b", "a100", 1, init=1),
            PoolSpec("dec-ll-l40s", "decode", "llama31_8b", "l40s", 1,
                     init=0, min=0, max=3),
            PoolSpec("conv-ll", "convertible", "llama31_8b", "a100", 2,
                     init=1),
            PoolSpec("pre-qw", "prefill", "qwen25_32b", "h100", 2, init=1),
            PoolSpec("dec-qw", "decode", "qwen25_32b", "h100", 2, init=1),
            PoolSpec("conv-qw", "convertible", "qwen25_32b", "a100", 2,
                     init=2),
        ),
        routes=(TraceRoute("llama31_8b", "burstgpt1"),
                TraceRoute("qwen25_32b", "azure_conv")))


def _profiles(fleet: FleetSpec):
    return {p.name: profile_for(p.model, p.chip, p.tp) for p in fleet.pools}


def _obs(fleet, t, rate_ll, rate_qw, burst_ll=False, conv_ll_idle=0,
         conv_qw_idle=2):
    snaps = {}
    for p in fleet.pools:
        idle = {"conv-ll": conv_ll_idle, "conv-qw": conv_qw_idle}.get(
            p.name, p.init)
        snaps[p.name] = PoolSnapshot(p.name, p.role, p.model, count=p.init,
                                     ready=p.init, idle=idle)
    gw = {
        "llama31_8b": GatewayStats(
            token_rate_in=rate_ll,
            token_rate_by_bucket={"S-M": rate_ll * 0.6, "M-M": rate_ll * 0.4},
            burst=burst_ll),
        "qwen25_32b": GatewayStats(
            token_rate_in=rate_qw,
            token_rate_by_bucket={"M-M": rate_qw}),
    }
    return FleetObservation(t=t, pools=snaps, gateway=gw)


@pytest.mark.parametrize("rate_ll", [0.0, 4e3, 4e4, 4e5, 4e6])
@pytest.mark.parametrize("burst", [False, True])
def test_plan_respects_floors_and_caps(rate_ll, burst):
    fleet = _grid_fleet()
    pol = CoordinatedTokenScalePolicy(fleet, _profiles(fleet))
    by_name = {p.name: p for p in fleet.pools}
    # t stride > down_delay so hysteresis never pins a stale current size
    for i, rate_qw in enumerate([0.0, 1e4]):
        plan = pol.plan(_obs(fleet, 100.0 * (i + 1), rate_ll, rate_qw,
                             burst_ll=burst))
        # every non-convertible pool is planned, and planned == drained
        planned = {n for n, p in by_name.items()
                   if p.role != "convertible"}
        assert set(plan.targets) == planned
        assert plan.drain == set(plan.targets)
        for name, tgt in plan.targets.items():
            spec = by_name[name]
            assert tgt >= spec.min, (name, tgt)
            if spec.max > 0:
                assert tgt <= spec.max, (name, tgt)


def test_spills_only_between_compatible_idle_convertibles():
    fleet = _grid_fleet()
    by_name = {p.name: p for p in fleet.pools}
    pol = CoordinatedTokenScalePolicy(fleet, _profiles(fleet))
    # llama bursting with no idle convertible; qwen calm with 2 idle ones
    plan = pol.plan(_obs(fleet, 100.0, 4e5, 0.0, burst_ll=True,
                         conv_ll_idle=0, conv_qw_idle=2))
    assert plan.spills, "burst + saturated convertible must borrow"
    for src, dst, n in plan.spills:
        a, b = by_name[src], by_name[dst]
        assert (a.chip, a.tp) == (b.chip, b.tp)      # spill-compatible
        assert {a.role, b.role} == {"convertible"}
        assert 0 < n <= a.init - 1                   # donor keeps one
    # no spill when the burster still has an idle convertible
    plan = pol.plan(_obs(fleet, 200.0, 4e5, 0.0, burst_ll=True,
                         conv_ll_idle=1, conv_qw_idle=2))
    assert not plan.spills
    # no spill when the donor is bursting too: nothing to borrow from
    obs = _obs(fleet, 300.0, 4e5, 1e4, burst_ll=True, conv_ll_idle=0)
    obs.gateway["qwen25_32b"].burst = True
    assert not pol.plan(obs).spills


def test_registry_resolves_coord():
    assert "tokenscale-coord" in FLEET_POLICY_REGISTRY
    fleet = _grid_fleet()
    pol = build_fleet_policy("tokenscale-coord", fleet, _profiles(fleet))
    assert isinstance(pol, CoordinatedTokenScalePolicy)
    with pytest.raises(ValueError, match="tokenscale-coord"):
        build_fleet_policy("nope", fleet, _profiles(fleet))


# ---------------------------------------------------------------------------
# cost-ranked placement
# ---------------------------------------------------------------------------

def test_rank_prefers_cheaper_chip_at_equal_velocity():
    """Two pools with identical profiled velocities but different chip
    pricing: the walk must land demand on the cheaper one first."""
    base = profile_for("llama31_8b", "a100", 1)
    cheap = VelocityProfile(model=base.model, chip="l40s", tp=1,
                            v_prefill=base.v_prefill,
                            v_network=base.v_network,
                            v_decode=dict(base.v_decode),
                            max_batch=dict(base.max_batch),
                            tpot=dict(base.tpot))
    fleet = FleetSpec(
        pools=(PoolSpec("pre", "prefill", "llama31_8b", "a100", 1),
               PoolSpec("dec-a100", "decode", "llama31_8b", "a100", 1),
               PoolSpec("dec-l40s", "decode", "llama31_8b", "l40s", 1)),
        routes=(TraceRoute("llama31_8b", "azure_conv"),))
    profiles = _profiles(fleet)
    profiles["dec-l40s"] = cheap          # same speed, cheaper chip
    pol = CoordinatedTokenScalePolicy(fleet, profiles)
    decode = [p for p in fleet.pools if p.role == "decode"]
    ranked = pol._rank(decode, decode_tokens_per_dollar)
    assert [p.name for p in ranked] == ["dec-l40s", "dec-a100"]
    # equal dollar-velocity keeps declaration order (stable sort)
    profiles["dec-l40s"] = profiles["dec-a100"]
    same = CoordinatedTokenScalePolicy(fleet, profiles)
    assert [p.name for p in same._rank(decode, decode_tokens_per_dollar)] \
        == ["dec-a100", "dec-l40s"]


# ---------------------------------------------------------------------------
# drain-based scale-down never strands a resident
# ---------------------------------------------------------------------------

def test_drain_never_strands_residents(monkeypatch):
    """Instances leave a draining pool only once idle: every request in
    the run finishes, none is evicted by a scale-down, and drains did
    actually happen (otherwise this asserts nothing)."""
    from repro.sim import instances as inst_mod
    drained, reaped = [], []
    orig = inst_mod.ClusterBase._scale_drain

    def spy(self, pool, want, t, startup):
        before = {id(i) for i in pool.instances if i.draining}
        alive = list(pool.instances)
        out = orig(self, pool, want, t, startup)
        for i in alive:
            if i.draining and id(i) not in before:
                drained.append(id(i))
            if not i.live and id(i) in before:
                reaped.append((id(i), i.idle))
        return out

    monkeypatch.setattr(inst_mod.ClusterBase, "_scale_drain", spy)
    rep = run_pareto_variant("coord", GOLDEN_PARETO["trace"],
                             duration=GOLDEN_PARETO["duration"],
                             engine="events")
    assert drained, "no drain ever planned — test config is dead"
    assert reaped, "no drained instance ever reaped"
    for _, was_idle in reaped:
        assert was_idle            # residents finished before removal
    assert all(r.t_finish >= 0 for r in rep.requests)
    assert all(r.n_evictions == 0 for r in rep.requests)


# ---------------------------------------------------------------------------
# deflection affinity: deflected prompts decode on their deflect target
# ---------------------------------------------------------------------------

def test_deflected_requests_decode_on_their_target(monkeypatch):
    """A chunk-deflected prompt's KV already lives on the deflection
    target, so decode admission is on-box: the admitting decoder is the
    recorded ``deflect_tgt``, not whatever bucket-aware balancing would
    pick."""
    from repro.sim import instances as inst_mod
    admitted = {}
    orig = inst_mod.Decoder.admit

    def spy(self, req, t):
        admitted[req.src.rid] = self
        return orig(self, req, t)

    monkeypatch.setattr(inst_mod.Decoder, "admit", spy)
    from benchmarks.run import run_deflect_variant
    rep = run_deflect_variant("chunked", "burstgpt1", duration=20.0,
                              engine="events")
    assert rep.n_deflected > 0
    pinned = [r for r in rep.requests if r.deflect_tgt is not None]
    assert pinned, "no deflected request kept a live target"
    for r in pinned:
        assert admitted[r.src.rid] is r.deflect_tgt, r.src.rid
