"""Serving engine: continuous batching + convertible chunked prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # JAX engine tests: minutes-scale on CPU

from repro.configs import get_config
from repro.models import greedy_generate, init_params
from repro.serving import Engine, Request


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama31_8b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in (7, 12, 5, 20)]
    refs = []
    for p in prompts:
        out = greedy_generate(cfg, params, jnp.asarray(p[None]),
                              jnp.array([len(p)], jnp.int32), 6)
        refs.append(np.asarray(out[0]))
    return cfg, params, prompts, refs


def _run(cfg, params, prompts, **kw):
    eng = Engine(cfg, params, **kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.add_request(r)
    eng.run_until_drained()
    return reqs


def test_continuous_batching_matches_greedy(setup):
    cfg, params, prompts, refs = setup
    reqs = _run(cfg, params, prompts, num_slots=4, max_len=64)
    for r, ref in zip(reqs, refs):
        assert np.array_equal(np.array(r.output), ref)


def test_queueing_with_fewer_slots(setup):
    cfg, params, prompts, refs = setup
    reqs = _run(cfg, params, prompts, num_slots=2, max_len=64)
    for r, ref in zip(reqs, refs):
        assert np.array_equal(np.array(r.output), ref)


def test_convertible_chunked_prefill_exact(setup):
    """Chunked prefill co-located with decode yields identical tokens —
    the restriction changes scheduling, never semantics (§III-D)."""
    cfg, params, prompts, refs = setup
    reqs = _run(cfg, params, prompts, num_slots=2, max_len=64, chunk_size=8)
    for r, ref in zip(reqs, refs):
        assert np.array_equal(np.array(r.output), ref)


def test_memory_accounting(setup):
    cfg, params, prompts, _ = setup
    eng = Engine(cfg, params, num_slots=4, max_len=64)
    assert eng.memory_tokens_used() == 0
    r = Request(rid=0, prompt=prompts[0], max_new_tokens=4)
    eng.add_request(r)
    assert eng.memory_tokens_used() == len(prompts[0])
    eng.run_until_drained()
    assert eng.memory_tokens_used() == 0
    assert eng.free_slots() == 4
