"""Golden-policy regression: catches silent control-plane regressions.

Three fixed-seed fixtures are replayed through both engines and must
match stored golden values within 5%:

  * ``tokenscale_azure_conv.json`` — a short azure_conv burst trace
    through the legacy single-pool shim; TokenScale must also keep its
    SLO lead over every baseline;
  * ``priority_preemption_burstgpt2.json`` — the contended tails-bench
    fleet (qwen25-32B TP2, 2-instance cap, evict-lowest) with per-
    priority-class attainment and p99 tails;
  * ``hetero_fleet.json`` — the canonical heterogeneous fleet (a100-TP2
    prefill -> h100-TP1 decode), replayed through the declarative path
    (``ExperimentSpec.from_dict`` -> ``run_spec``);
  * ``kvtiers_session.json`` — the tiered-KV contention fleet (paged
    blocks + host-DRAM offload + prefix reuse) across the none/recompute/
    swap/swap+prefix variants, pinning the acceptance gradients: swap
    strictly beats recompute on preempted p99 TTFT/TPOT, prefix reuse
    yields a nonzero hit rate and a lower prefill-token load.

If a future PR changes control-plane behavior on purpose, regenerate all
with ``PYTHONPATH=src python scripts/regen_golden.py`` and review the
JSON diff (CI runs ``regen_golden.py --check`` to catch stale fixtures).
"""
import json
import math
import os

import pytest

from repro.core import ExperimentSpec
from repro.sim.runner import run_policy, run_spec
from repro.sim.traces import DEFAULT_PRIORITY_MIX

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN = json.load(open(os.path.join(GOLDEN_DIR,
                                     "tokenscale_azure_conv.json")))
GOLDEN_PRIO = json.load(open(os.path.join(
    GOLDEN_DIR, "priority_preemption_burstgpt2.json")))
GOLDEN_HET = json.load(open(os.path.join(GOLDEN_DIR, "hetero_fleet.json")))
GOLDEN_KV = json.load(open(os.path.join(GOLDEN_DIR,
                                        "kvtiers_session.json")))
BASELINES = ["distserve", "aibrix", "blitzscale"]


def _run(policy, engine="fluid"):
    return run_policy(policy, GOLDEN["trace"], duration=GOLDEN["duration"],
                      rps=GOLDEN["rps"], seed=GOLDEN["seed"], engine=engine)


@pytest.fixture(scope="module")
def tokenscale_reports():
    return {eng: _run("tokenscale", eng) for eng in GOLDEN["engines"]}


def test_tokenscale_beats_every_baseline(tokenscale_reports):
    ts = tokenscale_reports["fluid"].slo_attainment()
    for name in BASELINES:
        base = _run(name).slo_attainment()
        assert ts >= base, (name, ts, base)


@pytest.mark.parametrize("engine", list(GOLDEN["engines"]))
def test_metrics_match_golden(tokenscale_reports, engine):
    # SimReport.summary() is the same schema the regenerator writes, so
    # the fixture and this check can never drift apart
    got = tokenscale_reports[engine].summary()
    want = GOLDEN["engines"][engine]
    assert set(got) == set(want), engine
    for key, expect in want.items():
        assert got[key] == pytest.approx(expect, rel=0.05), \
            (engine, key, got[key], expect)


# ---------------------------------------------------------------------------
# per-priority-class golden (preemption on the contended fleet)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def priority_reports():
    g = GOLDEN_PRIO
    # the run is driven entirely by the recorded spec, including the mix
    mix = {int(k): v for k, v in g["priority_mix"].items()}
    assert mix == DEFAULT_PRIORITY_MIX, \
        "golden priority_mix stale — regenerate (scripts/regen_golden.py)"
    return {eng: run_policy(
        g["policy"], g["trace"], model=g["model"], tp=g["tp"],
        duration=g["duration"], rps=g["rps"], seed=g["seed"], engine=eng,
        preemption=g["preemption"], max_instances=g["max_instances"],
        priority_mix=mix)
        for eng in g["engines"]}


@pytest.mark.parametrize("engine", list(GOLDEN_PRIO["engines"]))
def test_priority_metrics_match_golden(priority_reports, engine):
    rep = priority_reports[engine]
    want = GOLDEN_PRIO["engines"][engine]
    assert len(rep.requests) == want["n_requests"]
    assert len(rep.preemptions) == pytest.approx(want["n_preemptions"],
                                                 rel=0.05)
    for cls, w in want["classes"].items():
        got = rep.class_summary(int(cls))   # same schema as the regenerator
        assert set(got) == set(w), (engine, cls)
        assert got["n"] == w["n"], (engine, cls)
        for key in ("slo_attainment", "ttft_p99", "tpot_p99"):
            assert got[key] == pytest.approx(w[key], rel=0.05), \
                (engine, cls, key)


@pytest.mark.parametrize("engine", list(GOLDEN_PRIO["engines"]))
def test_priority_gradient_holds(priority_reports, engine):
    """Higher classes see no worse p99 TTFT than lower ones — the whole
    point of priority-ordered admission + eviction."""
    rep = priority_reports[engine]
    p99 = [rep.percentile("ttft", 99, priority=c)
           for c in rep.priority_classes()]
    assert p99 == sorted(p99)


# ---------------------------------------------------------------------------
# heterogeneous-fleet golden (declarative ExperimentSpec path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", list(GOLDEN_HET["engines"]))
def test_hetero_fleet_matches_golden(engine):
    """The recorded spec JSON replays through ExperimentSpec.from_dict ->
    run_spec, so this regression also covers the declarative pipeline."""
    spec = ExperimentSpec.from_dict({**GOLDEN_HET["spec"],
                                     "engine": engine})
    got = run_spec(spec).summary()
    want = GOLDEN_HET["engines"][engine]
    assert set(got) == set(want), engine
    for key, expect in want.items():
        assert got[key] == pytest.approx(expect, rel=0.05), \
            (engine, key, got[key], expect)


# ---------------------------------------------------------------------------
# tiered-KV golden (paged blocks + host-DRAM offload + prefix reuse)
# ---------------------------------------------------------------------------

def _run_kvtiers(variant, engine):
    """Replay one kvtiers cell entirely from the recorded fixture (same
    recipe as benchmarks.run.run_kvtiers_variant and the regenerator)."""
    g = GOLDEN_KV
    mode, prefix = g["variants"][variant]
    mix = {int(k): v for k, v in g["priority_mix"].items()}
    assert mix == DEFAULT_PRIORITY_MIX, \
        "kvtiers golden priority_mix stale — regenerate"
    return run_policy("tokenscale", g["trace"], engine=engine,
                      preemption=mode, priority_mix=mix,
                      session_prob=g["session_prob"],
                      block_size=g["block_size"], prefix_cache=prefix,
                      **g["fleet"])


@pytest.fixture(scope="module")
def kvtiers_reports():
    return {(eng, v): _run_kvtiers(v, eng)
            for eng in GOLDEN_KV["engines"]
            for v in GOLDEN_KV["variants"]}


@pytest.mark.parametrize("engine", list(GOLDEN_KV["engines"]))
@pytest.mark.parametrize("variant", list(GOLDEN_KV["variants"]))
def test_kvtiers_matches_golden(kvtiers_reports, engine, variant):
    rep = kvtiers_reports[(engine, variant)]
    want = GOLDEN_KV["engines"][engine][variant]
    assert len(rep.requests) == want["n_requests"]
    assert len(rep.preemptions) == pytest.approx(want["n_preemptions"],
                                                 rel=0.05)
    got_pf = sum(r.src.in_len - r.kv_hit_tokens for r in rep.requests)
    assert got_pf == pytest.approx(want["prefill_tokens"], rel=0.05)
    got = rep.kv_summary()       # same schema as the regenerator
    assert set(got) == set(want["kv"]), (engine, variant)
    for key, expect in want["kv"].items():
        if expect is None:       # non-finite stored as null (strict JSON)
            assert math.isnan(got[key]), (engine, variant, key)
        else:
            assert got[key] == pytest.approx(expect, rel=0.05), \
                (engine, variant, key, got[key], expect)


def test_kvtiers_swap_beats_recompute(kvtiers_reports):
    """The tentpole acceptance gradient: a real swap to the host-DRAM tier
    strictly improves the preempted-request p99 TTFT and TPOT over a full
    KV recomputation on the memory-tight fleet.  Judged at event fidelity
    — the engine the kvtiers bench runs — because the fluid engine smears
    exactly the tails this gradient lives in (DESIGN.md §1); the fluid
    numbers are still value-pinned by test_kvtiers_matches_golden.  The
    TPOT gradient (stall charged to decode time) survives the smearing,
    so it is asserted on both engines."""
    rec = kvtiers_reports[("events", "recompute")].kv_summary()
    swp = kvtiers_reports[("events", "swap")].kv_summary()
    assert swp["swap_outs"] > 0
    assert swp["preempted_ttft_p99"] < rec["preempted_ttft_p99"]
    assert swp["preempted_tpot_p99"] < rec["preempted_tpot_p99"]
    for engine in GOLDEN_KV["engines"]:
        rec = kvtiers_reports[(engine, "recompute")].kv_summary()
        swp = kvtiers_reports[(engine, "swap")].kv_summary()
        assert swp["preempted_tpot_p99"] < rec["preempted_tpot_p99"], engine


@pytest.mark.parametrize("engine", list(GOLDEN_KV["engines"]))
def test_kvtiers_prefix_reuse_cuts_prefill_load(kvtiers_reports, engine):
    """Prefix reuse on the session trace: nonzero hit rate, strictly
    fewer prefill tokens than the identical fleet without the cache."""
    base = kvtiers_reports[(engine, "swap")]
    pfx = kvtiers_reports[(engine, "swap+prefix")]
    assert pfx.kv["prefix_hit_rate"] > 0
    assert base.kv["prefix_hit_rate"] == 0

    def load(rep):
        return sum(r.src.in_len - r.kv_hit_tokens for r in rep.requests)

    assert load(pfx) < load(base)
