"""Golden-policy regression: catches silent control-plane regressions.

A short fixed-seed azure_conv burst trace is replayed through all four
policies; TokenScale must keep its SLO lead over every baseline, and its
emitted ``SimReport`` metrics must match stored golden values within 5%
(both engines).  If a future PR changes control-plane behavior on purpose,
regenerate tests/golden/tokenscale_azure_conv.json with the snippet in
that file's git history (the values are produced by ``run_policy`` with
the parameters recorded in the file).
"""
import json
import os

import pytest

from repro.sim.runner import run_policy

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "tokenscale_azure_conv.json")
GOLDEN = json.load(open(GOLDEN_PATH))
BASELINES = ["distserve", "aibrix", "blitzscale"]


def _run(policy, engine="fluid"):
    return run_policy(policy, GOLDEN["trace"], duration=GOLDEN["duration"],
                      rps=GOLDEN["rps"], seed=GOLDEN["seed"], engine=engine)


@pytest.fixture(scope="module")
def tokenscale_reports():
    return {eng: _run("tokenscale", eng) for eng in GOLDEN["engines"]}


def test_tokenscale_beats_every_baseline(tokenscale_reports):
    ts = tokenscale_reports["fluid"].slo_attainment()
    for name in BASELINES:
        base = _run(name).slo_attainment()
        assert ts >= base, (name, ts, base)


@pytest.mark.parametrize("engine", list(GOLDEN["engines"]))
def test_metrics_match_golden(tokenscale_reports, engine):
    rep = tokenscale_reports[engine]
    want = GOLDEN["engines"][engine]
    got = {
        "n_requests": len(rep.requests),
        "slo_attainment": rep.slo_attainment(),
        "ttft_attainment": rep.ttft_attainment(),
        "tpot_attainment": rep.tpot_attainment(),
        "avg_gpus": rep.avg_gpus(),
        "throughput": rep.throughput(),
        "ttft_mean": rep.mean("ttft"),
        "tpot_mean": rep.mean("tpot"),
        "ttft_p99": rep.percentile("ttft", 99),
    }
    for key, expect in want.items():
        actual = got[key]
        assert actual == pytest.approx(expect, rel=0.05), \
            (engine, key, actual, expect)
