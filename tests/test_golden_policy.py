"""Golden-policy regression: catches silent control-plane regressions.

Three fixed-seed fixtures are replayed through both engines and must
match stored golden values within 5%:

  * ``tokenscale_azure_conv.json`` — a short azure_conv burst trace
    through the legacy single-pool shim; TokenScale must also keep its
    SLO lead over every baseline;
  * ``priority_preemption_burstgpt2.json`` — the contended tails-bench
    fleet (qwen25-32B TP2, 2-instance cap, evict-lowest) with per-
    priority-class attainment and p99 tails;
  * ``hetero_fleet.json`` — the canonical heterogeneous fleet (a100-TP2
    prefill -> h100-TP1 decode), replayed through the declarative path
    (``ExperimentSpec.from_dict`` -> ``run_spec``).

If a future PR changes control-plane behavior on purpose, regenerate all
with ``PYTHONPATH=src python scripts/regen_golden.py`` and review the
JSON diff.
"""
import json
import os

import pytest

from repro.core import ExperimentSpec
from repro.sim.runner import run_policy, run_spec
from repro.sim.traces import DEFAULT_PRIORITY_MIX

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN = json.load(open(os.path.join(GOLDEN_DIR,
                                     "tokenscale_azure_conv.json")))
GOLDEN_PRIO = json.load(open(os.path.join(
    GOLDEN_DIR, "priority_preemption_burstgpt2.json")))
GOLDEN_HET = json.load(open(os.path.join(GOLDEN_DIR, "hetero_fleet.json")))
BASELINES = ["distserve", "aibrix", "blitzscale"]


def _run(policy, engine="fluid"):
    return run_policy(policy, GOLDEN["trace"], duration=GOLDEN["duration"],
                      rps=GOLDEN["rps"], seed=GOLDEN["seed"], engine=engine)


@pytest.fixture(scope="module")
def tokenscale_reports():
    return {eng: _run("tokenscale", eng) for eng in GOLDEN["engines"]}


def test_tokenscale_beats_every_baseline(tokenscale_reports):
    ts = tokenscale_reports["fluid"].slo_attainment()
    for name in BASELINES:
        base = _run(name).slo_attainment()
        assert ts >= base, (name, ts, base)


@pytest.mark.parametrize("engine", list(GOLDEN["engines"]))
def test_metrics_match_golden(tokenscale_reports, engine):
    # SimReport.summary() is the same schema the regenerator writes, so
    # the fixture and this check can never drift apart
    got = tokenscale_reports[engine].summary()
    want = GOLDEN["engines"][engine]
    assert set(got) == set(want), engine
    for key, expect in want.items():
        assert got[key] == pytest.approx(expect, rel=0.05), \
            (engine, key, got[key], expect)


# ---------------------------------------------------------------------------
# per-priority-class golden (preemption on the contended fleet)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def priority_reports():
    g = GOLDEN_PRIO
    # the run is driven entirely by the recorded spec, including the mix
    mix = {int(k): v for k, v in g["priority_mix"].items()}
    assert mix == DEFAULT_PRIORITY_MIX, \
        "golden priority_mix stale — regenerate (scripts/regen_golden.py)"
    return {eng: run_policy(
        g["policy"], g["trace"], model=g["model"], tp=g["tp"],
        duration=g["duration"], rps=g["rps"], seed=g["seed"], engine=eng,
        preemption=g["preemption"], max_instances=g["max_instances"],
        priority_mix=mix)
        for eng in g["engines"]}


@pytest.mark.parametrize("engine", list(GOLDEN_PRIO["engines"]))
def test_priority_metrics_match_golden(priority_reports, engine):
    rep = priority_reports[engine]
    want = GOLDEN_PRIO["engines"][engine]
    assert len(rep.requests) == want["n_requests"]
    assert len(rep.preemptions) == pytest.approx(want["n_preemptions"],
                                                 rel=0.05)
    for cls, w in want["classes"].items():
        got = rep.class_summary(int(cls))   # same schema as the regenerator
        assert set(got) == set(w), (engine, cls)
        assert got["n"] == w["n"], (engine, cls)
        for key in ("slo_attainment", "ttft_p99", "tpot_p99"):
            assert got[key] == pytest.approx(w[key], rel=0.05), \
                (engine, cls, key)


@pytest.mark.parametrize("engine", list(GOLDEN_PRIO["engines"]))
def test_priority_gradient_holds(priority_reports, engine):
    """Higher classes see no worse p99 TTFT than lower ones — the whole
    point of priority-ordered admission + eviction."""
    rep = priority_reports[engine]
    p99 = [rep.percentile("ttft", 99, priority=c)
           for c in rep.priority_classes()]
    assert p99 == sorted(p99)


# ---------------------------------------------------------------------------
# heterogeneous-fleet golden (declarative ExperimentSpec path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", list(GOLDEN_HET["engines"]))
def test_hetero_fleet_matches_golden(engine):
    """The recorded spec JSON replays through ExperimentSpec.from_dict ->
    run_spec, so this regression also covers the declarative pipeline."""
    spec = ExperimentSpec.from_dict({**GOLDEN_HET["spec"],
                                     "engine": engine})
    got = run_spec(spec).summary()
    want = GOLDEN_HET["engines"][engine]
    assert set(got) == set(want), engine
    for key, expect in want.items():
        assert got[key] == pytest.approx(expect, rel=0.05), \
            (engine, key, got[key], expect)
