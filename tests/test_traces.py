"""Trace generator coverage (§V Workload Generation, Table II ranges)."""
import numpy as np

from repro.sim.traces import (TRACES, burst_phases, generate, generate_mixed,
                              get_trace, step_trace, varying_rate_trace)


def test_same_seed_byte_identical():
    a = generate(TRACES["azure_conv"], 120.0, 8.0, seed=7)
    b = generate(TRACES["azure_conv"], 120.0, 8.0, seed=7)
    assert [(r.rid, r.t, r.in_len, r.out_len) for r in a] \
        == [(r.rid, r.t, r.in_len, r.out_len) for r in b]
    c = generate(TRACES["azure_conv"], 120.0, 8.0, seed=8)
    assert [(r.t, r.in_len) for r in a] != [(r.t, r.in_len) for r in c]


def test_mixed_same_seed_byte_identical():
    a = generate_mixed(60.0, 8.0, seed=3)
    b = generate_mixed(60.0, 8.0, seed=3)
    assert [(r.rid, r.t, r.in_len, r.out_len) for r in a] \
        == [(r.rid, r.t, r.in_len, r.out_len) for r in b]


def test_burst_duty_cycle_near_paper():
    """§I: the system is in a burst ~47% of operational time (ON 2.3 s /
    OFF 2.6 s)."""
    spec = TRACES["azure_conv"]
    rng = np.random.RandomState(0)
    phases = burst_phases(spec, 20000.0, rng)
    on = sum(e - s for s, e, m in phases if m > 1.0)
    total = max(e for _, e, _ in phases)
    duty = on / total
    expect = spec.burst_on_mean / (spec.burst_on_mean + spec.burst_off_mean)
    assert abs(expect - 0.47) < 0.01          # the constants encode §I
    assert abs(duty - expect) < 0.05          # and the generator realizes it


def test_lengths_clipped_to_table2_ranges():
    for name in TRACES:
        trace = generate(TRACES[name], 200.0, 10.0, seed=1)
        assert trace, name
        for r in trace:
            assert 32 <= r.in_len <= 8192, (name, r.in_len)
            assert 16 <= r.out_len <= 640, (name, r.out_len)


def test_every_named_trace_generates():
    for name in list(TRACES) + ["mixed"]:
        trace = get_trace(name, 60.0, 8.0, seed=0)
        assert len(trace) > 50, name
        assert all(trace[i].t <= trace[i + 1].t
                   for i in range(len(trace) - 1)), name
        # rids are consecutive for the composite traces
        if name == "mixed":
            assert [r.rid for r in trace] == list(range(len(trace)))


def test_rate_calibration_all_traces():
    """Long-run average arrival rate lands near the requested rps despite
    the ON/OFF modulation."""
    for name in TRACES:
        trace = generate(TRACES[name], 400.0, 10.0, seed=0)
        rps = len(trace) / 400.0
        assert 4.0 < rps < 25.0, (name, rps)


def test_step_and_varying_rate_traces():
    step = step_trace(20.0, base_rps=2.0, burst_rps=20.0, burst_start=5.0,
                      burst_len=5.0, seed=0)
    in_burst = sum(1 for r in step if 5.0 <= r.t < 10.0)
    outside = sum(1 for r in step if r.t < 5.0 or r.t >= 10.0)
    assert in_burst > outside            # 10x rate for 1/3 of the horizon
    seg = varying_rate_trace([(10.0, 2.0), (10.0, 20.0)], seed=0)
    assert sum(1 for r in seg if r.t >= 10.0) \
        > 2 * sum(1 for r in seg if r.t < 10.0)
    assert [r.rid for r in seg] == list(range(len(seg)))
