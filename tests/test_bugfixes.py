"""Unit tests pinning the PR-2 satellite bugfixes.

One test (cluster) per fixed defect:
  1. event/fluid first-token stamping (TTFT no longer one iteration
     optimistic) — see also the tightened causality checks in
     tests/test_sim_differential.py;
  2. scale-down hysteresis timer resets when the pending target changes;
  3. fluid decode tick clamps ``generated`` at ``out_len`` and prorates
     the final tick;
  4. burst detector normalizes both windows over their observed horizon,
     so an opening spike (t < 1 s) is detectable;
  5. ``_gpu_count`` bills exactly the provisioned fleet (booting + ready).

PR-6 satellite bugfixes:
  6. ``burst_ratio_of_trace`` vectorization (cumulative sums) matches a
     brute-force reference, and second *i* is excluded from its own
     baseline window;
  7. the fluid engine's snapshot cadence uses an integer tick counter —
     ``int(t / dt)`` on float-accumulated ``t`` drifts (rows 7/8/9 ticks
     apart instead of exactly 8);
  8. ``default_convertible_plan`` derives §IV-C2's pool sizing from the
     experiment's actual instance cap instead of a hardcoded 8;
  9. ``OutputPredictor`` mispredicts are uniform over the two *other*
     output classes (the module docstring used to promise neighbor bias
     it never implemented).
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (CHIPS, InstanceSpec, OutputPredictor,
                        TokenScalePolicy, default_convertible_plan, profile,
                        single_pool_fleet)
from repro.core.autoscaler import _DownHysteresis
from repro.core.convertible import burst_ratio_of_trace
from repro.core.router import BurstDetector
from repro.sim.cluster import Cluster
from repro.sim.events import EventCluster
from repro.sim.instances import Decoder, ModelCost, SimRequest
from repro.sim.runner import build_fleet, run_policy
from repro.sim.traces import TraceRequest


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama31_8b")


@pytest.fixture(scope="module")
def inst():
    return InstanceSpec(CHIPS["a100"], 1)


@pytest.fixture(scope="module")
def prof(cfg, inst):
    return profile(cfg, inst)


# ---------------------------------------------------------------------------
# 1. first-token stamping
# ---------------------------------------------------------------------------

def test_admit_does_not_stamp_first_token(cfg, inst):
    d = Decoder(1, inst, ModelCost.of(cfg), 0.0)
    r = SimRequest(TraceRequest(0, 0.0, 128, 32))
    d.admit(r, 1.0)
    assert r.t_decode_start == 1.0
    assert r.t_first_token < 0          # token 1 needs an iteration first


def test_event_first_token_lands_after_one_iteration():
    rep = run_policy("tokenscale", "azure_conv", duration=25.0, rps=6.0,
                     seed=0, engine="events")
    done = [r for r in rep.requests if r.t_first_token >= 0]
    assert done
    for r in done:
        # strictly after admission: the first decode iteration takes time
        assert r.t_first_token > r.t_decode_start
        assert r.t_first_token > r.t_kv_ready


def test_fluid_first_token_lands_after_admission():
    rep = run_policy("tokenscale", "azure_conv", duration=25.0, rps=6.0,
                     seed=0, engine="fluid")
    done = [r for r in rep.requests if r.t_first_token >= 0]
    assert done
    for r in done:
        assert r.t_first_token > r.t_decode_start


def test_readmission_preserves_first_stamps(cfg, inst):
    """Preemption round-trips must not reset decode-start/KV-ready."""
    d = Decoder(1, inst, ModelCost.of(cfg), 0.0)
    r = SimRequest(TraceRequest(0, 0.0, 128, 32))
    d.admit(r, 1.0)
    d.active.remove(r)
    d.admit(r, 9.0)
    assert r.t_decode_start == 1.0


# ---------------------------------------------------------------------------
# 2. down-scale hysteresis
# ---------------------------------------------------------------------------

def test_hysteresis_deeper_target_restarts_countdown():
    h = _DownHysteresis(delay=5.0)
    assert h.apply("d", 5, 5, 0.0) == 5
    assert h.apply("d", 5, 4, 1.0) == 5       # pending 4 since t=1
    assert h.apply("d", 5, 2, 3.0) == 5       # deeper target: timer resets
    # pre-fix the countdown inherited t=1 and released the deeper target at
    # t=6; it must persist from t=3 for the full delay
    assert h.apply("d", 5, 2, 7.0) == 5
    assert h.apply("d", 5, 2, 8.5) == 2       # 8.5 - 3.0 >= 5


def test_hysteresis_scale_up_clears_stale_pending():
    h = _DownHysteresis(delay=5.0)
    h.apply("d", 5, 3, 0.0)
    assert h.apply("d", 5, 6, 1.0) == 6       # scale-up clears the timer
    assert h.apply("d", 6, 3, 2.0) == 6       # fresh countdown from t=2
    assert h.apply("d", 6, 3, 6.9) == 6
    assert h.apply("d", 6, 3, 7.1) == 3


def test_hysteresis_shallower_target_also_restarts():
    h = _DownHysteresis(delay=5.0)
    assert h.apply("p", 5, 2, 0.0) == 5
    assert h.apply("p", 5, 4, 4.0) == 5       # target changed: reset at t=4
    assert h.apply("p", 5, 4, 8.0) == 5       # 8 - 4 < 5
    assert h.apply("p", 5, 4, 9.5) == 4


# ---------------------------------------------------------------------------
# 3. fluid decode tick: clamp + prorate
# ---------------------------------------------------------------------------

def test_fluid_tick_clamps_generated_and_prorates_final_tick(cfg, inst):
    d = Decoder(1, inst, ModelCost.of(cfg), 0.0)
    r = SimRequest(TraceRequest(0, 0.0, 128, 16))
    d.admit(r, 0.0)
    it = d.iter_time()
    dt = it * 20.0                      # one tick covers 20 tokens of work
    finished = d.tick(0.0, dt)
    assert finished == [r]
    assert r.generated == 16.0          # clamped, no overshoot
    # only 16/20 of the tick was spent decoding
    assert r.decode_time == pytest.approx(16.0 * it)
    assert r.t_finish == pytest.approx(0.8 * dt)
    assert r.tpot == pytest.approx(it, rel=1e-6)


def test_fluid_mem_never_counts_overshoot(cfg, inst):
    d = Decoder(1, inst, ModelCost.of(cfg), 0.0)
    r1 = SimRequest(TraceRequest(0, 0.0, 128, 16))
    r2 = SimRequest(TraceRequest(1, 0.0, 128, 640))
    d.admit(r1, 0.0)
    d.admit(r2, 0.0)
    it = d.iter_time()
    d.tick(0.0, it * 100.0)             # r1 finishes long before tick end
    c = d.cost
    # r2 is the only resident; its generated tokens are clamped at <= 100
    assert d.mem_used() <= (r2.src.in_len + 100.0) * c.kv_tok \
        + c.state_fix + 1e-6


# ---------------------------------------------------------------------------
# 4. burst detector: opening-spike normalization
# ---------------------------------------------------------------------------

def test_burst_detected_in_first_second():
    """A spike against a brief baseline, all inside the first second."""
    b = BurstDetector()                 # short 1 s / long 60 s / factor 1.5
    b.observe(0.05, 100.0)              # baseline trickle
    for i in range(6):                  # 6 requests slam in at ~0.45-0.5 s
        b.observe(0.45 + 0.01 * i, 200.0)
    short, long = b.rates(0.5)
    assert short > 1.5 * long
    assert b.is_burst(0.5)


def test_steady_traffic_is_not_a_burst():
    b = BurstDetector()
    for i in range(120):
        b.observe(0.5 * i, 100.0)
    assert not b.is_burst(59.9)


def test_opening_trickle_is_not_a_burst():
    """Cold-start traffic with no rate contrast must not be flagged: not a
    lone first arrival, and not a steady opening stream (the symmetric-
    elapsed normalization degenerated to always-burst for t < ~0.67 s)."""
    b = BurstDetector()
    b.observe(0.3, 500.0)
    assert not b.is_burst(0.3)          # single arrival
    b2 = BurstDetector()
    for i in range(8):                  # steady 10 rps from t=0
        b2.observe(0.1 * (i + 1), 100.0)
    assert not b2.is_burst(0.8)


def test_burst_definition_unchanged_at_steady_state():
    """Past the long horizon the fix is a no-op: spikes still register,
    constant load still does not."""
    b = BurstDetector()
    for i in range(600):
        b.observe(0.1 * i, 10.0)        # 100 tok/s for 60 s
    b.observe(60.05, 500.0)             # 5x spike in the short window
    assert b.is_burst(60.1)


# ---------------------------------------------------------------------------
# 5. GPU-second billing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine_cls", [Cluster, EventCluster])
def test_gpu_seconds_integrate_fleet_exactly(engine_cls, cfg, inst, prof):
    cl = engine_cls(cfg, inst, prof, TokenScalePolicy(prof, convertible=0),
                    n_convertible=0, init_prefillers=1, init_decoders=1)
    rep = cl.run([], duration=10.0)
    # no traffic -> the fleet stays at 1 prefiller + 1 decoder throughout
    assert rep.gpu_seconds == pytest.approx(2 * inst.gpus * 10.0, rel=0.01)


def test_booting_instances_are_billed(cfg, inst, prof):
    cl = Cluster(cfg, inst, prof, TokenScalePolicy(prof, convertible=0),
                 n_convertible=0, init_prefillers=1, init_decoders=1)
    # fleet mutation goes through the pool's live list (the decoders
    # property is a flattened read-only view)
    pool = cl.pools["decode"].instances
    pool.append(cl._new_decoder(ready_t=5.0))          # boots until t=5
    assert cl._gpu_count(0.0) == 3 * inst.gpus         # booting is billed
    pool.pop()
    assert cl._gpu_count(0.0) == 2 * inst.gpus         # removed is not


# ---------------------------------------------------------------------------
# 6. burst-ratio vectorization + baseline self-exclusion
# ---------------------------------------------------------------------------

def _burst_ratio_reference(arrivals, window_s=60.0, factor=1.0):
    """Straight-from-the-docstring brute force: per-second token sums, the
    baseline for second i = mean of seconds [i-window, i) — exclusive."""
    arrivals = sorted(arrivals, key=lambda a: a[0])
    if not arrivals:
        return 0.0
    t_end = max(a[0] for a in arrivals) + 1e-9
    n = int(t_end) + 1
    per_sec = [0.0] * n
    for t, tok in arrivals:
        per_sec[min(int(t), n - 1)] += tok
    burst = 0.0
    for i in range(n):
        lo = max(0, i - int(window_s))
        if i - lo == 0:
            continue                     # no history -> never burst
        avg = sum(per_sec[lo:i]) / (i - lo)
        burst += max(per_sec[i] - factor * avg, 0.0)
    return burst / max(sum(tok for _, tok in arrivals), 1e-9)


@pytest.mark.parametrize("window_s,factor", [(60.0, 1.0), (10.0, 1.5),
                                             (5.0, 0.8)])
def test_burst_ratio_matches_brute_force(window_s, factor):
    """Synthetic spike trace: a steady trickle with two 10x spike seconds
    plus a randomized tail, through every (window, factor) shape."""
    rng = np.random.RandomState(7)
    arrivals = [(float(s) + 0.5, 100.0) for s in range(120)]
    arrivals += [(30.2, 1000.0), (30.7, 1000.0), (75.4, 2000.0)]
    arrivals += [(float(rng.uniform(0, 120)), float(rng.randint(10, 500)))
                 for _ in range(200)]
    got = burst_ratio_of_trace(arrivals, window_s, factor)
    want = _burst_ratio_reference(arrivals, window_s, factor)
    assert got == pytest.approx(want, rel=1e-9)
    assert got > 0


def test_burst_ratio_excludes_self_from_baseline():
    """One 10x spike over a window it would otherwise dominate: with the
    spike polluting its own baseline (the historical inclusive window)
    the measured burst fraction collapses; excluded, the spike counts
    (almost) fully."""
    arrivals = [(float(s) + 0.5, 100.0) for s in range(10)]
    arrivals.append((9.6, 1000.0))       # second 9 jumps to 1100 tokens
    ratio = burst_ratio_of_trace(arrivals, window_s=60.0, factor=1.0)
    # baseline for second 9 is the 9 clean seconds (100 tok/s): burst
    # tokens = 1100 - 100 = 1000 of 2000 total
    assert ratio == pytest.approx(1000.0 / 2000.0)


def test_burst_ratio_first_second_never_bursts():
    assert burst_ratio_of_trace([(0.2, 5000.0)]) == 0.0
    assert burst_ratio_of_trace([]) == 0.0


# ---------------------------------------------------------------------------
# 7. fluid snapshot cadence (integer tick counter, not int(t / dt))
# ---------------------------------------------------------------------------

def test_fluid_snapshot_cadence_is_exact():
    """duration 30 + 30 s drain at dt=25 ms is 2401 ticks (the
    accumulated clock lands at 59.999… < 60, so the loop takes one final
    boundary tick); the 0.2 s cadence is exactly every 8th tick -> exactly
    301 rows, uniformly spaced.  Deriving the tick index as
    ``int(t / dt)`` on the float-accumulated clock stalls within the
    first few ticks and yields rows spaced 1/7/8/9 ticks apart."""
    rep = run_policy("tokenscale", "azure_conv", duration=30.0, rps=2.0,
                     seed=0, engine="fluid")
    assert len(rep.timeline) == 301
    ts = [s["t"] for s in rep.timeline]
    diffs = [b - a for a, b in zip(ts, ts[1:])]
    assert max(diffs) - min(diffs) < 1e-9
    assert diffs[0] == pytest.approx(0.2)


# ---------------------------------------------------------------------------
# 8. convertible pool sizing follows the experiment's instance cap
# ---------------------------------------------------------------------------

def test_default_plan_pool_size_scales_with_max_decoders(cfg, inst, prof):
    import math
    for cap in (8, 40, 64):
        plan = default_convertible_plan(cfg, inst, prof, max_decoders=cap)
        assert plan.pool_size == max(math.ceil(cap * 0.2), 1)
    # the historical 8 stays the default for direct callers
    assert default_convertible_plan(cfg, inst, prof).pool_size == \
        default_convertible_plan(cfg, inst, prof, max_decoders=8).pool_size


def test_build_fleet_plumbs_max_decoders():
    import math
    fs = single_pool_fleet("llama31_8b", "a100", 1, n_convertible=1)
    conv_of = lambda fleet: fleet.role_pools("convertible")[0].conv_cfg
    assert conv_of(build_fleet(fs)).pool_size == 2              # legacy 8
    assert conv_of(build_fleet(fs, max_decoders=40)).pool_size \
        == math.ceil(40 * 0.2)
    # Eq. 5-6 restriction itself is cap-independent — only the §IV-C2
    # sizing moves
    assert conv_of(build_fleet(fs)).chunk_size \
        == conv_of(build_fleet(fs, max_decoders=40)).chunk_size


# ---------------------------------------------------------------------------
# 9. predictor mispredicts: uniform over the two other output classes
# ---------------------------------------------------------------------------

def test_predictor_mispredicts_cover_both_other_classes():
    """At accuracy 0 every prediction is wrong: for a true S-output
    request both M and L must appear (the docstring used to promise
    neighbor-only errors that were never implemented — the uniform error
    model is the documented behavior now), in roughly equal shares, and
    never the true class itself."""
    p = OutputPredictor(accuracy=0.0, seed=0)
    preds = [p.predict_bucket(100, 50) for _ in range(600)]  # true S-S
    outs = [b.split("-")[1] for b in preds]
    assert set(outs) == {"M", "L"}
    assert all(b.split("-")[0] == "S" for b in preds)  # input class kept
    assert 0.4 < outs.count("L") / len(outs) < 0.6     # uniform, not biased


def test_predictor_accuracy_is_calibrated():
    p = OutputPredictor(accuracy=0.85, seed=1)
    for i in range(4000):
        p.predict_bucket(100 + i % 900, 30 + i % 400)
    assert p.measured_accuracy == pytest.approx(0.85, abs=0.02)
