"""Unit tests pinning the PR-2 satellite bugfixes.

One test (cluster) per fixed defect:
  1. event/fluid first-token stamping (TTFT no longer one iteration
     optimistic) — see also the tightened causality checks in
     tests/test_sim_differential.py;
  2. scale-down hysteresis timer resets when the pending target changes;
  3. fluid decode tick clamps ``generated`` at ``out_len`` and prorates
     the final tick;
  4. burst detector normalizes both windows over their observed horizon,
     so an opening spike (t < 1 s) is detectable;
  5. ``_gpu_count`` bills exactly the provisioned fleet (booting + ready).
"""
import pytest

from repro.configs import get_config
from repro.core import CHIPS, InstanceSpec, TokenScalePolicy, profile
from repro.core.autoscaler import _DownHysteresis
from repro.core.router import BurstDetector
from repro.sim.cluster import Cluster
from repro.sim.events import EventCluster
from repro.sim.instances import Decoder, ModelCost, SimRequest
from repro.sim.runner import run_policy
from repro.sim.traces import TraceRequest


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama31_8b")


@pytest.fixture(scope="module")
def inst():
    return InstanceSpec(CHIPS["a100"], 1)


@pytest.fixture(scope="module")
def prof(cfg, inst):
    return profile(cfg, inst)


# ---------------------------------------------------------------------------
# 1. first-token stamping
# ---------------------------------------------------------------------------

def test_admit_does_not_stamp_first_token(cfg, inst):
    d = Decoder(1, inst, ModelCost.of(cfg), 0.0)
    r = SimRequest(TraceRequest(0, 0.0, 128, 32))
    d.admit(r, 1.0)
    assert r.t_decode_start == 1.0
    assert r.t_first_token < 0          # token 1 needs an iteration first


def test_event_first_token_lands_after_one_iteration():
    rep = run_policy("tokenscale", "azure_conv", duration=25.0, rps=6.0,
                     seed=0, engine="events")
    done = [r for r in rep.requests if r.t_first_token >= 0]
    assert done
    for r in done:
        # strictly after admission: the first decode iteration takes time
        assert r.t_first_token > r.t_decode_start
        assert r.t_first_token > r.t_kv_ready


def test_fluid_first_token_lands_after_admission():
    rep = run_policy("tokenscale", "azure_conv", duration=25.0, rps=6.0,
                     seed=0, engine="fluid")
    done = [r for r in rep.requests if r.t_first_token >= 0]
    assert done
    for r in done:
        assert r.t_first_token > r.t_decode_start


def test_readmission_preserves_first_stamps(cfg, inst):
    """Preemption round-trips must not reset decode-start/KV-ready."""
    d = Decoder(1, inst, ModelCost.of(cfg), 0.0)
    r = SimRequest(TraceRequest(0, 0.0, 128, 32))
    d.admit(r, 1.0)
    d.active.remove(r)
    d.admit(r, 9.0)
    assert r.t_decode_start == 1.0


# ---------------------------------------------------------------------------
# 2. down-scale hysteresis
# ---------------------------------------------------------------------------

def test_hysteresis_deeper_target_restarts_countdown():
    h = _DownHysteresis(delay=5.0)
    assert h.apply("d", 5, 5, 0.0) == 5
    assert h.apply("d", 5, 4, 1.0) == 5       # pending 4 since t=1
    assert h.apply("d", 5, 2, 3.0) == 5       # deeper target: timer resets
    # pre-fix the countdown inherited t=1 and released the deeper target at
    # t=6; it must persist from t=3 for the full delay
    assert h.apply("d", 5, 2, 7.0) == 5
    assert h.apply("d", 5, 2, 8.5) == 2       # 8.5 - 3.0 >= 5


def test_hysteresis_scale_up_clears_stale_pending():
    h = _DownHysteresis(delay=5.0)
    h.apply("d", 5, 3, 0.0)
    assert h.apply("d", 5, 6, 1.0) == 6       # scale-up clears the timer
    assert h.apply("d", 6, 3, 2.0) == 6       # fresh countdown from t=2
    assert h.apply("d", 6, 3, 6.9) == 6
    assert h.apply("d", 6, 3, 7.1) == 3


def test_hysteresis_shallower_target_also_restarts():
    h = _DownHysteresis(delay=5.0)
    assert h.apply("p", 5, 2, 0.0) == 5
    assert h.apply("p", 5, 4, 4.0) == 5       # target changed: reset at t=4
    assert h.apply("p", 5, 4, 8.0) == 5       # 8 - 4 < 5
    assert h.apply("p", 5, 4, 9.5) == 4


# ---------------------------------------------------------------------------
# 3. fluid decode tick: clamp + prorate
# ---------------------------------------------------------------------------

def test_fluid_tick_clamps_generated_and_prorates_final_tick(cfg, inst):
    d = Decoder(1, inst, ModelCost.of(cfg), 0.0)
    r = SimRequest(TraceRequest(0, 0.0, 128, 16))
    d.admit(r, 0.0)
    it = d.iter_time()
    dt = it * 20.0                      # one tick covers 20 tokens of work
    finished = d.tick(0.0, dt)
    assert finished == [r]
    assert r.generated == 16.0          # clamped, no overshoot
    # only 16/20 of the tick was spent decoding
    assert r.decode_time == pytest.approx(16.0 * it)
    assert r.t_finish == pytest.approx(0.8 * dt)
    assert r.tpot == pytest.approx(it, rel=1e-6)


def test_fluid_mem_never_counts_overshoot(cfg, inst):
    d = Decoder(1, inst, ModelCost.of(cfg), 0.0)
    r1 = SimRequest(TraceRequest(0, 0.0, 128, 16))
    r2 = SimRequest(TraceRequest(1, 0.0, 128, 640))
    d.admit(r1, 0.0)
    d.admit(r2, 0.0)
    it = d.iter_time()
    d.tick(0.0, it * 100.0)             # r1 finishes long before tick end
    c = d.cost
    # r2 is the only resident; its generated tokens are clamped at <= 100
    assert d.mem_used() <= (r2.src.in_len + 100.0) * c.kv_tok \
        + c.state_fix + 1e-6


# ---------------------------------------------------------------------------
# 4. burst detector: opening-spike normalization
# ---------------------------------------------------------------------------

def test_burst_detected_in_first_second():
    """A spike against a brief baseline, all inside the first second."""
    b = BurstDetector()                 # short 1 s / long 60 s / factor 1.5
    b.observe(0.05, 100.0)              # baseline trickle
    for i in range(6):                  # 6 requests slam in at ~0.45-0.5 s
        b.observe(0.45 + 0.01 * i, 200.0)
    short, long = b.rates(0.5)
    assert short > 1.5 * long
    assert b.is_burst(0.5)


def test_steady_traffic_is_not_a_burst():
    b = BurstDetector()
    for i in range(120):
        b.observe(0.5 * i, 100.0)
    assert not b.is_burst(59.9)


def test_opening_trickle_is_not_a_burst():
    """Cold-start traffic with no rate contrast must not be flagged: not a
    lone first arrival, and not a steady opening stream (the symmetric-
    elapsed normalization degenerated to always-burst for t < ~0.67 s)."""
    b = BurstDetector()
    b.observe(0.3, 500.0)
    assert not b.is_burst(0.3)          # single arrival
    b2 = BurstDetector()
    for i in range(8):                  # steady 10 rps from t=0
        b2.observe(0.1 * (i + 1), 100.0)
    assert not b2.is_burst(0.8)


def test_burst_definition_unchanged_at_steady_state():
    """Past the long horizon the fix is a no-op: spikes still register,
    constant load still does not."""
    b = BurstDetector()
    for i in range(600):
        b.observe(0.1 * i, 10.0)        # 100 tok/s for 60 s
    b.observe(60.05, 500.0)             # 5x spike in the short window
    assert b.is_burst(60.1)


# ---------------------------------------------------------------------------
# 5. GPU-second billing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine_cls", [Cluster, EventCluster])
def test_gpu_seconds_integrate_fleet_exactly(engine_cls, cfg, inst, prof):
    cl = engine_cls(cfg, inst, prof, TokenScalePolicy(prof, convertible=0),
                    n_convertible=0, init_prefillers=1, init_decoders=1)
    rep = cl.run([], duration=10.0)
    # no traffic -> the fleet stays at 1 prefiller + 1 decoder throughout
    assert rep.gpu_seconds == pytest.approx(2 * inst.gpus * 10.0, rel=0.01)


def test_booting_instances_are_billed(cfg, inst, prof):
    cl = Cluster(cfg, inst, prof, TokenScalePolicy(prof, convertible=0),
                 n_convertible=0, init_prefillers=1, init_decoders=1)
    # fleet mutation goes through the pool's live list (the decoders
    # property is a flattened read-only view)
    pool = cl.pools["decode"].instances
    pool.append(cl._new_decoder(ready_t=5.0))          # boots until t=5
    assert cl._gpu_count(0.0) == 3 * inst.gpus         # booting is billed
    pool.pop()
    assert cl._gpu_count(0.0) == 2 * inst.gpus         # removed is not
