"""Pool-centric control-plane API: specs, registry, heterogeneous fleets,
multi-model serving.

Covers the four contract points of the redesign:
  * ``ExperimentSpec`` round-trips through JSON (including int-keyed
    priority mixes, which JSON stringifies);
  * the policy registry rejects unknown names with the registered set;
  * a heterogeneous two-pool fleet (mixed chips/TP) agrees between the
    fluid and event engines within the existing 15% differential band;
  * a two-model fleet produces per-model SLO accounting in ``SimReport``
    and per-pool scaling decisions in the timeline.
"""
import numpy as np
import pytest

from repro.core import (ExperimentSpec, FleetSpec, PoolSpec, TraceRoute,
                        build_policy, profile_for)
from repro.core.autoscaler import POLICY_REGISTRY
from repro.sim.runner import hetero_demo_spec, run_policy, run_spec
from repro.sim.traces import get_trace, trace_stats

REL_TOL = 0.15          # same band as tests/test_sim_differential.py
ABS_TTFT = 0.020
ABS_TPOT = 0.005


def _close(a, b, rel, abs_tol=0.0):
    return abs(a - b) <= max(rel * max(abs(a), abs(b)), abs_tol)


def two_model_spec(engine="fluid"):
    return ExperimentSpec(
        fleet=FleetSpec(
            pools=(
                PoolSpec("llama-pre", "prefill", "llama31_8b", "a100"),
                PoolSpec("llama-dec", "decode", "llama31_8b", "a100"),
                PoolSpec("qwen-pre", "prefill", "qwen25_32b", "a100", tp=4),
                PoolSpec("qwen-dec", "decode", "qwen25_32b", "a100", tp=4),
            ),
            routes=(
                TraceRoute("llama31_8b", "azure_conv", rps=5.0,
                           priority_mix={0: 0.3, 1: 0.7}),
                TraceRoute("qwen25_32b", "azure_code", rps=3.0),
            )),
        policy="tokenscale", engine=engine, duration=25.0, seed=0)


# ---------------------------------------------------------------------------
# ExperimentSpec: JSON round trip + validation
# ---------------------------------------------------------------------------

def test_spec_json_round_trip():
    spec = two_model_spec()
    again = ExperimentSpec.from_json(spec.to_json())
    assert again == spec
    # int priority-class keys survive JSON's string keys
    assert again.fleet.routes[0].priority_mix == {0: 0.3, 1: 0.7}


def test_spec_round_trip_via_file(tmp_path):
    path = tmp_path / "exp.json"
    spec = hetero_demo_spec()
    path.write_text(spec.to_json())
    assert ExperimentSpec.load(str(path)) == spec


def test_fleet_spec_validation():
    with pytest.raises(ValueError, match="unknown role"):
        PoolSpec("p", "prefiller")
    with pytest.raises(ValueError, match="duplicate pool names"):
        FleetSpec((PoolSpec("p", "prefill"), PoolSpec("p", "decode")))
    with pytest.raises(ValueError, match="at least one prefill"):
        FleetSpec((PoolSpec("p", "prefill"),))          # no decode pool
    # same-role pool *sets* are legal (fleet-native planners apportion
    # demand across them); only a missing role is an error
    FleetSpec((PoolSpec("p", "prefill"), PoolSpec("d1", "decode"),
               PoolSpec("d2", "decode", chip="l40s")))
    with pytest.raises(ValueError, match="unknown model"):
        FleetSpec((PoolSpec("p", "prefill"), PoolSpec("d", "decode")),
                  (TraceRoute("qwen25_32b"),))


def test_run_spec_needs_a_route():
    spec = ExperimentSpec(fleet=FleetSpec(
        (PoolSpec("p", "prefill"), PoolSpec("d", "decode"))), duration=5.0)
    with pytest.raises(ValueError, match="TraceRoute"):
        run_spec(spec)


# ---------------------------------------------------------------------------
# Policy registry
# ---------------------------------------------------------------------------

def test_registry_has_all_four_policies():
    assert {"tokenscale", "distserve", "aibrix",
            "blitzscale"} <= set(POLICY_REGISTRY)


def test_registry_unknown_name_error():
    prof = profile_for("llama31_8b", "a100", 1)
    with pytest.raises(ValueError) as ei:
        build_policy("k8s-hpa", prof, mean_in=512.0, mean_out=128.0)
    # the error names the registered policies so typos are self-diagnosing
    assert "k8s-hpa" in str(ei.value)
    assert "tokenscale" in str(ei.value)


def test_make_policy_requires_workload_stats():
    from repro.sim.runner import make_policy
    prof = profile_for("llama31_8b", "a100", 1)
    with pytest.raises(ValueError, match="mean_in"):
        make_policy("distserve", prof)       # no stats, no trace
    trace = get_trace("azure_code", 30.0, 6.0, seed=0)
    stats = trace_stats(trace)
    pol = make_policy("distserve", prof, trace=trace)
    # thresholds derive from the actual (code-heavy, long-prompt) trace,
    # not the historical hardcoded 1024/240
    expect = max(0.7 * prof.v_prefill / stats.mean_in, 0.5)
    assert pol.rp == pytest.approx(expect)
    assert stats.mean_in > 1200.0            # azure_code is prompt-heavy


# ---------------------------------------------------------------------------
# Heterogeneous fleet: both engines, same control plane
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def hetero_reports():
    """Both engines on the mixed-chip fleet.  As in
    tests/test_sim_differential.py, the fluid engine runs at half its
    default tick: it converges toward the event engine as dt -> 0 and
    the default 25 ms leaves ~1.5 ticks of TTFT smearing."""
    import dataclasses
    out = {}
    for eng in ("fluid", "events"):
        spec = hetero_demo_spec(duration=30.0, rps=6.0, engine=eng)
        if eng == "fluid":
            spec = dataclasses.replace(spec, dt=0.0125)
        out[eng] = run_spec(spec)
    return out

def test_hetero_engines_agree(hetero_reports):
    fl, ev = hetero_reports["fluid"], hetero_reports["events"]
    assert len(fl.requests) == len(ev.requests)      # same arrivals
    assert _close(fl.throughput(), ev.throughput(), REL_TOL, 0.1)
    assert _close(fl.mean("ttft"), ev.mean("ttft"), REL_TOL, ABS_TTFT)
    assert _close(fl.mean("tpot"), ev.mean("tpot"), REL_TOL, ABS_TPOT)
    assert _close(fl.avg_gpus(), ev.avg_gpus(), 0.25, 1.0)


def test_hetero_pools_actually_differ(hetero_reports):
    """The point of the fleet: prefill and decode pools run different
    (chip, tp) tuples, with per-pool velocity profiles and per-pool
    scaling decisions recorded in the timeline."""
    rep = hetero_reports["events"]
    pools = rep.timeline[-1]["pools"]
    assert set(pools) == {"pre-a100", "dec-h100", "conv-h100"}
    pre = profile_for("llama31_8b", "a100", 2)
    dec = profile_for("llama31_8b", "h100", 1)
    assert pre.v_prefill != dec.v_prefill            # genuinely mixed
    assert rep.slo_attainment() > 0.7


def test_hetero_serves_requests(hetero_reports):
    for rep in hetero_reports.values():
        done = sum(1 for r in rep.requests if r.t_finish >= 0)
        assert done > 0.8 * len(rep.requests)


# ---------------------------------------------------------------------------
# Multi-model serving: per-model SLO accounting
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mm_reports():
    import dataclasses
    out = {}
    for eng in ("fluid", "events"):
        spec = two_model_spec(engine=eng)
        if eng == "fluid":          # half tick, as in hetero_reports
            spec = dataclasses.replace(spec, dt=0.0125)
        out[eng] = run_spec(spec)
    return out


def test_multi_model_slicing(mm_reports):
    for eng, rep in mm_reports.items():
        assert rep.models() == ["llama31_8b", "qwen25_32b"], eng
        per_model = [rep.model_summary(m) for m in rep.models()]
        # slices partition the request set
        assert sum(s["n"] for s in per_model) == len(rep.requests)
        for s in per_model:
            assert s["n"] > 0
            assert 0.0 <= s["slo_attainment"] <= 1.0
        # throughput decomposes across models
        assert sum(s["throughput"] for s in per_model) == \
            pytest.approx(rep.throughput())


def test_multi_model_isolated_pools(mm_reports):
    """Each model's requests decode only on its own pools: per-pool
    scaling is per model, and the qwen route never inflates llama's
    fleet."""
    rep = mm_reports["fluid"]
    pools = rep.timeline[-1]["pools"]
    assert set(pools) == {"llama-pre", "llama-dec", "qwen-pre", "qwen-dec"}
    # priority mix only applied to the llama route
    llama = [r for r in rep.requests if r.model == "llama31_8b"]
    qwen = [r for r in rep.requests if r.model == "qwen25_32b"]
    assert {r.priority for r in llama} == {0, 1}
    assert {r.priority for r in qwen} == {1}


def test_multi_model_engines_agree(mm_reports):
    fl, ev = mm_reports["fluid"], mm_reports["events"]
    assert len(fl.requests) == len(ev.requests)
    for m in fl.models():
        assert _close(fl.throughput(model=m), ev.throughput(model=m),
                      REL_TOL, 0.1), m
        assert _close(fl.mean("ttft", model=m), ev.mean("ttft", model=m),
                      REL_TOL, ABS_TTFT), m


# ---------------------------------------------------------------------------
# Shim equivalence: run_policy is a one-pool spec
# ---------------------------------------------------------------------------

def test_run_policy_equals_run_spec():
    """The legacy entry point and the equivalent one-pool spec produce
    identical per-request timestamps — the shim adds nothing."""
    from repro.core import single_pool_fleet
    legacy = run_policy("distserve", "azure_conv", duration=20.0, rps=6.0,
                        seed=0, engine="events")
    spec = ExperimentSpec(
        fleet=single_pool_fleet("llama31_8b", "a100", 1,
                                trace="azure_conv", rps=6.0),
        policy="distserve", engine="events", duration=20.0, seed=0)
    direct = run_spec(spec)
    assert len(legacy.requests) == len(direct.requests)
    la = sorted(legacy.requests, key=lambda r: r.src.rid)
    di = sorted(direct.requests, key=lambda r: r.src.rid)
    assert [r.t_finish for r in la] == [r.t_finish for r in di]
    assert [r.t_first_token for r in la] == [r.t_first_token for r in di]
    assert legacy.gpu_seconds == direct.gpu_seconds
