"""Training substrate: optimizer math, schedules, checkpointing, loss."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.training import (AdamWConfig, DataConfig, PackedDataset,
                            adamw_init, adamw_update, lr_at, restore, save,
                            train)


def test_lr_schedule():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_at(cfg, 0)) == 0.0
    assert float(lr_at(cfg, 10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr_at(cfg, 100)) == pytest.approx(1e-4, rel=1e-3)
    # monotone decay after warmup
    vals = [float(lr_at(cfg, s)) for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_adamw_against_manual_step():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      grad_clip=0.0, warmup_steps=0, total_steps=1,
                      min_lr_ratio=1.0)
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -0.5])}
    opt = adamw_init(p, cfg)
    p2, opt2, m = adamw_update(cfg, g, opt, p)
    # bias-corrected first step of Adam: delta = lr * g/|g| elementwise
    want = np.array([1.0, 2.0]) - 0.1 * np.sign([0.5, -0.5])
    np.testing.assert_allclose(np.asarray(p2["w"]), want, atol=1e-4)


def test_grad_clip():
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    opt = adamw_init(p, cfg)
    _, _, m = adamw_update(cfg, g, opt, p)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_factored_adamw_state_shapes():
    cfg = AdamWConfig(factored=True, moment_dtype="bfloat16")
    p = {"w": jnp.zeros((4, 6, 8)), "b": jnp.zeros((5,))}
    opt = adamw_init(p, cfg)
    vr, vc = opt.v["w"]
    assert vr.shape == (4, 6) and vc.shape == (4, 8)
    assert vr.dtype == jnp.bfloat16
    assert opt.v["b"].shape == (5,)          # 1D stays unfactored
    g = jax.tree.map(jnp.ones_like, p)
    p2, opt2, _ = adamw_update(cfg, g, opt, p)
    assert p2["w"].shape == p["w"].shape
    assert opt2.v["w"][0].shape == (4, 6)


@pytest.mark.slow
def test_loss_drops_on_synthetic_corpus():
    cfg = get_config("gemma_2b", smoke=True)
    _, res = train(cfg, steps=25, batch=8, seq_len=64, log_every=0)
    assert res.losses[-1] < res.losses[0] - 0.2


def test_data_pipeline_deterministic_and_resumable():
    dc = DataConfig(vocab_size=512, seq_len=32, global_batch=4, seed=9)
    ds1, ds2 = PackedDataset(dc), PackedDataset(dc)
    t1, l1 = ds1.batch(17)
    t2, l2 = ds2.batch(17)       # random access == resume
    assert np.array_equal(t1, t2) and np.array_equal(l1, l2)
    assert np.array_equal(t1[:, 1:], l1[:, :-1])   # next-token labels
    assert t1.min() >= 0 and t1.max() < 512


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": np.arange(6, dtype=np.float32).reshape(2, 3)},
            "c": [np.ones((4,), np.int32), np.zeros((2, 2))]}
    save(str(tmp_path / "ck"), tree, step=42)
    got, step = restore(str(tmp_path / "ck"), like=tree)
    assert step == 42
    np.testing.assert_array_equal(got["a"]["b"], tree["a"]["b"])
    np.testing.assert_array_equal(got["c"][0], tree["c"][0])


@pytest.mark.slow
def test_checkpoint_restores_training(tmp_path):
    cfg = get_config("qwen2_0_5b", smoke=True)
    params, res = train(cfg, steps=3, batch=2, seq_len=16, log_every=0)
    save(str(tmp_path / "ck"), params, step=3)
    got, step = restore(str(tmp_path / "ck"), like=params)
    d = jax.tree.map(lambda a, b: float(np.abs(np.asarray(a, np.float32)
                                               - np.asarray(b, np.float32)).max()),
                     params, got)
    assert max(jax.tree.leaves(d)) == 0.0


@pytest.mark.slow
def test_remat_preserves_loss():
    """Activation checkpointing changes memory, not math."""
    from repro.training import lm_loss
    from repro.models import init_params
    cfg = get_config("llama31_8b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    labels = jnp.roll(toks, -1, 1)
    l1, _ = lm_loss(cfg, params, toks, labels, remat=False)
    l2, _ = lm_loss(cfg, params, toks, labels, remat=True)
    assert float(jnp.abs(l1 - l2)) < 1e-5
    g1 = jax.grad(lambda p: lm_loss(cfg, p, toks, labels, remat=False)[0])(params)
    g2 = jax.grad(lambda p: lm_loss(cfg, p, toks, labels, remat=True)[0])(params)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), g1, g2)
    assert max(jax.tree.leaves(d)) < 1e-4
