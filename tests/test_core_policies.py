"""Autoscaler policies (Eq.2-4 + baselines) and the Fig. 6 scenario."""
import pytest

from repro.configs import get_config
from repro.core import (AIBrixPolicy, BlitzScalePolicy, CHIPS,
                        DistServePolicy, InstanceSpec, Observation,
                        TokenScalePolicy, profile)
from repro.core.router import BurstDetector, Router, ttft_slo


@pytest.fixture(scope="module")
def prof():
    return profile(get_config("llama31_8b"), InstanceSpec(CHIPS["a100"], 1))


def _obs(t=10.0, tok=0.0, buckets=None, rps=0.0, queue=0, inflight=0,
         util=0.0, p=1, d=1):
    return Observation(t=t, token_rate_in=tok,
                       token_rate_by_bucket=buckets or {}, rps=rps,
                       prefill_queue=queue, decode_inflight=inflight,
                       mem_util=util, cur_prefillers=p, cur_decoders=d)


def test_eq2_prefiller_count(prof):
    pol = TokenScalePolicy(prof, convertible=1)
    v = min(prof.v_prefill, prof.v_network)
    dec = pol.decide(_obs(tok=v * 2.5))
    assert dec.prefillers == 3          # ceil(2.5)


def test_eq3_eq4_decoder_count(prof):
    pol = TokenScalePolicy(prof, convertible=1)
    lam = {"M-M": prof.v_decode["M-M"] * 1.4,
           "S-L": prof.v_decode["S-L"] * 0.9}
    dec = pol.decide(_obs(buckets=lam))
    # Eq.3: ceil(1.4 + 0.9) = 3; Eq.4: minus 1 convertible
    assert dec.decoders == 3 - 1


def test_fig6_token_burst_detected_only_by_tokenscale(prof):
    """Fig. 6 T2: few requests, many tokens. Request-threshold policies
    under-provision; the velocity policy scales."""
    ts = TokenScalePolicy(prof, convertible=0)
    ds = DistServePolicy(rps_per_prefiller=4.0, rps_per_decoder=8.0)
    # 2 requests/s but each with huge prompts: token rate = 3x V_P
    obs = _obs(tok=prof.v_prefill * 3.0, rps=2.0)
    assert ts.decide(obs).prefillers == 3
    assert ds.decide(obs).prefillers == 1      # blind to token volume


def test_fig6_request_burst_both_detect(prof):
    ts = TokenScalePolicy(prof, convertible=0)
    ds = DistServePolicy(rps_per_prefiller=4.0, rps_per_decoder=8.0)
    # many tiny requests: 12 rps of ~0.1*V_P total tokens
    obs = _obs(tok=prof.v_prefill * 1.2, rps=12.0)
    assert ts.decide(obs).prefillers == 2
    assert ds.decide(obs).prefillers == 3


def test_scale_down_hysteresis(prof):
    pol = TokenScalePolicy(prof, convertible=0, down_delay=5.0)
    hi = _obs(t=0.0, tok=prof.v_prefill * 3.0, p=3)
    assert pol.decide(hi).prefillers == 3
    lo1 = _obs(t=1.0, tok=prof.v_prefill * 0.5, p=3)
    assert pol.decide(lo1).prefillers == 3     # held
    lo2 = _obs(t=7.0, tok=prof.v_prefill * 0.5, p=3)
    assert pol.decide(lo2).prefillers == 1     # released after delay


def test_aibrix_lags_burst(prof):
    """AIBrix averages over a sliding window — a 1-tick spike must not
    trigger full scaling immediately (the §II-D lag)."""
    pol = AIBrixPolicy(conc_per_prefiller=2.0, window_s=5.0)
    for t in range(5):
        pol.decide(_obs(t=float(t), queue=0))
    spike = pol.decide(_obs(t=5.0, queue=20))
    assert spike.prefillers < 10    # 20/2 = 10 would be the instant answer


def test_blitzscale_is_live(prof):
    pol = BlitzScalePolicy()
    assert pol.decide(_obs(queue=30, inflight=50)).live


# ---------------------------------------------------------------------------
# Router (Alg. 1) + burst detector
# ---------------------------------------------------------------------------

class _FakeInst:
    def __init__(self, tokens, v):
        self._t, self._v = tokens, v

    def inflight_tokens(self):
        return self._t

    def prefill_velocity(self):
        return self._v


def test_alg1_first_feasible_prefiller():
    r = Router()
    fast = _FakeInst(tokens=100, v=10_000)
    slow = _FakeInst(tokens=100_000, v=10_000)
    tgt, kind = r.route_prefill(100, [slow, fast], [], now=0.0)
    assert tgt is fast and kind == "prefiller"


def test_alg1_falls_through_to_convertible():
    r = Router()
    slow = _FakeInst(tokens=100_000, v=10_000)     # 10 s wait >> SLO
    conv = _FakeInst(tokens=0, v=5_000)
    tgt, kind = r.route_prefill(100, [slow], [conv], now=0.0)
    assert tgt is conv and kind == "convertible"


def test_alg1_queues_when_nothing_feasible():
    r = Router()
    slow = _FakeInst(tokens=100_000, v=10_000)
    tgt, kind = r.route_prefill(100, [slow], [slow], now=0.0)
    assert tgt is None and kind is None


def test_ttft_slo_tiers():
    assert ttft_slo(100) == 0.25
    assert ttft_slo(512) == 0.40
    assert ttft_slo(8000) == 2.0


def test_burst_detector():
    bd = BurstDetector(short_s=1.0, long_s=60.0, factor=1.5)
    for t in range(30):
        bd.observe(float(t), 100.0)
    assert not bd.is_burst(30.0)
    bd.observe(30.1, 3000.0)    # spike
    assert bd.is_burst(30.2)


class _FakeDecoder:
    is_convertible = False

    def __init__(self, inflight_by_bucket, util=0.1, conv=False):
        self._b = inflight_by_bucket
        self._u = util
        self.is_convertible = conv

    def inflight_of_bucket(self, b):
        return self._b.get(b, 0)

    def mem_util(self):
        return self._u


def test_decode_routing_by_bucket():
    r = Router()
    d1 = _FakeDecoder({"M-M": 5})
    d2 = _FakeDecoder({"M-M": 1})
    assert r.route_decode("M-M", [d1, d2]) is d2


def test_decode_routing_excludes_full_convertible():
    r = Router()
    conv = _FakeDecoder({"M-M": 0}, util=0.95, conv=True)
    reg = _FakeDecoder({"M-M": 9}, util=0.5)
    assert r.route_decode("M-M", [conv, reg], mem_threshold=0.9) is reg
