# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device.  Only launch/dryrun.py (run as __main__ or via
# subprocess) forces 512 placeholder devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
