# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device.  Only launch/dryrun.py (run as __main__ or via
# subprocess) forces 512 placeholder devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    # Belt-and-braces marker registration so `-m "not slow"` (the pytest.ini
    # default) works even when the suite is run from another rootdir.
    config.addinivalue_line(
        "markers",
        "slow: long-running system/bench-shaped tests "
        "(deselected by default; run with -m slow)")
