"""Chunked prefill + load-aware deflection (tentpole PR 6).

Covers the chunk-interleaved execution path and Alg. 1 round 2b:

  * golden replay of ``tests/golden/deflect_burst.json`` (both engines x
    wholesale/chunked variants on the saturated burst fleet);
  * the acceptance gradient — chunked deflection beats whole-instance
    conversion on p99 TTFT on both burst traces while resident p99 TPOT
    stays inside the SLO;
  * fluid-vs-events differential band (<= 15%) for the chunked variant;
  * per-class tails under priority classes + paged-KV mode;
  * the Eq. 5 property: a planned chunk never pushes the resident batch
    past the strictest resident class's TPOT budget — asserted both on a
    parameter grid over ``Decoder`` directly and via an end-to-end audit
    of every chunk the event engine actually plans.
"""
import json
import os

import pytest

from repro.configs import get_config
from repro.core import CHIPS, InstanceSpec
from repro.core.router import TPOT_SLO, tpot_slo
from repro.sim.instances import (MIN_DEFLECT_CHUNK, Decoder, ModelCost,
                                 SimRequest)
from repro.sim.runner import run_policy
from repro.sim.traces import DEFAULT_PRIORITY_MIX, TraceRequest

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN_DEF = json.load(open(os.path.join(GOLDEN_DIR, "deflect_burst.json")))


def _run_deflect(variant, engine, trace=None, **overrides):
    """Replay one deflect cell from the recorded fixture (same recipe as
    benchmarks.run.run_deflect_variant and the regenerator)."""
    g = GOLDEN_DEF
    fleet = dict(g["fleet"])
    fleet.update(overrides)
    return run_policy("tokenscale", trace or g["trace"], engine=engine,
                      prefill_chunking=g["variants"][variant], **fleet)


@pytest.fixture(scope="module")
def deflect_reports():
    return {(eng, v): _run_deflect(v, eng)
            for eng in GOLDEN_DEF["engines"]
            for v in GOLDEN_DEF["variants"]}


# ---------------------------------------------------------------------------
# golden replay
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", list(GOLDEN_DEF["engines"]))
@pytest.mark.parametrize("variant", list(GOLDEN_DEF["variants"]))
def test_deflect_matches_golden(deflect_reports, engine, variant):
    rep = deflect_reports[(engine, variant)]
    want = GOLDEN_DEF["engines"][engine][variant]
    got = rep.summary()                  # same schema as the regenerator
    got["tpot_p99"] = rep.percentile("tpot", 99)
    got["n_deflected"] = rep.n_deflected
    assert set(got) == set(want), (engine, variant)
    assert got["n_requests"] == want["n_requests"]
    for key, expect in want.items():
        assert got[key] == pytest.approx(expect, rel=0.05), \
            (engine, variant, key, got[key], expect)


# ---------------------------------------------------------------------------
# acceptance gradient: chunked deflection vs wholesale conversion
# ---------------------------------------------------------------------------

def test_chunked_beats_wholesale_on_burst_tail(deflect_reports):
    """p99 TTFT strictly improves on the burst trace at event fidelity,
    deflections actually fire, and the resident tail TPOT stays inside
    the SLO for both variants (the ISSUE acceptance criteria; the second
    burst trace is covered by test_gradient_holds_on_second_trace)."""
    whole = deflect_reports[("events", "wholesale")]
    chunk = deflect_reports[("events", "chunked")]
    assert chunk.n_deflected > 0
    assert whole.n_deflected == 0        # round 2b gated off by the knob
    assert chunk.percentile("ttft", 99) < whole.percentile("ttft", 99)
    assert chunk.percentile("tpot", 99) <= TPOT_SLO
    assert whole.percentile("tpot", 99) <= TPOT_SLO


def test_gradient_holds_on_second_trace():
    """The same win on burstgpt2 — deflection is a load-shape property,
    not a single-trace artifact."""
    whole = _run_deflect("wholesale", "events", trace="burstgpt2")
    chunk = _run_deflect("chunked", "events", trace="burstgpt2")
    assert chunk.n_deflected > 0
    assert chunk.percentile("ttft", 99) < whole.percentile("ttft", 99)
    assert chunk.percentile("tpot", 99) <= TPOT_SLO


# ---------------------------------------------------------------------------
# fluid vs events differential band
# ---------------------------------------------------------------------------

def test_chunked_differential_band(deflect_reports):
    """The fluid engine's per-tick chunk approximation tracks the event
    engine's exact chunk boundaries on the aggregates (DESIGN.md
    "Deflection fidelity")."""
    fl = deflect_reports[("fluid", "chunked")]
    ev = deflect_reports[("events", "chunked")]
    for metric in ("ttft", "tpot"):
        a, b = fl.mean(metric), ev.mean(metric)
        assert abs(a - b) / max(b, 1e-9) <= 0.15, (metric, a, b)
    assert abs(fl.throughput() - ev.throughput()) \
        / max(ev.throughput(), 1e-9) <= 0.15
    # both engines route a comparable share through round 2b
    assert abs(fl.n_deflected - ev.n_deflected) \
        / max(ev.n_deflected, 1) <= 0.15


# ---------------------------------------------------------------------------
# priority classes + paged-KV mode
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def prio_kv_report():
    return _run_deflect("chunked", "events", duration=20.0,
                        priority_mix=DEFAULT_PRIORITY_MIX, block_size=16)


def test_deflection_fires_under_priority_and_paged_kv(prio_kv_report):
    assert prio_kv_report.n_deflected > 0


def test_class_tail_gradient_survives_deflection(prio_kv_report):
    """Priority-ordered admission still holds with chunks in the decode
    iterations: higher classes see no worse p99 TTFT than lower ones."""
    rep = prio_kv_report
    p99 = [rep.percentile("ttft", 99, priority=c)
           for c in rep.priority_classes()]
    assert len(p99) == 3
    assert p99 == sorted(p99)


def test_interactive_tail_tpot_within_class_slo(prio_kv_report):
    """Chunk planning budgets against the *strictest resident* class, so
    the interactive class's tail TPOT must hold its own (unscaled) SLO
    even while prompts are being deflected through the same batches."""
    rep = prio_kv_report
    assert rep.percentile("tpot", 99, priority=0) <= tpot_slo(0)


# ---------------------------------------------------------------------------
# Eq. 5 property: planned chunks respect the resident TPOT budget
# ---------------------------------------------------------------------------

def _chunked_decoder(chunking=2048, batch=0, in_len=512, out_len=128,
                     priorities=(1,)):
    cfg = get_config("llama31_8b")
    d = Decoder(1, InstanceSpec(CHIPS["a100"], 1), ModelCost.of(cfg), 0.0)
    d.chunking = chunking
    for i in range(batch):
        r = SimRequest(TraceRequest(i, 0.0, in_len, out_len,
                                    priority=priorities[i % len(priorities)]))
        d.admit(r, 0.0)
    return d


@pytest.mark.parametrize("batch", [0, 1, 8, 32, 64])
@pytest.mark.parametrize("in_len", [128, 2048])
@pytest.mark.parametrize("priorities", [(1,), (0, 1, 2)])
def test_planned_chunk_respects_tpot_budget(batch, in_len, priorities):
    """Grid over batch size x context x resident mix: whenever the Eq. 5
    headroom clears the starvation floor, the planned chunk's mixed
    iteration stays within the strictest resident class's TPOT budget;
    below the floor, progress is capped at the floor itself (bounded
    overshoot) and the decoder advertises zero deflect velocity so the
    router never adds work served only through the floor."""
    d = _chunked_decoder(batch=batch, in_len=in_len, priorities=priorities)
    d.submit_prefill(SimRequest(TraceRequest(999, 0.0, 4096, 64)), 0.0)
    head = d._headroom_chunk()
    chunk = d.plan_chunk()
    assert 0 < chunk <= d.chunking
    if head >= MIN_DEFLECT_CHUNK:
        assert d.mixed_iter_time(chunk) <= d._tpot_budget() * (1 + 1e-9)
        assert d.deflect_velocity() > 0
    else:
        assert chunk <= MIN_DEFLECT_CHUNK
        assert d.deflect_velocity() == 0.0


def test_budget_tracks_strictest_resident_class():
    """A batch-priority-only batch relaxes the budget 4x; admitting one
    interactive request snaps it back to the base SLO."""
    d = _chunked_decoder(batch=4, priorities=(2,))
    assert d._tpot_budget() == tpot_slo(2)
    d.admit(SimRequest(TraceRequest(50, 0.0, 256, 64, priority=0)), 0.0)
    assert d._tpot_budget() == tpot_slo(0)


def test_e2e_planned_chunks_respect_budget(monkeypatch):
    """End-to-end audit at event fidelity: record every chunk the engine
    actually plans and verify the Eq. 5 property held each time headroom
    cleared the floor."""
    from repro.sim import instances as inst_mod
    records = []
    orig = inst_mod.Decoder.plan_chunk

    def spy(self):
        chunk = orig(self)
        if chunk > 0:
            records.append((self._headroom_chunk(), chunk,
                            self.mixed_iter_time(chunk),
                            self._tpot_budget()))
        return chunk

    monkeypatch.setattr(inst_mod.Decoder, "plan_chunk", spy)
    _run_deflect("chunked", "events", duration=15.0)
    assert records
    in_budget = 0
    for head, chunk, it_mix, budget in records:
        assert chunk <= max(head, MIN_DEFLECT_CHUNK) + 1e-9
        if head >= MIN_DEFLECT_CHUNK:
            assert it_mix <= budget * (1 + 1e-9), (head, chunk, it_mix)
            in_budget += 1
    assert in_budget > 0
