"""Hypothesis property tests over simulator / planning invariants."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import CHIPS, InstanceSpec, plan_convertible, profile
from repro.sim import get_trace
from repro.sim.traces import TRACES, generate


@pytest.fixture(scope="module")
def prof():
    return profile(get_config("llama31_8b"), InstanceSpec(CHIPS["a100"], 1))


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(list(TRACES)), st.integers(0, 5),
       st.floats(1.0, 20.0))
def test_trace_generator_invariants(name, seed, rps):
    trace = generate(TRACES[name], 60.0, rps, seed)
    for r in trace:
        assert 0.0 <= r.t < 60.0
        assert 32 <= r.in_len <= 8192
        assert 16 <= r.out_len <= 640
    ts = [r.t for r in trace]
    assert ts == sorted(ts)
    ids = [r.rid for r in trace]
    assert len(set(ids)) == len(ids)


@settings(max_examples=8, deadline=None)
@given(st.floats(0.05, 0.8), st.integers(2, 32))
def test_convertible_pool_monotone_in_burst_ratio(ratio, max_dec):
    cfg = get_config("llama31_8b")
    inst = InstanceSpec(CHIPS["a100"], 1)
    lo = plan_convertible(cfg, inst, 32, 1200.0, ratio / 2, max_dec)
    hi = plan_convertible(cfg, inst, 32, 1200.0, ratio, max_dec)
    assert hi.pool_size >= lo.pool_size
    assert lo.pool_size >= 1
    assert hi.chunk_size == lo.chunk_size    # chunk is SLO-, not burst-bound


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 3))
def test_sim_report_invariants(seed):
    from repro.sim.runner import run_policy
    rep = run_policy("tokenscale", "azure_conv", duration=25.0, rps=6.0,
                     seed=seed)
    assert 0.0 <= rep.slo_attainment() <= 1.0
    assert 0.0 <= rep.ttft_attainment() <= 1.0
    assert 0.0 <= rep.tpot_attainment() <= 1.0
    # at least (1 prefiller + 1 decoder + 1 convertible) always resident
    assert rep.gpu_seconds >= 3 * rep.duration * 0.9
    for r in rep.requests:
        if r.t_finish >= 0:
            assert r.t_finish >= r.src.t
            assert r.ttft >= 0.0
            assert r.tpot >= 0.0


def test_velocity_profile_positive(prof):
    assert prof.v_prefill > 0
    assert prof.v_network > 0
    assert all(v > 0 for v in prof.v_decode.values())
    assert all(b >= 1 for b in prof.max_batch.values())
