"""Differential validation: fluid vs discrete-event cluster simulator.

The two engines (sim/cluster.py, sim/events.py) share one roofline model,
one metrics pipeline, and the *same unmodified* control plane (policies,
router, burst detector, convertible planning) — but advance time completely
differently.  Agreement across every trace x policy is therefore a strong
end-to-end check on both implementations; disagreement localizes bugs to
whichever mechanism the engines do not share.

Also holds the event-engine property tests: event causality (no token
before prefill completes, no decode before the KVC transfer lands) and
conservation (every arrived request either finishes or is in flight at the
horizon).
"""
import numpy as np
import pytest

from repro.core.router import ttft_slo
from repro.sim import get_trace
from repro.sim.runner import ENGINES, compare_engines, run_policy

TRACE_NAMES = ["azure_conv", "azure_code", "burstgpt1", "burstgpt2", "mixed"]
POLICIES = ["tokenscale", "distserve", "aibrix", "blitzscale"]

# §Acceptance: engines agree within 15% on throughput and mean TTFT/TPOT.
REL_TOL = 0.15
# absolute floors keep tiny denominators from blowing up the relative check;
# with first-token stamping at the end of the first decode iteration (both
# engines — PR 2) the TTFT floor tightened from 30 ms to 20 ms
ABS_TTFT = 0.020
ABS_TPOT = 0.005


def _close(a: float, b: float, rel: float, abs_tol: float = 0.0) -> bool:
    return abs(a - b) <= max(rel * max(abs(a), abs(b)), abs_tol)


@pytest.fixture(scope="module")
def reports():
    """Both engines over every trace x policy (short horizon keeps this
    tier-1-fast).  The fluid engine runs at half its default tick (12.5 ms):
    it converges toward the event engine as dt -> 0, and the default 25 ms
    leaves ~1.5 ticks of TTFT smearing across the prefill -> transfer ->
    admit pipeline."""
    out = {}
    for trace in TRACE_NAMES:
        for pol in POLICIES:
            out[(trace, pol)] = compare_engines(pol, trace, duration=40.0,
                                                rps=6.0, seed=0, dt=0.0125)
    return out


@pytest.mark.parametrize("trace", TRACE_NAMES)
@pytest.mark.parametrize("pol", POLICIES)
def test_engines_agree(reports, trace, pol):
    fl = reports[(trace, pol)]["fluid"]
    ev = reports[(trace, pol)]["events"]
    assert len(fl.requests) == len(ev.requests)          # same arrivals
    assert _close(fl.throughput(), ev.throughput(), REL_TOL, 0.1), \
        ("throughput", fl.throughput(), ev.throughput())
    assert _close(fl.mean("ttft"), ev.mean("ttft"), REL_TOL, ABS_TTFT), \
        ("ttft", fl.mean("ttft"), ev.mean("ttft"))
    assert _close(fl.mean("tpot"), ev.mean("tpot"), REL_TOL, ABS_TPOT), \
        ("tpot", fl.mean("tpot"), ev.mean("tpot"))


@pytest.mark.parametrize("trace", TRACE_NAMES)
@pytest.mark.parametrize("pol", POLICIES)
def test_scaling_decisions_agree(reports, trace, pol):
    """The control plane sees near-identical Observations in both engines,
    so provisioning (avg GPUs over the run) must track closely."""
    fl = reports[(trace, pol)]["fluid"]
    ev = reports[(trace, pol)]["events"]
    assert _close(fl.avg_gpus(), ev.avg_gpus(), 0.25, 1.0), \
        ("avg_gpus", fl.avg_gpus(), ev.avg_gpus())


def test_engines_registry():
    assert set(ENGINES) == {"fluid", "events"}
    with pytest.raises(ValueError):
        run_policy("tokenscale", "azure_conv", duration=5.0,
                   engine="nonsense")


# ---------------------------------------------------------------------------
# Event-engine properties
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def event_report(reports):
    return reports[("azure_conv", "tokenscale")]["events"]


def test_event_causality(event_report):
    """No first token before prefill completes; no decode before the KVC
    transfer lands; finish after first token."""
    for r in event_report.requests:
        if r.t_prefill_start >= 0:
            assert r.t_prefill_start >= r.src.t
        if r.t_prefill_end >= 0:
            assert r.t_prefill_end >= r.t_prefill_start
        if r.t_kv_ready >= 0:
            assert r.t_kv_ready >= r.t_prefill_end
        if r.t_first_token >= 0:
            assert r.t_prefill_end >= 0, "token emitted before prefill"
            assert r.t_first_token >= r.t_prefill_end
            # strict: token 1 exists only after the first decode iteration
            # *completes* — admission-time stamping was the PR-2 TTFT bug
            assert r.t_first_token > r.t_kv_ready
            assert r.t_first_token > r.t_decode_start >= 0
        if r.t_finish >= 0:
            assert r.t_finish >= r.t_first_token


def test_event_conservation(reports):
    """Every arrived request finishes or is in flight at the horizon, for
    every trace x policy — nothing is dropped or duplicated."""
    for (trace, pol), pair in reports.items():
        ev = pair["events"]
        arrived = sum(1 for t in get_trace(trace, 40.0, 6.0, 0)
                      if t.t < ev.duration)
        assert len(ev.requests) == len({id(r) for r in ev.requests})
        assert len(ev.requests) == arrived, (trace, pol)


def test_event_tokens_are_integers(event_report):
    """Per-iteration batching: generated counts advance in whole tokens
    (the fluid engine smears fractional tokens per tick instead)."""
    finished = [r for r in event_report.requests if r.t_finish >= 0]
    assert finished
    for r in finished:
        assert float(r.generated).is_integer()
        assert int(r.generated) == r.src.out_len


def test_event_tails_not_smeared(event_report):
    """TTFTs land on exact event timestamps, not dt-quantized ticks: the
    distribution must not collapse onto the 25 ms grid."""
    ttfts = np.array([r.ttft for r in event_report.requests
                      if r.t_first_token >= 0])
    assert len(ttfts) > 50
    on_grid = np.isclose(ttfts / 0.025, np.round(ttfts / 0.025), atol=1e-6)
    assert on_grid.mean() < 0.5
    # and per-request TPOT varies (batch-size-dependent iteration times)
    tpots = {round(r.tpot, 9) for r in event_report.requests
             if r.t_finish >= 0 and r.src.out_len > 1}
    assert len(tpots) > 10


def test_event_engine_deterministic():
    a = run_policy("tokenscale", "azure_conv", duration=30.0, seed=5,
                   engine="events")
    b = run_policy("tokenscale", "azure_conv", duration=30.0, seed=5,
                   engine="events")
    assert a.slo_attainment() == b.slo_attainment()
    assert a.gpu_seconds == b.gpu_seconds
    assert [r.t_finish for r in a.requests] == \
        [r.t_finish for r in b.requests]


# ---------------------------------------------------------------------------
# Fault differential (chaos engine, sim.faults)
# ---------------------------------------------------------------------------

#: crash(prefill) + straggler(decode) + crash(decode), all landing inside
#: the horizon on both engines (skipped == 0) — the schedule is drawn
#: once from the seeded substream, so both engines replay the same list
FAULT_SCHEDULE = dict(seed=2, crashes=2, stragglers=1, t0=6.0,
                      recovery=True)


@pytest.fixture(scope="module")
def fault_reports():
    """Both engines over the identical crash + straggler schedule.  The
    fluid engine approximates injections at tick granularity and applies
    straggler slowdown to in-flight iterations immediately (the event
    engine from the next kick) — the standard 15% band must absorb
    exactly that divergence (DESIGN.md 'Fault fidelity')."""
    return compare_engines("tokenscale", "burstgpt1", duration=40.0,
                           rps=6.0, seed=0, dt=0.0125,
                           faults=dict(FAULT_SCHEDULE))


def test_engines_agree_under_faults(fault_reports):
    fl, ev = fault_reports["fluid"], fault_reports["events"]
    assert len(fl.requests) == len(ev.requests)          # same arrivals
    # the pre-drawn schedule resolved identically: same injections landed
    for key in ("crashes", "restarts", "straggler_windows", "skipped"):
        assert fl.fault_summary()[key] == ev.fault_summary()[key], key
    assert fl.fault_summary()["crashes"] == 2
    assert fl.fault_summary()["straggler_windows"] == 1
    assert fl.fault_summary()["skipped"] == 0
    assert _close(fl.throughput(), ev.throughput(), REL_TOL, 0.1), \
        ("throughput", fl.throughput(), ev.throughput())
    assert _close(fl.mean("ttft"), ev.mean("ttft"), REL_TOL, ABS_TTFT), \
        ("ttft", fl.mean("ttft"), ev.mean("ttft"))
    assert _close(fl.mean("tpot"), ev.mean("tpot"), REL_TOL, ABS_TPOT), \
        ("tpot", fl.mean("tpot"), ev.mean("tpot"))


def test_fault_conservation_both_engines(fault_reports):
    """Crashes neither drop nor duplicate work: every arrival is in the
    report exactly once on both engines."""
    for name, rep in fault_reports.items():
        rids = [r.src.rid for r in rep.requests]
        assert len(rids) == len(set(rids)), name
    assert len(fault_reports["fluid"].requests) == \
        len(fault_reports["events"].requests)


def test_event_engine_slo_sanity(event_report):
    """The event engine reproduces the headline behavior: TokenScale keeps
    most requests within SLO on a bursty trace."""
    assert event_report.slo_attainment() > 0.7
    for r in event_report.requests:
        if r.t_first_token >= 0 and r.ttft <= ttft_slo(r.src.in_len):
            break
    else:
        pytest.fail("no request met its TTFT SLO")
