"""Token Velocity metric, offline profiler, and Eq. 1-6 (paper §III-IV)."""
import math

import pytest

from repro.configs import get_config
from repro.core import CHIPS, InstanceSpec, bucket_lengths, bucket_of, profile
from repro.core.velocity import (BUCKETS, convertible_chunk_size,
                                 convertible_prefill_velocity, mixed_iter_time,
                                 profile_decode_velocity,
                                 profile_prefill_velocity, reserved_memory)

# Paper Table II: Llama-3.1-8B TP=1 on the A100 cluster (tok/s)
TABLE_II_LLAMA = {
    "S-S": 23535, "S-M": 8146, "S-L": 5138,
    "M-S": 33106, "M-M": 9794, "M-L": 5766,
    "L-S": 39551, "L-M": 11310, "L-L": 6495,
}


@pytest.fixture(scope="module")
def llama_profile():
    cfg = get_config("llama31_8b")
    return profile(cfg, InstanceSpec(CHIPS["a100"], tp=1))


def test_bucket_taxonomy():
    assert bucket_of(100, 50) == "S-S"
    assert bucket_of(256, 100) == "S-S"
    assert bucket_of(257, 101) == "M-M"
    assert bucket_of(8192, 610) == "L-L"
    assert len(BUCKETS) == 9
    for b in BUCKETS:
        i, o = bucket_lengths(b)
        assert bucket_of(i, o) == b


def test_decode_velocity_within_table_ii_band(llama_profile):
    """The analytic profiler must land within 2x of every paper Table II
    per-bucket decode velocity (same hardware, same model)."""
    for b, paper_v in TABLE_II_LLAMA.items():
        ours = llama_profile.v_decode[b]
        assert paper_v / 2 <= ours <= paper_v * 2, (b, ours, paper_v)


def test_prefill_velocity_near_table_i(llama_profile):
    """Table I sets TokenScale's prefiller threshold at 14K tok/s for this
    (model, cluster) — our V_P must be the same order."""
    assert 7_000 <= llama_profile.v_prefill <= 28_000


def test_network_velocity_not_bottleneck(llama_profile):
    """§III-C: network velocity is far above prefill/decode velocities."""
    assert llama_profile.v_network > 3 * llama_profile.v_prefill


def test_decode_velocity_ordering(llama_profile):
    """Longer outputs hold memory longer -> lower velocity (paper Table II
    monotonicity along the output axis)."""
    for i in "SML":
        vs = [llama_profile.v_decode[f"{i}-{o}"] for o in "SML"]
        assert vs[0] > vs[1] > vs[2], (i, vs)


def test_eq5_convertible_prefill_velocity():
    assert convertible_prefill_velocity(2048, 48, 0.1) == (2048 - 48) / 0.1
    assert convertible_prefill_velocity(10, 48, 0.1) == 0.0


def test_eq6_reserved_memory():
    v = 20_000.0
    mem_t = 131072.0
    assert reserved_memory(v, mem_t, 0.4) == v * mem_t * 0.4


def test_chunk_size_respects_tpot_slo():
    cfg = get_config("llama31_8b")
    inst = InstanceSpec(CHIPS["a100"], tp=1)
    chunk = convertible_chunk_size(cfg, inst, decode_batch=32,
                                   avg_ctx=1200.0, tpot_slo=0.1)
    assert chunk > 0 and chunk % 128 == 0
    assert mixed_iter_time(cfg, inst, 32, 1200.0, chunk) <= 0.1
    assert mixed_iter_time(cfg, inst, 32, 1200.0, chunk + 128) > 0.1


def test_chunk_size_monotone_in_slo():
    cfg = get_config("llama31_8b")
    inst = InstanceSpec(CHIPS["a100"], tp=1)
    c1 = convertible_chunk_size(cfg, inst, 32, 1200.0, tpot_slo=0.05)
    c2 = convertible_chunk_size(cfg, inst, 32, 1200.0, tpot_slo=0.2)
    assert c2 >= c1


def test_velocity_scales_with_hardware():
    """H100 velocities strictly dominate A100 (paper Fig. 7/15)."""
    cfg = get_config("llama31_8b")
    pa = profile(cfg, InstanceSpec(CHIPS["a100"], tp=1))
    ph = profile(cfg, InstanceSpec(CHIPS["h100"], tp=1))
    assert ph.v_prefill > pa.v_prefill
    assert sum(ph.v_decode.values()) > sum(pa.v_decode.values())


def test_int8_kv_raises_decode_velocity():
    """Beyond-paper: quantized KV cache ~doubles memory-bound decode
    velocity, which Eq. 3 converts into fewer decoders."""
    cfg = get_config("llama31_8b")
    inst = InstanceSpec(CHIPS["a100"], tp=1)
    p16 = profile(cfg, inst)
    p8 = profile(cfg.replace(kv_cache_dtype="int8"), inst)
    assert p8.v_decode["M-M"] > 1.5 * p16.v_decode["M-M"]
    assert p8.max_batch["M-M"] >= 1.7 * p16.max_batch["M-M"]


def test_ssm_network_velocity_unbounded_vs_kvc():
    """RWKV (attention-free) transfers O(1) state: network velocity must
    dwarf a KV-cache model's (DESIGN.md arch-applicability)."""
    from repro.core.velocity import profile_network_velocity
    inst = InstanceSpec(CHIPS["a100"], tp=1)
    v_rwkv = profile_network_velocity(get_config("rwkv6_3b"), inst)
    v_llama = profile_network_velocity(get_config("llama31_8b"), inst)
    # O(1)-state transfer amortized over ~1k-token requests: ~6x here
    assert v_rwkv > 3 * v_llama
