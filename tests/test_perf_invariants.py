"""Perf-rework invariants: cached hot-path aggregates == from-scratch truth.

The O(1) hot-path rework (DESIGN.md "Performance") replaced per-call
reductions over ``Decoder.active`` / ``Prefiller.queue`` with dirty-flag
caches, incremental integer counters, and an exact-integer context sum.
A missed invalidation would silently skew admission/routing, so — in the
spirit of ``KVAllocator.check()`` — ``check_aggregates`` re-derives every
aggregate from first principles, and these tests call it

  * after every step of a 2000-op randomized admit/evict/advance/finish
    fuzz driven directly against a ``Decoder`` + ``Prefiller`` pair, and
  * after end-to-end runs of both engines on the contended
    preemption-heavy fleet (where eviction churn is maximal).

The file also pins the behavior-preserving contracts of the rework that
the golden fixtures cover only indirectly: bisect queue inserts match the
historical linear scan, the incremental burst-detector windows match the
historical rebuild-and-resum, lazy streamed arrivals match an eager list,
the snapshot-cadence knob, and SimReport's memoized metrics.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import CHIPS, InstanceSpec, profile
from repro.core.router import BurstDetector
from repro.sim.instances import (Decoder, ModelCost, Prefiller, SimRequest,
                                 _priority_insert)
from repro.sim.runner import get_engine, run_policy
from repro.sim.traces import (DEFAULT_PRIORITY_MIX, TraceRequest, get_trace,
                              stream_trace)


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama31_8b")


@pytest.fixture(scope="module")
def inst():
    return InstanceSpec(CHIPS["a100"], 1)


@pytest.fixture(scope="module")
def cost(cfg):
    return ModelCost.of(cfg)


# ---------------------------------------------------------------------------
# 2000-op randomized fuzz (mirrors the KVAllocator per-op check())
# ---------------------------------------------------------------------------

def test_decoder_prefiller_aggregate_fuzz(inst, cost):
    rng = np.random.RandomState(0)
    d = Decoder(1, inst, cost, 0.0)
    p = Prefiller(2, inst, cost, 0.0, v_prefill=9000.0)
    rid = 0
    t = 0.0
    for step in range(2000):
        op = rng.randint(6)
        t += float(rng.uniform(0.0, 0.05))
        if op == 0:                                   # admit a fresh request
            r = SimRequest(TraceRequest(rid, t, int(rng.randint(32, 4096)),
                                        int(rng.randint(16, 640)),
                                        priority=int(rng.randint(3))))
            r.bucket_pred = ["S-S", "M-M", "L-L"][rng.randint(3)]
            rid += 1
            d.admit(r, t)
        elif op == 1 and d.active:                    # evict a random victim
            d.remove_active(d.active[rng.randint(len(d.active))])
        elif op == 2 and d.active:                    # fluid tick (fractional
            d.tick(t, float(rng.uniform(0.001, 0.2)))  # grants + finishes)
        elif op == 3:                                 # prefill submit
            r = SimRequest(TraceRequest(rid, t, int(rng.randint(32, 4096)),
                                        int(rng.randint(16, 640)),
                                        priority=int(rng.randint(3))))
            rid += 1
            if rng.rand() < 0.5:
                p.submit(r, t)
            else:
                d.submit_prefill(r, t)
        elif op == 4:                                 # prefill progress
            p.advance(float(rng.uniform(0.0, 5000.0)))
            d.advance_prefill(float(rng.uniform(0.0, 2000.0)), t)
        else:                                         # probe (fills caches)
            d.mem_used()
            d.iter_time()
            d.inflight_tokens()
            d.inflight_of_bucket("M-M")
            p.inflight_tokens()
        d.check_aggregates()
        p.check_aggregates()
    assert rid > 100                                  # the fuzz did work


@pytest.mark.parametrize("engine", ["fluid", "events"])
@pytest.mark.parametrize("preemption", ["evict-lowest", "pause-requeue"])
def test_e2e_aggregates_audit(engine, preemption):
    """After a contended preemption-heavy run, every instance's cached
    aggregates must equal their from-scratch recomputation."""
    cl = []
    eng_cls = get_engine(engine)

    class Audited(eng_cls):
        def _report(self, t_end):
            cl.append(self)
            return super()._report(t_end)

    from repro.core import OutputPredictor, single_pool_fleet
    from repro.core.autoscaler import build_policy
    from repro.core.fleet import PerModelFleetPolicy
    from repro.sim.runner import build_fleet
    fs = single_pool_fleet("qwen25_32b", "a100", 2, trace="burstgpt2",
                           rps=8.0, n_convertible=1,
                           priority_mix=DEFAULT_PRIORITY_MIX)
    fleet = build_fleet(fs)
    g = fleet.groups["qwen25_32b"]
    pol = build_policy("tokenscale", g.prefill.prof,
                       decode_prof=g.decode.prof, mean_in=640.0,
                       mean_out=350.0, n_convertible=1)
    eng = Audited(fleet, policy=PerModelFleetPolicy({"qwen25_32b": pol}),
                  predictor=OutputPredictor(0.85, 0), max_instances=2,
                  preemption=preemption)
    trace = get_trace("burstgpt2", 15.0, 8.0, seed=0,
                      priority_mix=DEFAULT_PRIORITY_MIX)
    eng.run(trace, 20.0)
    (run_cl,) = cl
    audited = 0
    for pool in run_cl.pools.values():
        for i in pool.instances:
            i.check_aggregates()
            audited += 1
    assert audited >= 3          # prefill + decode + convertible pools


# ---------------------------------------------------------------------------
# bisect inserts == the historical linear scan
# ---------------------------------------------------------------------------

def _reference_insert(queue, entry):
    """The pre-rework linear scan, verbatim."""
    req = entry[0]
    for j in range(1 if queue else 0, len(queue)):
        if queue[j][0].priority > req.priority:
            queue.insert(j, entry)
            return
    queue.append(entry)


def test_priority_insert_matches_reference():
    rng = np.random.RandomState(1)
    fast: list = []
    ref: list = []
    for rid in range(500):
        r = SimRequest(TraceRequest(rid, 0.0, 64, 16,
                                    priority=int(rng.randint(4))))
        _priority_insert(fast, (r, float(rid)))
        _reference_insert(ref, (r, float(rid)))
        assert [e[0].src.rid for e in fast] == [e[0].src.rid for e in ref]
        # heads pop like the engines pop them
        if rng.rand() < 0.3 and fast:
            fast.pop(0)
            ref.pop(0)


# ---------------------------------------------------------------------------
# incremental burst-detector windows == the historical rebuild/resum
# ---------------------------------------------------------------------------

class _ReferenceBurst:
    """The pre-rework list-rebuild implementation, verbatim."""

    def __init__(self, short_s=1.0, long_s=60.0):
        self.short_s, self.long_s = short_s, long_s
        self._events: list = []

    def observe(self, t, tokens):
        self._events.append((t, tokens))
        self._events = [e for e in self._events if t - e[0] <= self.long_s]

    def _short_h(self, t):
        return min(self.short_s, max(t / 2.0, 1e-3))

    def rates(self, t):
        short_h = self._short_h(t)
        short = sum(v for ts, v in self._events if t - ts <= short_h) \
            / short_h
        long_h = min(self.long_s, max(t, 1e-3))
        long = sum(v for ts, v in self._events) / long_h
        return short, long


def test_burst_detector_matches_reference():
    rng = np.random.RandomState(2)
    b = BurstDetector()
    ref = _ReferenceBurst()
    t = 0.0
    for _ in range(3000):
        t += float(rng.exponential(0.2))
        tokens = int(rng.randint(32, 8192))      # integer prompt lengths
        b.observe(t, tokens)
        ref.observe(t, tokens)
        s1, l1 = b.rates(t)
        s2, l2 = ref.rates(t)
        assert s1 == s2 and l1 == l2             # bitwise, not approx


# ---------------------------------------------------------------------------
# lazy streamed arrivals == an eager list
# ---------------------------------------------------------------------------

def test_event_engine_streaming_matches_list():
    stream = stream_trace("azure_conv", 40.0, 6.0, seed=0, chunk_s=10.0)
    eager = list(stream_trace("azure_conv", 40.0, 6.0, seed=0, chunk_s=10.0))
    assert len(eager) > 100

    def _run(trace):
        from repro.core import OutputPredictor, single_pool_fleet
        from repro.core.autoscaler import build_policy
        from repro.core.fleet import PerModelFleetPolicy
        from repro.sim.events import EventCluster
        from repro.sim.runner import build_fleet
        fs = single_pool_fleet("llama31_8b", "a100", 1, trace="azure_conv",
                               rps=6.0, n_convertible=1)
        fleet = build_fleet(fs)
        g = fleet.groups["llama31_8b"]
        pol = build_policy("tokenscale", g.prefill.prof,
                           decode_prof=g.decode.prof,
                           mean_in=1024.0, mean_out=240.0, n_convertible=1)
        cl = EventCluster(fleet,
                          policy=PerModelFleetPolicy({"llama31_8b": pol}),
                          predictor=OutputPredictor(0.85, 0))
        return cl.run(trace, duration=50.0)
    a = _run(eager)                      # list path (sorted eagerly)
    b = _run(stream_trace("azure_conv", 40.0, 6.0, seed=0, chunk_s=10.0))
    assert a.summary() == b.summary()
    assert [r.src.rid for r in a.requests] == [r.src.rid for r in b.requests]


def test_streaming_trace_requires_duration():
    from repro.sim.events import EventCluster
    from repro.core import TokenScalePolicy
    cfg = get_config("llama31_8b")
    inst = InstanceSpec(CHIPS["a100"], 1)
    prof = profile(cfg, inst)
    cl = EventCluster(cfg, inst, prof, TokenScalePolicy(prof, convertible=0))
    with pytest.raises(ValueError, match="duration"):
        cl.run(iter([]), duration=None)


def test_unsorted_stream_fails_loudly():
    """An out-of-order streaming iterator must raise, not silently
    corrupt the piecewise-constant GPU integral."""
    from repro.core import TokenScalePolicy
    cfg = get_config("llama31_8b")
    inst = InstanceSpec(CHIPS["a100"], 1)
    prof = profile(cfg, inst)
    cl = get_engine("events")(cfg, inst, prof,
                              TokenScalePolicy(prof, convertible=0))
    bad = iter([TraceRequest(0, 5.0, 64, 16), TraceRequest(1, 2.0, 64, 16)])
    with pytest.raises(ValueError, match="not sorted"):
        cl.run(bad, duration=10.0)


def test_stream_trace_is_deterministic_and_ordered():
    a = list(stream_trace("azure_code", 50.0, 5.0, seed=3, chunk_s=13.0))
    b = list(stream_trace("azure_code", 50.0, 5.0, seed=3, chunk_s=13.0))
    assert [(r.rid, r.t, r.in_len, r.out_len) for r in a] \
        == [(r.rid, r.t, r.in_len, r.out_len) for r in b]
    ts = [r.t for r in a]
    assert ts == sorted(ts)
    assert [r.rid for r in a] == list(range(len(a)))


# ---------------------------------------------------------------------------
# snapshot cadence knob
# ---------------------------------------------------------------------------

def test_snapshot_interval_knob_and_adaptive_default():
    from repro.core import ExperimentSpec, single_pool_fleet
    fs = single_pool_fleet("llama31_8b", "a100", 1, trace="azure_conv",
                           rps=4.0)
    # explicit knob: ~duration / interval rows
    spec = ExperimentSpec(fleet=fs, duration=10.0, extra_horizon=0.0,
                          engine="events", snapshot_interval=1.0)
    from repro.sim.runner import run_spec
    rep = run_spec(spec)
    assert 8 <= len(rep.timeline) <= 12
    # spec JSON stays on the pre-knob schema when the knob is unset (the
    # hetero golden's recorded spec dict must reproduce byte-identically)
    d = ExperimentSpec(fleet=fs).to_dict()
    assert "snapshot_interval" not in d
    d2 = ExperimentSpec(fleet=fs, snapshot_interval=0.5).to_dict()
    assert d2["snapshot_interval"] == 0.5
    again = ExperimentSpec.from_dict(d2)
    assert again.snapshot_interval == 0.5


def test_adaptive_snapshot_cadence_caps_timeline():
    cfg = get_config("llama31_8b")
    inst = InstanceSpec(CHIPS["a100"], 1)
    prof = profile(cfg, inst)
    from repro.core import TokenScalePolicy
    cl = get_engine("events")(cfg, inst, prof,
                              TokenScalePolicy(prof, convertible=0))
    # historical horizons keep the historical 0.2 s cadence...
    assert cl._snapshot_every(120.0) == 0.2
    assert cl._snapshot_every(800.0) == 0.2
    # ...multi-hour horizons stretch it to cap the timeline at ~4000 rows
    assert cl._snapshot_every(36000.0) == pytest.approx(9.0)


# ---------------------------------------------------------------------------
# SimReport memoization
# ---------------------------------------------------------------------------

def test_report_metric_memoization_is_stable():
    rep = run_policy("tokenscale", "azure_conv", duration=20.0, rps=6.0,
                     seed=0, engine="events")
    fresh = run_policy("tokenscale", "azure_conv", duration=20.0, rps=6.0,
                       seed=0, engine="events")
    # repeated queries hit the memo and stay bitwise equal to a fresh run
    for _ in range(2):
        assert rep.percentile("ttft", 99) == fresh.percentile("ttft", 99)
        assert rep.percentile("ttft", 99.9) == fresh.percentile("ttft", 99.9)
        assert rep.mean("tpot") == fresh.mean("tpot")
        assert rep.summary() == fresh.summary()
    # the memo key includes every filter axis
    assert rep._pool(priority=1) is rep._pool(priority=1)
    assert rep._pool(priority=1) is not rep._pool(priority=0)
