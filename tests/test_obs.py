"""Flight-recorder observability layer (repro.obs).

Pins the PR's acceptance properties:

  * span conservation — for every finished request the gap-free span
    chain sums to its recorded TTFT (first five spans) and E2E (all
    six), across both engines and four subsystem regimes (priority
    preemption, chunked deflection, locality gateway, KV tiers);
  * token reconciliation — the recorder's prefill/decode odometers
    match the SimReport's per-request aggregates;
  * schema-valid exports — JSONL (hand-rolled validator) and
    Chrome-trace JSON on both engines;
  * explainer attributions — at least one scale-up reconstructed from
    its Eq. 2-4 inputs and at least one TTFT violation attributed to
    its dominant span on the burst trace;
  * default-off purity — telemetry off leaves summaries and timelines
    identical (the golden fixtures pin byte-identity repo-wide);
  * the *_summary degradation contract and the new tail percentiles.
"""
from __future__ import annotations

import json
import math
from functools import lru_cache

import pytest

from repro.core import ExperimentSpec
from repro.obs import (FlightRecorder, SPAN_ORDER, TTFT_STAGE_LABELS,
                       chrome_trace, explain, render_report, request_spans,
                       trace_records, validate_trace_lines)
from repro.obs.export import load_jsonl, write_chrome_trace, write_jsonl
from repro.sim.instances import SimReport
from repro.sim.runner import run_policy
from repro.sim.traces import DEFAULT_PRIORITY_MIX

ENGINES = ("fluid", "events")

#: four subsystem regimes the span/token properties must hold in —
#: contended preemption, chunked prefill deflection, the locality
#: gateway with lazy paging, and the KV-tier swap/prefix stack.
SCENARIOS = {
    "preemption": dict(trace_name="burstgpt2", model="qwen25_32b", tp=2,
                       duration=18.0, rps=8.0, seed=0, max_instances=2,
                       preemption="evict-lowest",
                       priority_mix=DEFAULT_PRIORITY_MIX),
    "deflection": dict(trace_name="burstgpt1", model="llama31_8b", tp=1,
                       duration=18.0, rps=40.0, seed=0, max_instances=6,
                       prefill_chunking=2048),
    "gateway": dict(trace_name="azure_code", model="qwen25_32b", tp=2,
                    duration=15.0, rps=7.0, seed=0, max_instances=2,
                    block_size=16, gateway=True, kv_alloc="lazy",
                    prefix_cache=True, session_prob=0.4,
                    shared_prefix_prob=0.7, shared_prefix_len=1024,
                    shared_prefix_count=2),
    "lazy_kv": dict(trace_name="azure_conv", model="qwen25_32b", tp=2,
                    duration=15.0, rps=7.0, seed=0, max_instances=2,
                    block_size=16, offload_gb=12.0, prefix_cache=True,
                    session_prob=0.4, preemption="pause-requeue"),
}


@lru_cache(maxsize=None)
def traced_report(scenario: str, engine: str) -> SimReport:
    return run_policy("tokenscale", engine=engine, telemetry=True,
                      **SCENARIOS[scenario])


def _close(a: float, b: float) -> bool:
    return abs(a - b) <= 1e-9 * max(1.0, abs(a), abs(b))


# ---------------------------------------------------------------------------
# span conservation + token reconciliation (the property grid)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_span_conservation(scenario, engine):
    rep = traced_report(scenario, engine)
    rec = rep.obs
    finished = [r for r in rec.requests if r["finished"]]
    assert finished, "scenario produced no finished requests"
    for r in rec.requests:
        spans = r["spans"]
        # chain structure: lifecycle order, contiguous boundaries
        names = [s["name"] for s in spans]
        assert names == list(SPAN_ORDER[:len(names)])
        for a, b in zip(spans, spans[1:]):
            assert b["t0"] == a["t1"]
        for s in spans:
            assert s["t1"] >= s["t0"]
        if not r["finished"]:
            continue
        assert len(spans) == len(SPAN_ORDER)
        assert _close(sum(s["dur"] for s in spans[:5]), r["ttft"])
        assert _close(sum(s["dur"] for s in spans), r["e2e"])


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_token_reconciliation(scenario, engine):
    rep = traced_report(scenario, engine)
    rec = rep.obs
    exp_prefill = sum(r.prefill_tokens for r in rep.requests)
    exp_decode = sum(r.generated for r in rep.requests)
    assert abs(rec.prefill_tokens_done - exp_prefill) \
        <= 1e-6 * max(1.0, exp_prefill)
    assert abs(rec.decode_tokens_done - exp_decode) \
        <= 1e-6 * max(1.0, exp_decode)
    # request records agree with the engine-side aggregates too
    assert len(rec.requests) == len(rep.requests)
    rec_gen = sum(r["generated"] for r in rec.requests)
    assert abs(rec_gen - exp_decode) <= 1e-6 * max(1.0, exp_decode)


def test_scenarios_exercise_their_subsystems():
    """The grid actually hits the paths it claims to cover (otherwise the
    conservation properties are vacuous there)."""
    kinds_p = {e["kind"] for e in
               traced_report("preemption", "events").obs.events}
    assert "preempt" in kinds_p
    rep_d = traced_report("deflection", "events")
    assert rep_d.n_deflected > 0
    kinds_d = {e["kind"] for e in rep_d.obs.events}
    assert {"deflect", "chunk"} <= kinds_d
    rep_g = traced_report("gateway", "events")
    assert rep_g.gw_summary()["replications"] > 0
    kinds_g = {e["kind"] for e in rep_g.obs.events}
    assert "replication_planned" in kinds_g
    assert traced_report("lazy_kv", "events").kv_summary()["prefix_hits"] \
        >= 0


# ---------------------------------------------------------------------------
# exporters: JSONL + Chrome trace, schema-valid on both engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_jsonl_export_schema_valid(engine, tmp_path):
    rec = traced_report("deflection", engine).obs
    path = tmp_path / "trace.jsonl"
    n = write_jsonl(rec, str(path))
    records = load_jsonl(str(path))
    assert len(records) == n
    assert records[0]["type"] == "meta"
    assert records[0]["engine"] == engine
    assert validate_trace_lines(records) == []
    types = {r["type"] for r in records}
    assert {"meta", "decision", "request", "metrics", "totals"} <= types


@pytest.mark.parametrize("engine", ENGINES)
def test_chrome_trace_export(engine, tmp_path):
    rec = traced_report("deflection", engine).obs
    path = tmp_path / "trace.chrome.json"
    write_chrome_trace(rec, str(path))
    doc = json.loads(path.read_text())
    ev = doc["traceEvents"]
    spans = [e for e in ev if e["ph"] == "X"]
    assert spans and all(e["dur"] >= 0 and e["name"] in SPAN_ORDER
                         for e in spans)
    assert any(e["ph"] == "i" for e in ev)       # point events/decisions
    assert any(e["ph"] == "C" for e in ev)       # counter tracks
    n_spans = sum(len(r["spans"]) for r in rec.requests)
    assert len(spans) == n_spans


def test_validator_catches_corruption():
    rec = traced_report("deflection", "events").obs
    records = trace_records(rec)
    assert validate_trace_lines(records) == []
    # missing meta head
    assert validate_trace_lines(records[1:])
    # span-chain gap
    broken = json.loads(json.dumps(records))
    req = next(r for r in broken if r["type"] == "request"
               and len(r["spans"]) >= 2)
    req["spans"][1]["t0"] += 0.5
    assert any("gap" in e for e in validate_trace_lines(broken))
    # unknown span name
    broken2 = json.loads(json.dumps(records))
    req2 = next(r for r in broken2 if r["type"] == "request" and r["spans"])
    req2["spans"][0]["name"] = "warp_drive"
    assert any("malformed span" in e for e in validate_trace_lines(broken2))


# ---------------------------------------------------------------------------
# explainer: Eq. 2-4 scale-up attribution + dominant-span violations
# ---------------------------------------------------------------------------

def test_explainer_attributes_scale_up_and_violations():
    rec = traced_report("deflection", "events").obs
    report = explain(trace_records(rec))
    assert report["n_decisions"] > 0
    ups = report["scale_ups"]
    assert ups, "burst trace produced no scale-up to explain"
    with_eq = [u for u in ups if u["inputs"].get("eq2")]
    assert with_eq, "no scale-up carried Eq. 2-4 inputs"
    eq2 = with_eq[0]["inputs"]["eq2"]
    for key in ("token_rate_in", "deflected_rate", "rate", "v_prefill",
                "v_network", "v_eff", "i_p"):
        assert key in eq2
    assert with_eq[0]["inputs"]["eq3"]["rate_by_bucket"]
    # Eq. 2 arithmetic is internally consistent in the recorded inputs
    assert _close(eq2["rate"],
                  max(eq2["token_rate_in"] - eq2["deflected_rate"], 0.0))
    assert eq2["v_eff"] == min(eq2["v_prefill"], eq2["v_network"])
    vio = report["violations"]
    assert vio, "saturated burst fleet produced no TTFT violations"
    v = vio[0]
    assert v["ttft"] > v["slo"]
    assert v["dominant"] in TTFT_STAGE_LABELS
    assert v["stage"] == TTFT_STAGE_LABELS[v["dominant"]]
    assert v["spans"][v["dominant"]] == max(v["spans"].values())
    assert report["violations_by_stage"]


def test_render_report_shows_eq_arithmetic():
    rec = traced_report("deflection", "events").obs
    text = render_report(explain(trace_records(rec)))
    assert "Eq.2" in text and "v_eff" in text
    assert "## scale-ups" in text
    assert "## TTFT SLO violations" in text
    assert "dominant stage" in text


# ---------------------------------------------------------------------------
# default-off purity + spec plumbing
# ---------------------------------------------------------------------------

def test_telemetry_off_is_identical():
    cfg = dict(trace_name="azure_conv", duration=12.0, rps=6.0, seed=0)
    for engine in ENGINES:
        off = run_policy("tokenscale", engine=engine, **cfg)
        on = run_policy("tokenscale", engine=engine, telemetry=True, **cfg)
        assert off.obs is None and on.obs is not None
        off_s, on_s = off.summary(), on.summary()
        assert off_s == on_s
        # timeline rows: identical stock keys; telemetry adds only "obs"
        assert len(off.timeline) == len(on.timeline)
        for a, b in zip(off.timeline, on.timeline):
            assert set(b) - set(a) == {"obs"}
            assert a == {k: v for k, v in b.items() if k != "obs"}


def test_spec_telemetry_field_roundtrip():
    from repro.core.fleet import single_pool_fleet
    fs = single_pool_fleet("llama31_8b", "a100", 1)
    # default-off serializes away (old spec JSON stays stable)
    d = ExperimentSpec(fleet=fs, duration=5.0).to_dict()
    assert "telemetry" not in d
    spec = ExperimentSpec(fleet=fs, duration=5.0, telemetry=True)
    back = ExperimentSpec.from_json(spec.to_json())
    assert back.telemetry is True
    assert back == spec


# ---------------------------------------------------------------------------
# satellite: new tail percentiles + *_summary degradation contract
# ---------------------------------------------------------------------------

def test_summary_gains_tail_percentiles():
    rep = traced_report("deflection", "events")
    s = rep.summary()
    assert s["tpot_p99"] == rep.percentile("tpot", 99)
    assert s["ttft_p999"] == rep.percentile("ttft", 99.9)
    assert s["ttft_p999"] >= s["ttft_p99"]


def test_summary_helpers_degrade_to_zero_valued_schemas():
    rep = SimReport(name="empty", requests=[], gpu_seconds=0.0,
                    duration=1.0)
    cs = rep.class_summary(0)
    assert cs == {"n": 0, "slo_attainment": 0.0, "ttft_p99": 0.0,
                  "tpot_p99": 0.0}
    ms = rep.model_summary("nope")
    assert ms["n"] == 0 and set(ms) == {
        "n", "slo_attainment", "ttft_attainment", "tpot_attainment",
        "throughput", "ttft_p99"}
    assert all(v == 0 for v in ms.values())
    kv = rep.kv_summary()
    assert kv and all(v == 0 for v in kv.values())
    gw = rep.gw_summary()
    assert gw and all(v == 0 for v in gw.values())
    # the populated schemas carry the same key sets (no schema forks)
    full = traced_report("preemption", "events")
    assert set(full.class_summary(0)) == set(cs)
    assert set(full.model_summary("qwen25_32b")) == set(ms)


def test_unfinished_request_spans_are_valid_prefix():
    """A request cut off mid-flight yields a truncated-but-contiguous
    chain (negative sentinel timestamps never leak into spans)."""
    class Src:
        t, rid, in_len, out_len = 1.0, 7, 128, 64
    class Req:
        src = Src()
        t_prefill_start, t_prefill_end = 1.5, 2.0
        t_kv_ready, t_decode_start = 2.1, -1.0
        t_first_token, t_finish = -1.0, -1.0
    spans = request_spans(Req())
    assert [s["name"] for s in spans] == ["queue_wait", "prefill",
                                         "kvc_transfer"]
    assert all(s["dur"] >= 0 for s in spans)


def test_recorder_meta_reaches_trace_head(tmp_path):
    rep = traced_report("preemption", "fluid")
    rec = rep.obs
    assert rec.engine == "fluid"
    head = trace_records(rec)[0]
    assert head["type"] == "meta"
    assert head["policy"] == "tokenscale"
    assert "dt" in head and "duration" in head
