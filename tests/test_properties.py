"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.convertible import burst_ratio_of_trace
from repro.core.predictor import OutputPredictor
from repro.core.velocity import (BUCKETS, bucket_of,
                                 convertible_prefill_velocity,
                                 reserved_memory)


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 20000), st.integers(1, 2000))
def test_bucket_of_total(in_len, out_len):
    """Every (in, out) maps to exactly one of the 9 buckets."""
    b = bucket_of(in_len, out_len)
    assert b in BUCKETS


@settings(max_examples=30, deadline=None)
@given(st.floats(0.0, 1.0))
def test_predictor_accuracy_converges(acc):
    pred = OutputPredictor(accuracy=acc, seed=1)
    for i in range(400):
        pred.predict_bucket(100 + i % 5000, 50 + i % 500)
    assert abs(pred.measured_accuracy - acc) < 0.1


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 4096), st.integers(0, 512),
       st.floats(0.01, 1.0))
def test_eq5_nonnegative_and_monotone(chunk, batch, slo):
    v = convertible_prefill_velocity(chunk, batch, slo)
    assert v >= 0.0
    assert convertible_prefill_velocity(chunk + 128, batch, slo) >= v


@settings(max_examples=30, deadline=None)
@given(st.floats(0, 1e6), st.floats(0, 1e6), st.floats(0, 10))
def test_eq6_scales_linearly(v, mem_t, slo):
    assert reserved_memory(v, mem_t, slo) == pytest.approx(v * mem_t * slo)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 100), st.floats(1, 1000)),
                min_size=1, max_size=200))
def test_burst_ratio_bounded(arrivals):
    r = burst_ratio_of_trace(arrivals)
    assert 0.0 <= r <= 1.0


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 64), st.integers(1, 64))
def test_data_pipeline_pure_function_of_index(idx, b, s):
    from repro.training import DataConfig, PackedDataset
    dc = DataConfig(vocab_size=128, seq_len=s, global_batch=b, seed=3)
    t1, l1 = PackedDataset(dc).batch(idx)
    t2, l2 = PackedDataset(dc).batch(idx)
    assert np.array_equal(t1, t2)
    assert t1.shape == (b, s) and l1.shape == (b, s)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(1, 10), st.integers(1, 2),
       st.sampled_from([8, 16]))
def test_wkv6_zero_key_is_identity(b, s, h, k):
    """k=0 writes nothing: state must equal decayed initial state."""
    import jax.numpy as jnp
    from repro.kernels.ops import wkv6_op
    rng = np.random.RandomState(b * s)
    r = jnp.asarray(rng.randn(b, s, h, k).astype(np.float32))
    kk = jnp.zeros((b, s, h, k), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, h, k).astype(np.float32))
    w = jnp.full((b, s, h, k), 0.5, jnp.float32)
    u = jnp.asarray(rng.randn(h, k).astype(np.float32))
    s0 = jnp.asarray(rng.randn(b, h, k, k).astype(np.float32))
    y, sT = wkv6_op(r, kk, v, w, u, s0)
    want = np.asarray(s0) * (0.5 ** s)
    np.testing.assert_allclose(np.asarray(sT), want, atol=1e-4, rtol=1e-4)


def test_scheduler_never_oversubscribes_slots():
    """Engine invariant: active slots never exceed num_slots, queue drains."""
    import jax
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import Engine, Request
    cfg = get_config("qwen2_0_5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, num_slots=2, max_len=48)
    rng = np.random.RandomState(1)
    reqs = [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       size=(int(rng.randint(3, 20)),)
                                       ).astype(np.int32),
                    max_new_tokens=4)
            for i in range(7)]
    for r in reqs:
        eng.add_request(r)
        assert int(eng.active.sum()) <= 2
    steps = 0
    while eng.active.any() or eng.waiting or eng.pending_chunked:
        eng.step()
        assert int(eng.active.sum()) <= 2
        steps += 1
        assert steps < 500
    assert all(len(r.output) == 4 for r in reqs)
