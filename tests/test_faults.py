"""Chaos-engine tests: seeded fault injection + the self-healing path.

Covers the determinism contract (the fault substream never perturbs
arrivals — the byte-identical-goldens construction), the schedule/target
machinery (``sim.faults``), the ``fault_summary`` degradation contract,
the spec round trip, conservation under randomized crash schedules on
both engines (every arrival finishes or is accounted exactly once, KV
allocators audit clean), and the recovery gradient the
``chaos_recovery.json`` golden pins.
"""
import json
import os

import numpy as np
import pytest

from repro.core import ExperimentSpec, OutputPredictor, PerModelFleetPolicy
from repro.core import fleet as fleet_mod
from repro.core.autoscaler import build_policy
from repro.core.fleet import single_pool_fleet
from repro.sim.faults import (FAULT_KINDS, FaultConfig, FaultStats,
                              HealthMonitor, build_schedule, pick_target)
from repro.sim.runner import build_fleet, build_traces, get_engine, run_policy
from repro.sim.traces import SALT_FAULTS, substream, trace_stats

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "chaos_recovery.json")

#: a small-but-busy fault mix used across the run-level tests
FAULTS = dict(seed=0, crashes=2, stragglers=1, swap_degrades=1,
              link_outages=1, t0=6.0)


# ---------------------------------------------------------------------------
# schedule / target machinery
# ---------------------------------------------------------------------------

def test_build_schedule_deterministic_and_sorted():
    cfg = FaultConfig(seed=7, crashes=3, stragglers=2, swap_degrades=2,
                      link_outages=1)
    a = build_schedule(cfg, 60.0)
    b = build_schedule(cfg, 60.0)
    assert [(e.t, e.kind, e.role, e.pick) for e in a] == \
        [(e.t, e.kind, e.role, e.pick) for e in b]
    assert len(a) == 8
    assert all(a[i].t <= a[i + 1].t for i in range(len(a) - 1))
    for e in a:
        assert e.kind in FAULT_KINDS


def test_build_schedule_window():
    cfg = FaultConfig(seed=1, crashes=10, t0=5.0)
    for e in build_schedule(cfg, 100.0):
        assert 5.0 <= e.t <= 60.0          # t1 defaults to 60% of horizon
    cfg = FaultConfig(seed=1, crashes=10, t0=5.0, t1=12.0)
    for e in build_schedule(cfg, 100.0):
        assert 5.0 <= e.t <= 12.0


def test_build_schedule_uses_independent_substream():
    """The schedule draw consumes only the SALT_FAULTS stream — drawing
    it must not advance any other stream's state (independence is by
    construction: separate RandomState objects)."""
    probe = substream(3, SALT_FAULTS)
    expect = [float(probe.random_sample()) for _ in range(4)]
    rng = np.random.RandomState((3 + SALT_FAULTS) % (2 ** 31))
    assert [float(rng.random_sample()) for _ in range(4)] == expect


class _Inst:
    def __init__(self, iid):
        self.iid = iid


def test_pick_target():
    insts = [_Inst(3), _Inst(1), _Inst(2)]
    import dataclasses
    from repro.sim.faults import FaultEvent
    ev = FaultEvent(t=0.0, kind="crash", pick=0.0)
    assert pick_target(ev, insts).iid == 1          # sorted by iid
    assert pick_target(dataclasses.replace(ev, pick=0.999), insts).iid == 3
    assert pick_target(dataclasses.replace(ev, pick=0.5), insts).iid == 2
    assert pick_target(ev, []) is None


def test_config_validation():
    with pytest.raises(ValueError):
        FaultConfig(crashes=-1)
    with pytest.raises(ValueError):
        FaultConfig(straggler_factor=0.0)
    with pytest.raises(ValueError):
        FaultConfig(swap_factor=1.5)
    with pytest.raises(ValueError):
        FaultConfig(roles=("convertible",))
    with pytest.raises(ValueError):
        FaultConfig.from_dict({"crashes": 1, "nonsense": True})
    assert FaultConfig.from_dict({"crashes": 1}).crashes == 1


def test_health_monitor_detects_at_next_probe():
    hm = HealthMonitor(cadence=1.0)
    assert hm.detect_at(3.2) == 4.0
    assert hm.detect_at(4.0) == 5.0        # never the same instant
    assert hm.detections == 2
    assert hm.restart_at(4.0, 5.0, 0.8) == pytest.approx(8.0)


def test_fault_stats_summary_schema():
    s = FaultStats().summary()
    assert all(v == 0 for v in s.values())
    assert set(s) == {"crashes", "restarts", "residents_requeued",
                      "prefill_requeued", "kvc_retries",
                      "kvc_retry_backoff_s", "kvc_fallbacks",
                      "straggler_windows", "swap_degrade_windows",
                      "link_down_windows", "skipped"}


# ---------------------------------------------------------------------------
# spec plumbing
# ---------------------------------------------------------------------------

def test_spec_faults_roundtrip():
    fs = single_pool_fleet(trace="azure_conv", rps=4.0)
    spec = ExperimentSpec(fleet=fs, duration=10.0, faults=dict(FAULTS))
    d = spec.to_dict()
    assert d["faults"] == FAULTS
    back = ExperimentSpec.from_dict(json.loads(json.dumps(d)))
    assert back.faults == FAULTS
    # faults unset (or falsy) -> the pre-chaos schema, byte-for-byte
    off = ExperimentSpec(fleet=fs, duration=10.0)
    assert "faults" not in off.to_dict()
    assert "faults" not in ExperimentSpec(fleet=fs, duration=10.0,
                                          faults={}).to_dict()


def test_core_fleet_reexports_health_monitor():
    """The control-plane pieces are reachable from the fleet layer
    (lazily, to avoid the core<->sim import cycle)."""
    assert fleet_mod.HealthMonitor is HealthMonitor
    assert fleet_mod.FaultConfig is FaultConfig
    with pytest.raises(AttributeError):
        fleet_mod.NoSuchThing


# ---------------------------------------------------------------------------
# arrivals stay byte-identical (the substream contract, satellite of PR 10)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["events", "fluid"])
def test_faults_do_not_perturb_arrivals(engine):
    """Same seed, faults on vs off: the arrival stream — times, lengths,
    priorities, session assignment — is identical, because the fault
    schedule draws from its own RNG substream."""
    kw = dict(duration=20.0, rps=6.0, seed=4, engine=engine,
              priority_mix={0: 0.3, 1: 0.7}, session_prob=0.3)
    off = run_policy("tokenscale", "azure_conv", **kw)
    on = run_policy("tokenscale", "azure_conv", faults=dict(FAULTS), **kw)
    key = lambda rep: sorted(
        (r.src.rid, r.src.t, r.src.in_len, r.src.out_len, r.priority,
         r.src.session, r.src.prefix_len)
        for r in rep.requests)
    assert key(off) == key(on)


# ---------------------------------------------------------------------------
# fault_summary degradation contract
# ---------------------------------------------------------------------------

def test_fault_summary_degrades_to_zero_schema():
    rep = run_policy("tokenscale", "azure_conv", duration=10.0, rps=4.0,
                     engine="events")
    s = rep.fault_summary()
    assert s == FaultStats().summary()
    assert rep.faults == {}


def test_fault_summary_counts_injections():
    rep = run_policy("tokenscale", "azure_conv", duration=25.0, rps=6.0,
                     seed=0, engine="events", faults=dict(FAULTS))
    s = rep.fault_summary()
    assert set(s) == set(FaultStats().summary())
    fired = s["crashes"] + s["straggler_windows"] + \
        s["swap_degrade_windows"] + s["link_down_windows"] + s["skipped"]
    assert fired == 5                      # every scheduled event resolved
    assert s["restarts"] == s["crashes"]   # recovery defaults on


# ---------------------------------------------------------------------------
# conservation under randomized crash schedules (both engines)
# ---------------------------------------------------------------------------

def _run_cluster(engine, faults, seed, duration=22.0, rps=8.0):
    """Build the engine by hand (mirroring run_spec) so the test can
    audit cluster internals after the run."""
    fleet_spec = single_pool_fleet("llama31_8b", "a100", 1,
                                   trace="burstgpt1", rps=rps,
                                   n_convertible=1,
                                   priority_mix={0: 0.2, 1: 0.6, 2: 0.2},
                                   block_size=16, prefix_cache=True)
    spec = ExperimentSpec(fleet=fleet_spec, policy="tokenscale",
                          engine=engine, preemption="evict-lowest",
                          duration=duration, seed=seed, faults=faults)
    fleet = build_fleet(spec.fleet, max_decoders=spec.max_instances)
    trace = build_traces(spec)
    stats = trace_stats(trace)
    policies = {}
    for model, g in fleet.groups.items():
        policies[model] = build_policy(
            spec.policy, g.prefill.prof, decode_prof=g.decode.prof,
            mean_in=stats.mean_in, mean_out=stats.mean_out,
            n_convertible=g.convertible.spec.init if g.convertible else 0)
    cl = get_engine(engine)(
        fleet, policy=PerModelFleetPolicy(policies),
        predictor=OutputPredictor(spec.predictor_accuracy, spec.seed),
        dt=spec.dt, preemption=spec.preemption,
        max_instances=spec.max_instances, faults=spec.faults)
    rep = cl.run(trace, spec.duration + spec.extra_horizon)
    return cl, rep, trace


@pytest.mark.parametrize("engine", ["events", "fluid"])
@pytest.mark.parametrize("recovery", [True, False])
def test_conservation_under_crashes(engine, recovery):
    """Randomized crash/straggler schedules: every arrival is accounted
    exactly once (finished or in flight at the horizon — crashes neither
    drop nor duplicate requests), and every live KV allocator + instance
    aggregate audits clean after the run."""
    total_crashes = 0
    for fseed in (0, 11, 23):
        faults = dict(seed=fseed, crashes=3, stragglers=1, swap_degrades=1,
                      link_outages=1, t0=4.0, recovery=recovery)
        cl, rep, trace = _run_cluster(engine, faults, seed=fseed)
        rids = [r.src.rid for r in rep.requests]
        assert len(rids) == len(set(rids)), (engine, recovery, fseed)
        assert len(rids) == len(trace), (engine, recovery, fseed)
        for inst in cl.prefillers + cl.decoders + cl.convertibles:
            inst.check_aggregates()
            if getattr(inst, "kv", None) is not None:
                inst.kv.check()
        total_crashes += cl.fault_stats.crashes
    assert total_crashes > 0               # the fuzz actually crashed boxes


@pytest.mark.parametrize("engine", ["events", "fluid"])
def test_crash_frees_kv_and_reenters_with_prefix_reuse(engine):
    """A decode crash purges the box's allocator (audits clean, empty)
    and its residents re-enter decode exactly once — finished output
    token counts are exact on the event engine even for requeued
    residents."""
    faults = dict(seed=19, crashes=2, stragglers=0, t0=4.0,
                  roles=("decode",), recovery=True)
    cl, rep, trace = _run_cluster(engine, faults, seed=19)
    assert cl.fault_stats.crashes >= 1
    assert cl.fault_stats.restarts == cl.fault_stats.crashes
    assert cl.fault_stats.residents_requeued >= 1
    if engine == "events":
        for r in rep.requests:
            if r.t_finish >= 0:
                assert float(r.generated).is_integer()
                assert int(r.generated) == r.src.out_len


# ---------------------------------------------------------------------------
# the recovery gradient (the chaos_recovery.json acceptance)
# ---------------------------------------------------------------------------

def test_golden_pins_recovery_gradient():
    """The committed golden shows recovery-on strictly beating
    recovery-off on class-0 SLO attainment AND p99 TTFT on both engines
    (regen_golden.py asserts the same at regeneration time, so the
    fixture can never pin a regression)."""
    g = json.load(open(GOLDEN))
    for eng, rows in g["engines"].items():
        rec, blind = rows["recovery"], rows["norecovery"]
        assert rec["class0"]["slo_attainment"] > \
            blind["class0"]["slo_attainment"], eng
        assert rec["ttft_p99"] < blind["ttft_p99"], eng
        assert rec["faults"]["restarts"] == rec["faults"]["crashes"] > 0
        assert blind["faults"]["restarts"] == 0


def test_straggler_feeds_measured_velocity():
    """Under a straggler window with recovery on, the planner sees the
    pool's measured effective velocity (PoolSnapshot.eff_perf < 1) and
    inflates targets; the run completes with the window opened and
    closed."""
    faults = dict(seed=19, crashes=0, stragglers=2, straggler_dur=8.0,
                  t0=4.0, recovery=True)
    rep = run_policy("tokenscale", "burstgpt1", duration=30.0, rps=8.0,
                     seed=0, engine="events", faults=faults)
    s = rep.fault_summary()
    assert s["straggler_windows"] + s["skipped"] == 2
    assert s["straggler_windows"] >= 1
