"""Sharding rules, param/state axis trees, and the HLO roofline parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.mesh import act_rules, needs_fsdp, param_rules
from repro.launch.roofline import (analyze_hlo, model_flops_estimate,
                                   parse_hlo, _shape_bytes)
from repro.models.params import (abstract_params, abstract_state, param_axes,
                                 state_axes)
from repro.sharding import DEFAULT_RULES, logical_to_pspec


def test_logical_to_pspec_basic():
    rules = {"batch": ("pod", "data"), "heads": ("model",), "embed": ()}
    assert logical_to_pspec(("batch", None, "heads"), rules) \
        == P(("pod", "data"), None, "model")
    assert logical_to_pspec(("embed",), rules) == P()


def test_logical_to_pspec_divisibility_drop():
    """4 KV heads cannot shard over a 16-way model axis -> replicated."""
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((1,), ("model",))
    rules = {"kv_heads": ("model",)}

    class FakeMesh:
        shape = {"model": 16}
    spec = logical_to_pspec(("kv_heads",), rules, shape=(4,), mesh=FakeMesh())
    assert spec == P()
    spec = logical_to_pspec(("kv_heads",), rules, shape=(32,),
                            mesh=FakeMesh())
    assert spec == P("model")


def test_logical_to_pspec_no_axis_reuse():
    rules = {"batch": ("data",), "ctx": ("data", "model")}
    spec = logical_to_pspec(("batch", "ctx"), rules)
    # "data" already used by batch -> ctx keeps only "model"
    assert spec == P("data", "model")


@pytest.mark.parametrize("arch", ["llama31_8b", "kimi_k2_1t_a32b",
                                  "jamba_v0_1_52b", "rwkv6_3b"])
def test_param_axes_structure_matches_params(arch):
    cfg = get_config(arch, smoke=True)
    pa = abstract_params(cfg)
    ax = param_axes(cfg)
    ta = jax.tree.structure(pa)
    tb = jax.tree.structure(ax, is_leaf=lambda x: isinstance(x, tuple))
    assert ta == tb
    for leaf, axes in zip(jax.tree.leaves(pa),
                          jax.tree.leaves(ax, is_leaf=lambda x:
                                          isinstance(x, tuple))):
        assert len(leaf.shape) == len(axes)


def test_state_axes_structure_matches_state():
    cfg = get_config("jamba_v0_1_52b", smoke=True)
    st = abstract_state(cfg, 2, 8)
    ax = state_axes(cfg, 2, 8)
    assert jax.tree.structure(st) == jax.tree.structure(
        ax, is_leaf=lambda x: isinstance(x, tuple))


def test_needs_fsdp():
    assert needs_fsdp(get_config("kimi_k2_1t_a32b"),
                      INPUT_SHAPES["decode_32k"])
    assert not needs_fsdp(get_config("qwen2_0_5b"),
                          INPUT_SHAPES["decode_32k"])
    # small trains fit TP-only (12 B/param); frontier trains must FSDP
    assert not needs_fsdp(get_config("qwen2_0_5b"), INPUT_SHAPES["train_4k"])
    assert needs_fsdp(get_config("kimi_k2_1t_a32b"),
                      INPUT_SHAPES["train_4k"])
    assert needs_fsdp(get_config("qwen25_32b"), INPUT_SHAPES["train_4k"])


def test_long_context_rules_use_context_parallelism():
    r = act_rules(INPUT_SHAPES["long_500k"], multi_pod=False)
    assert r["batch"] == ()
    assert "data" in r["ctx"]


# ---------------------------------------------------------------------------
# HLO roofline parser
# ---------------------------------------------------------------------------

HLO_SAMPLE = """\
HloModule test, num_partitions=8

%body.1 (p: (s32[], f32[4,64])) -> (s32[], f32[4,64]) {
  %p = (s32[], f32[4,64]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4,64]{1,0} get-tuple-element(%p), index=1
  %ag = f32[4,256]{1,0} all-gather(%x), channel_id=1, replica_groups=[2,4]<=[8], dimensions={1}
  %w = f32[256,64]{1,0} constant({...})
  %dot = f32[4,64]{1,0} dot(%ag, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[4,64]{1,0}) tuple(%i, %dot)
}

%cond.1 (p2: (s32[], f32[4,64])) -> pred[] {
  %p2 = (s32[], f32[4,64]{1,0}) parameter(0)
  ROOT %lt = pred[] constant(false)
}

ENTRY %main (a: f32[4,64]) -> f32[4,64] {
  %a = f32[4,64]{1,0} parameter(0)
  %c = s32[] constant(0)
  %tup = (s32[], f32[4,64]{1,0}) tuple(%c, %a)
  %wh = (s32[], f32[4,64]{1,0}) while(%tup), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[4,64]{1,0} get-tuple-element(%wh), index=1
}
"""


def test_parse_hlo_trip_counts():
    mod = parse_hlo(HLO_SAMPLE)
    assert mod.entry == "main"
    mult = mod.multipliers()
    assert mult["body.1"] == 7.0


def test_analyze_hlo_flops_and_collectives():
    counts = analyze_hlo(HLO_SAMPLE)
    # dot: 2 * |out|(4*64) * K(256) per iteration x 7
    assert counts.flops == pytest.approx(2 * 4 * 64 * 256 * 7)
    # all-gather output 4*256*4B x 7 iterations
    assert counts.collective_bytes == pytest.approx(4 * 256 * 4 * 7)
    assert counts.collective_counts["all-gather"] == 7


def test_shape_bytes():
    assert _shape_bytes("f32[4,64]{1,0}") == 4 * 64 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(s32[], f32[4,4]{1,0})") == 4 + 64
    assert _shape_bytes("pred[7]") == 7


def test_model_flops_estimate():
    cfg = get_config("llama31_8b")
    tr = model_flops_estimate(cfg, INPUT_SHAPES["train_4k"])
    de = model_flops_estimate(cfg, INPUT_SHAPES["decode_32k"])
    n = cfg.param_counts()["active"]
    assert tr == pytest.approx(6 * n * 256 * 4096)
    assert de == pytest.approx(2 * n * 128)


def test_moe_active_vs_total_params():
    cfg = get_config("kimi_k2_1t_a32b")
    pc = cfg.param_counts()
    assert pc["total"] > 0.9e12            # the 1T class
    assert pc["active"] < 0.05 * pc["total"]   # top-8 of 384


def test_dryrun_results_file_complete():
    """The sweep artifact must cover every (arch x shape x mesh) pair with
    either ok or a documented skip (deliverable e)."""
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "..",
                        "results_dryrun.jsonl")
    if not os.path.exists(path):
        pytest.skip("dry-run sweep not yet executed")
    recs = {}
    for line in open(path):
        r = json.loads(line)
        recs[(r["arch"], r["shape"], r["mesh"])] = r["status"]
    from repro.configs import ARCH_IDS
    missing, errors = [], []
    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES:
            for mesh in ("16x16", "2x16x16"):
                s = recs.get((arch, shape, mesh))
                if s is None:
                    missing.append((arch, shape, mesh))
                elif s == "error":
                    errors.append((arch, shape, mesh))
    assert not missing, missing
    assert not errors, errors
