"""Per-architecture smoke tests (deliverable f).

Every assigned architecture instantiates its REDUCED variant (<=2 layers,
d_model<=512, <=4 experts) and runs one forward + one train step + one
prefill/decode step on CPU, asserting output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (decode_step, forward_train, init_params,
                          init_state, prefill)
from repro.training import AdamWConfig, adamw_init, make_train_step

B, S = 2, 16


def _inputs(cfg, key=1):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0,
                              cfg.vocab_size)
    ie = None
    if cfg.num_vision_tokens:
        ie = jax.random.normal(
            jax.random.PRNGKey(key + 1),
            (B, cfg.num_vision_tokens, cfg.d_model), jnp.float32)
    return toks, ie


@pytest.fixture(scope="module")
def smoke(request):
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks, ie = _inputs(cfg)
    logits, aux = forward_train(cfg, params, toks, image_embeds=ie)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.slow
def test_one_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(total_steps=10, warmup_steps=2)
    opt = adamw_init(params, opt_cfg)
    step = make_train_step(cfg, opt_cfg, donate=False)
    toks, ie = _inputs(cfg)
    labels = jnp.roll(toks, -1, axis=1)
    if ie is None:
        params2, opt2, m = step(params, opt, toks, labels)
    else:
        params2, opt2, m = step(params, opt, toks, labels, ie)
    assert bool(jnp.isfinite(m["loss"]))
    assert bool(jnp.isfinite(m["grad_norm"]))
    # parameters actually changed
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     params, params2)
    assert max(jax.tree.leaves(d)) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.slow
def test_prefill_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks, ie = _inputs(cfg)
    lengths = jnp.array([S, S - 5], jnp.int32)
    st = init_state(cfg, B, S + 8)
    logits, st = prefill(cfg, params, st, toks, lengths, image_embeds=ie)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    dl, st = decode_step(cfg, params, st, nxt, lengths)
    assert dl.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(dl).all())


@pytest.mark.parametrize("arch", ["llama31_8b", "deepseek_v2_lite_16b",
                                  "rwkv6_3b", "jamba_v0_1_52b",
                                  "gemma2_9b", "musicgen_large"])
@pytest.mark.slow
def test_decode_matches_train_forward(arch):
    """KV-cache/recurrent-state decode must reproduce the full causal
    forward position by position."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks, ie = _inputs(cfg)
    full, _ = forward_train(cfg, params, toks, image_embeds=ie)
    P0 = 10
    st = init_state(cfg, B, S + 4)
    lengths = jnp.full((B,), P0, jnp.int32)
    pl, st = prefill(cfg, params, st, toks[:, :P0], lengths, image_embeds=ie)
    np.testing.assert_allclose(np.asarray(pl), np.asarray(full[:, P0 - 1]),
                               atol=2e-4, rtol=2e-4)
    cur = lengths
    for t in range(P0, S):
        dl, st = decode_step(cfg, params, st, toks[:, t], cur)
        cur = cur + 1
        np.testing.assert_allclose(np.asarray(dl), np.asarray(full[:, t]),
                                   atol=2e-4, rtol=2e-4)
