"""Priority classes + preemption/eviction (PR-2 tentpole).

Trace-level priority assignment, per-class SLOs and report slicing,
priority-ordered queues, and the event-engine preemption properties the
ISSUE names: no finished request is ever evicted, eviction conserves
requests, victims are strictly lower priority than their preemptor, and
every paused/evicted request either finishes or survives to the horizon.

The contention scenario mirrors ``benchmarks/run.py --bench=tails``: a
memory-tight qwen25-32B TP2 fleet capped at 2 instances, where HBM
backpressure actually occurs.
"""
import numpy as np
import pytest

from repro.core.router import tpot_slo, ttft_slo
from repro.sim.instances import PreemptionPolicy
from repro.sim.runner import run_policy
from repro.sim.traces import (DEFAULT_PRIORITY_MIX, PRIORITY_CLASSES,
                              generate, get_trace, TRACES)

MIX = DEFAULT_PRIORITY_MIX
# 22 s keeps the module tier-1-fast while still saturating the fleet (the
# first backpressure hits ~13 s in); the longer 30 s run is pinned by the
# per-class golden in tests/test_golden_policy.py
CONTENTION = dict(model="qwen25_32b", tp=2, duration=22.0, rps=8.0, seed=0,
                  max_instances=2, priority_mix=MIX)


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------

def test_priority_mix_is_deterministic_and_calibrated():
    a = generate(TRACES["azure_conv"], 200.0, 10.0, seed=3,
                 priority_mix=MIX)
    b = generate(TRACES["azure_conv"], 200.0, 10.0, seed=3,
                 priority_mix=MIX)
    assert [r.priority for r in a] == [r.priority for r in b]
    fracs = {c: np.mean([r.priority == c for r in a]) for c in MIX}
    for c, want in MIX.items():
        assert abs(fracs[c] - want) < 0.1, (c, fracs[c], want)


def test_priority_mix_does_not_perturb_arrivals():
    """The priority draw uses an independent RNG stream: the same seed
    yields byte-identical times/lengths with or without a mix."""
    plain = generate(TRACES["burstgpt1"], 60.0, 8.0, seed=5)
    mixed = generate(TRACES["burstgpt1"], 60.0, 8.0, seed=5,
                     priority_mix=MIX)
    assert [(r.t, r.in_len, r.out_len) for r in plain] \
        == [(r.t, r.in_len, r.out_len) for r in mixed]
    assert all(r.priority == 1 for r in plain)       # default: standard


def test_mixed_and_step_traces_take_priority_mix():
    from repro.sim.traces import step_trace
    mixed = get_trace("mixed", 30.0, 8.0, seed=0, priority_mix=MIX)
    step = step_trace(20.0, 2.0, 10.0, 5.0, 5.0, seed=0, priority_mix=MIX)
    for trace in (mixed, step):
        assert {r.priority for r in trace} <= set(MIX)
        assert len({r.priority for r in trace}) > 1


# ---------------------------------------------------------------------------
# per-class SLOs
# ---------------------------------------------------------------------------

def test_per_class_slo_scaling():
    interactive = PRIORITY_CLASSES["interactive"]
    batch = PRIORITY_CLASSES["batch"]
    assert ttft_slo(512) == ttft_slo(512, interactive)
    assert ttft_slo(512, batch) == 4.0 * ttft_slo(512)
    assert tpot_slo(batch) == 4.0 * tpot_slo()
    # unknown classes fall back to the standard targets
    assert ttft_slo(512, priority=7) == ttft_slo(512)


def test_preemption_policy_validation():
    assert not PreemptionPolicy("none").enabled
    assert PreemptionPolicy("evict-lowest").enabled
    assert PreemptionPolicy.of("pause-requeue").mode == "pause-requeue"
    with pytest.raises(ValueError):
        PreemptionPolicy("drop-random")


# ---------------------------------------------------------------------------
# preemption properties (event engine, contended fleet)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=["evict-lowest", "pause-requeue"])
def contended(request):
    rep = run_policy("tokenscale", "burstgpt2", engine="events",
                     preemption=request.param, **CONTENTION)
    return rep


def test_preemption_actually_fires(contended):
    assert len(contended.preemptions) > 0


def test_victims_strictly_lower_priority(contended):
    for t, victim_pri, preemptor_pri, generated in contended.preemptions:
        assert victim_pri > preemptor_pri
        assert generated >= 0.0


def test_no_finished_request_evicted(contended):
    """Victim selection skips finished work: every eviction is logged
    before the victim's finish, and a finished+evicted request still ends
    with exactly ``out_len`` tokens (no token was clawed back)."""
    evicted = [r for r in contended.requests if r.n_evictions > 0]
    assert evicted
    for r in evicted:
        if r.t_finish >= 0:
            assert r.generated == r.src.out_len


def test_eviction_conserves_requests(contended):
    arrived = sum(1 for t in get_trace("burstgpt2",
                                       CONTENTION["duration"],
                                       CONTENTION["rps"],
                                       CONTENTION["seed"],
                                       priority_mix=MIX)
                  if t.t < contended.duration)
    assert len(contended.requests) == arrived
    assert len(contended.requests) == len({id(r)
                                           for r in contended.requests})


def test_evicted_requests_finish_or_survive(contended):
    """Paused/evicted requests eventually finish or are still tracked in
    flight at the horizon — none vanish."""
    evicted = [r for r in contended.requests if r.n_evictions > 0]
    assert evicted
    finished = [r for r in evicted if r.t_finish >= 0]
    assert finished                       # some preempted work completes
    for r in finished:
        assert float(r.generated).is_integer()
        assert int(r.generated) == r.src.out_len


def test_interactive_class_never_evicted_under_default_mix(contended):
    """With classes {0,1,2}, class 0 has no strictly-higher preemptor."""
    for _, victim_pri, _, _ in contended.preemptions:
        assert victim_pri >= 1


def test_no_preemption_when_disabled():
    rep = run_policy("tokenscale", "burstgpt2", engine="events",
                     preemption="none", **CONTENTION)
    assert rep.preemptions == []
    assert all(r.n_evictions == 0 for r in rep.requests)


# ---------------------------------------------------------------------------
# the headline: eviction protects high-priority tails under backpressure
# ---------------------------------------------------------------------------

def test_evict_lowest_improves_high_priority_p99_ttft():
    """The tails-bench acceptance row: on the burst trace, evict-lowest
    strictly improves class-0 p99 TTFT over no preemption."""
    none = run_policy("tokenscale", "burstgpt2", engine="events",
                      preemption="none", **CONTENTION)
    evict = run_policy("tokenscale", "burstgpt2", engine="events",
                       preemption="evict-lowest", **CONTENTION)
    p99_none = none.percentile("ttft", 99, priority=0)
    p99_evict = evict.percentile("ttft", 99, priority=0)
    assert p99_evict < p99_none
    assert evict.slo_attainment(0) >= none.slo_attainment(0)


def test_fluid_preemption_approximation_agrees_in_direction():
    """The fluid tick path carries the same preemption mechanics: it must
    fire and point the same way, even if the magnitudes smear."""
    none = run_policy("tokenscale", "burstgpt2", engine="fluid",
                      preemption="none", **CONTENTION)
    evict = run_policy("tokenscale", "burstgpt2", engine="fluid",
                       preemption="evict-lowest", **CONTENTION)
    assert len(evict.preemptions) > 0
    assert evict.percentile("ttft", 99, priority=0) \
        < none.percentile("ttft", 99, priority=0)


# ---------------------------------------------------------------------------
# report slicing
# ---------------------------------------------------------------------------

def test_report_priority_slicing(contended):
    classes = contended.priority_classes()
    assert classes == sorted(set(classes))
    n = sum(len(contended._pool(c)) for c in classes)
    assert n == len(contended.requests)
    for c in classes:
        assert 0.0 <= contended.slo_attainment(c) <= 1.0
        p99 = contended.percentile("ttft", 99, priority=c)
        p999 = contended.percentile("ttft", 99.9, priority=c)
        assert p999 >= p99 or np.isnan(p99)
