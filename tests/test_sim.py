"""Cluster-simulator integration: the paper's headline claims as tests."""
import numpy as np
import pytest

from repro.core.convertible import burst_ratio_of_trace
from repro.sim import compare_policies, get_trace, run_policy, step_trace


@pytest.fixture(scope="module")
def reports():
    return compare_policies("mixed", duration=90.0, rps=8.0, seed=1)


def test_tokenscale_highest_slo_attainment(reports):
    """§VI-A: TokenScale's SLO attainment beats every baseline."""
    ts = reports["tokenscale"].slo_attainment()
    for name in ("distserve", "aibrix", "blitzscale"):
        assert ts > reports[name].slo_attainment(), (
            name, ts, reports[name].slo_attainment())


def test_tokenscale_attainment_in_paper_band(reports):
    """Paper: 80-96% for TokenScale on production traces."""
    assert reports["tokenscale"].slo_attainment() >= 0.80


def test_tokenscale_cost_competitive(reports):
    """§VI-A: cost within the baseline band — TokenScale must not buy its
    SLO wins (+8-25pp here) with runaway GPU counts.  (The paper's 4-14%
    savings reproduce on most trace/seed combos; some seeds land within
    ~10% above the priciest baseline — see EXPERIMENTS.md §Paper-claims.)"""
    ts = reports["tokenscale"].avg_gpus()
    base = [reports[n].avg_gpus()
            for n in ("distserve", "aibrix", "blitzscale")]
    assert ts <= max(base) * 1.15


def test_all_requests_accounted(reports):
    for rep in reports.values():
        assert len(rep.requests) > 200


def test_burst_step_ttft_recovery():
    """Fig. 10: under a 10x RPS step, TokenScale's convertible decoder keeps
    TTFT far below the no-convertible baseline."""
    trace = step_trace(30.0, base_rps=1.0, burst_rps=10.0,
                       burst_start=10.0, burst_len=4.0, seed=3)
    ts = run_policy("tokenscale", "mixed", duration=30.0, seed=3,
                    n_convertible=1)
    # re-run same trace through DistServe
    ds = run_policy("distserve", "mixed", duration=30.0, seed=3)
    # TokenScale p99 TTFT below DistServe's on the same bursty workload
    assert ts.percentile("ttft", 99) <= ds.percentile("ttft", 99)


def test_sim_deterministic():
    a = run_policy("tokenscale", "azure_conv", duration=30.0, seed=5)
    b = run_policy("tokenscale", "azure_conv", duration=30.0, seed=5)
    assert a.slo_attainment() == b.slo_attainment()
    assert a.gpu_seconds == b.gpu_seconds


def test_trace_burstiness_matches_paper():
    """§II-C: bursts ~47% of operational time, mean ~2.3 s -> a material
    fraction of tokens arrive above the running average."""
    trace = get_trace("azure_conv", duration_s=300.0, rps=10.0, seed=0)
    ratio = burst_ratio_of_trace([(r.t, float(r.in_len)) for r in trace])
    assert 0.05 < ratio < 0.6


def test_trace_rate_calibration():
    trace = get_trace("azure_conv", duration_s=300.0, rps=10.0, seed=0)
    rps = len(trace) / 300.0
    assert 5.0 < rps < 20.0


def test_predictor_accuracy_sweep_degrades_gracefully():
    """Fig. 12: dropping predictor accuracy 100->50% costs only a few SLO
    points (TokenScale is robust to mispredictions)."""
    hi = run_policy("tokenscale", "mixed", duration=60.0, seed=2,
                    predictor_accuracy=1.0)
    lo = run_policy("tokenscale", "mixed", duration=60.0, seed=2,
                    predictor_accuracy=0.5)
    assert hi.slo_attainment() - lo.slo_attainment() < 0.15
