"""Paged KV cache (allocator + paged flash-decode kernel) and sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.paged_decode_attention import paged_decode_attention
from repro.serving.engine import SamplingParams, sample_token
from repro.serving.paged import (BlockAllocator, OutOfBlocks, PagedKV,
                                 paged_decode_attention_ref)


# ---------------------------------------------------------------------------
# Block allocator
# ---------------------------------------------------------------------------

def test_allocator_basic():
    al = BlockAllocator(4)
    blocks = [al.alloc(1), al.alloc(1), al.alloc(2)]
    assert len(set(blocks)) == 3
    assert al.n_free == 1
    assert al.utilization() == pytest.approx(0.75)
    assert al.free_request(1) == 2
    assert al.n_free == 3


def test_allocator_oom_signals_backpressure():
    al = BlockAllocator(2)
    al.alloc(1)
    al.alloc(1)
    with pytest.raises(OutOfBlocks):
        al.alloc(2)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.booleans()),
                min_size=1, max_size=40))
def test_allocator_never_double_allocates(ops):
    """Property: live blocks are unique; free returns exactly what was
    owned; n_free + live == num_blocks at every step."""
    al = BlockAllocator(8)
    live: dict[int, int] = {}
    for rid, do_free in ops:
        if do_free:
            n = al.free_request(rid)
            owned = [b for b, r in live.items() if r == rid]
            assert n == len(owned)
            for b in owned:
                del live[b]
        else:
            try:
                b = al.alloc(rid)
            except OutOfBlocks:
                assert len(live) == 8
                continue
            assert b not in live
            live[b] = rid
        assert al.n_free + len(live) == 8


def test_pagedkv_write_and_capacity():
    kv = PagedKV(num_layers=2, num_blocks=8, num_slots=2,
                 max_blocks_per_slot=4, n_kv_heads=2, head_dim=8,
                 dtype=jnp.float32)
    kv.ensure_capacity(0, rid=7, n_tokens=130)   # needs 2 blocks (BS=128)
    assert (kv.tables[0] >= 0).sum() == 2
    k = jnp.ones((2, 130, 2, 8))
    kv.write_tokens(0, k, k * 2, start=0)
    assert kv.lens[0] == 130
    blk0 = int(kv.tables[0, 0])
    assert float(kv.pool_k[0, blk0, 0, 0, 0]) == 1.0
    assert float(kv.pool_v[1, blk0, 5, 1, 3]) == 2.0
    kv.release(0, rid=7)
    assert kv.alloc.n_free == 8


# ---------------------------------------------------------------------------
# Paged flash-decode kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,MB,NB,BS,Hq,Hkv,D", [
    (1, 2, 4, 16, 2, 1, 16),
    (3, 4, 12, 16, 4, 2, 32),
    (2, 3, 8, 32, 8, 8, 64),      # MHA
])
def test_paged_decode_attention(B, MB, NB, BS, Hq, Hkv, D):
    rng = np.random.RandomState(B * 100 + MB)
    pool_k = jnp.asarray(rng.randn(NB, BS, Hkv, D).astype(np.float32))
    pool_v = jnp.asarray(rng.randn(NB, BS, Hkv, D).astype(np.float32))
    tables = np.full((B, MB), -1, np.int32)
    perm = rng.permutation(NB)
    j = 0
    curs = []
    for b in range(B):
        n = rng.randint(1, MB + 1)
        tables[b, :n] = perm[j:j + n]
        j += n
        curs.append(rng.randint(0, n * BS))
    cur = jnp.asarray(curs, jnp.int32)
    q = jnp.asarray(rng.randn(B, Hq, D).astype(np.float32))
    out = paged_decode_attention(q, pool_k, pool_v, jnp.asarray(tables),
                                 cur, interpret=True)
    for b in range(B):
        want = paged_decode_attention_ref(q[b], pool_k, pool_v,
                                          jnp.asarray(tables[b]), cur[b])
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


def test_paged_attention_ignores_foreign_pages():
    """Pages owned by other requests must not leak into the output."""
    rng = np.random.RandomState(0)
    NB, BS, H, D = 6, 16, 2, 16
    pool_k = jnp.asarray(rng.randn(NB, BS, H, D).astype(np.float32))
    pool_v = jnp.asarray(rng.randn(NB, BS, H, D).astype(np.float32))
    q = jnp.asarray(rng.randn(1, 2, D).astype(np.float32))
    t1 = jnp.asarray(np.array([[2, 4, -1]], np.int32))
    cur = jnp.array([20], jnp.int32)
    out1 = paged_decode_attention(q, pool_k, pool_v, t1, cur,
                                  interpret=True)
    # poison all pages NOT in the table
    poison_k = pool_k.at[jnp.array([0, 1, 3, 5])].set(jnp.nan)
    poison_v = pool_v.at[jnp.array([0, 1, 3, 5])].set(jnp.nan)
    out2 = paged_decode_attention(q, poison_k, poison_v, t1, cur,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------

def test_greedy_default():
    logits = np.array([0.1, 3.0, -1.0, 2.9])
    rng = np.random.RandomState(0)
    assert sample_token(logits, SamplingParams(), rng) == 1


def test_top_k_restricts_support():
    logits = np.array([5.0, 4.0, -10.0, -10.0])
    rng = np.random.RandomState(0)
    sp = SamplingParams(temperature=1.0, top_k=2, seed=0)
    draws = {sample_token(logits, sp, rng) for _ in range(50)}
    assert draws <= {0, 1}


def test_top_p_restricts_support():
    logits = np.array([10.0, 0.0, 0.0, 0.0])
    rng = np.random.RandomState(0)
    sp = SamplingParams(temperature=1.0, top_p=0.9)
    draws = {sample_token(logits, sp, rng) for _ in range(50)}
    assert draws == {0}


def test_temperature_zero_matches_argmax_under_ties_free_logits():
    rng = np.random.RandomState(0)
    for _ in range(10):
        logits = rng.randn(32)
        assert sample_token(logits, SamplingParams(), rng) \
            == int(np.argmax(logits))


def test_engine_sampled_generation_reproducible():
    """Same sampling seed -> identical stochastic streams."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import Engine, Request
    cfg = get_config("qwen2_0_5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(3)
    prompt = rng.randint(0, cfg.vocab_size, size=(9,)).astype(np.int32)
    outs = []
    for _ in range(2):
        eng = Engine(cfg, params, num_slots=1, max_len=48)
        r = Request(rid=0, prompt=prompt, max_new_tokens=6,
                    sampling=SamplingParams(temperature=0.8, top_k=20,
                                            seed=42))
        eng.add_request(r)
        eng.run_until_drained()
        outs.append(list(r.output))
    assert outs[0] == outs[1]
    # and differs from greedy (with overwhelming probability)
    eng = Engine(cfg, params, num_slots=1, max_len=48)
    g = Request(rid=0, prompt=prompt, max_new_tokens=6)
    eng.add_request(g)
    eng.run_until_drained()
    assert isinstance(g.output, list)
