"""Tiered KV-cache subsystem (PR 4 tentpole; sim/kvcache.py).

Covers, in order:

  * allocator unit semantics — admit/release/drop/swap round trips,
    double-free and over-allocation raising, CoW prefix sharing, LRU
    reclaim with DRAM demotion;
  * the block-conservation property the ISSUE names: a seeded random-ops
    fuzz over the allocator with the double-entry ``check()`` audit after
    every operation (blocks never leak or double-free across
    admit/evict/swap/complete), then the same audit at the end of
    end-to-end runs on BOTH engines x every preemption mode;
  * the multi-turn session trace knob (arrivals stay byte-identical,
    independent RNG stream, prefix semantics);
  * engine integration — fluid-vs-events differential band with the KV
    subsystem enabled, the ``hbm_frac`` knob, spec JSON round-trip of the
    tier knobs, and the ``evict-least-slack`` SLO-aware victim selector.

The headline gradients (swap strictly beating recompute on preempted p99
TTFT/TPOT, prefix reuse cutting prefill-token load) are pinned by the
``kvtiers_session`` golden in tests/test_golden_policy.py.
"""
import numpy as np
import pytest

from repro.core import ExperimentSpec, OutputPredictor, PerModelFleetPolicy
from repro.core.autoscaler import build_policy
from repro.core.fleet import PoolSpec, single_pool_fleet
from repro.sim.kvcache import KVAllocator, KVError, KVStats, KVTierConfig
from repro.sim.runner import (build_fleet, build_traces, compare_engines,
                              get_engine, run_policy)
from repro.sim.traces import (DEFAULT_PRIORITY_MIX, TRACES, assign_sessions,
                              generate, get_trace, trace_stats)


def make_alloc(n_hbm=32, n_dram=16, bs=4, prefix=True, stats=None):
    cfg = KVTierConfig(block_size=bs, block_bytes=float(bs), n_hbm=n_hbm,
                       n_dram=n_dram, swap_bw=1e9, prefix_cache=prefix)
    return KVAllocator(cfg, stats)


# ---------------------------------------------------------------------------
# allocator unit semantics
# ---------------------------------------------------------------------------

def test_admit_release_roundtrip():
    kv = make_alloc()
    assert kv.can_admit(1, 10.0)
    kv.admit(1, 10.0)              # 10 bytes / 4-byte blocks -> 3 blocks
    kv.check()
    assert kv.hard_used == 3
    assert kv.used_bytes() == 3 * 4.0
    kv.release(1, sid=-1, ctx_tokens=12, t=0.0)
    kv.check()
    assert kv.hard_used == 0
    assert len(kv.free) == kv.cfg.n_hbm


def test_double_admit_and_unknown_release_raise():
    kv = make_alloc()
    kv.admit(1, 4.0)
    with pytest.raises(KVError):
        kv.admit(1, 4.0)
    with pytest.raises(KVError):
        kv.release(2, -1, 4, 0.0)
    with pytest.raises(KVError):
        kv.drop(3)
    kv.check()


def test_over_allocation_raises():
    kv = make_alloc(n_hbm=4, n_dram=0)
    assert not kv.can_admit(1, 100.0)     # 25 blocks > 4
    with pytest.raises(KVError):
        kv.admit(1, 100.0)


def test_prefix_cache_copy_on_write_share():
    kv = make_alloc(n_hbm=32)
    kv.admit(1, 40.0)                     # 10 blocks
    kv.release(1, sid=7, ctx_tokens=38, t=1.0)   # cache 9 full blocks
    kv.check()
    tok, tier = kv.lookup(7, prefix_len=38)
    assert (tok, tier) == (36, "hbm")     # 9 blocks x 4 tokens
    kv.pin(2, 7, tok, t=2.0)
    kv.check()
    # the follow-up only allocates beyond the 9 shared blocks
    assert kv.need_blocks(2, 48.0) == 12 - 9
    kv.admit(2, 48.0)
    kv.check()
    a = kv.allocs[2]
    assert len(a.shared) == 9 and len(a.owned) == 3
    # shared blocks are referenced, not copied: 9 + 3 hard-used in total
    assert kv.hard_used == 12
    kv.release(2, sid=7, ctx_tokens=48, t=3.0)
    kv.check()
    # the session entry now covers the longer prefix
    assert kv.lookup(7, prefix_len=100)[0] == 48


def test_lru_reclaim_demotes_to_dram_then_drops():
    stats = KVStats()
    kv = make_alloc(n_hbm=8, n_dram=4, stats=stats)
    kv.admit(1, 16.0)                     # 4 blocks
    kv.release(1, sid=0, ctx_tokens=16, t=1.0)
    kv.admit(2, 16.0)
    kv.release(2, sid=1, ctx_tokens=16, t=2.0)
    kv.check()
    # 8 blocks cached across two sessions; a 6-block admission must
    # reclaim: session 0 (LRU) demotes into the 4-block DRAM tier,
    # session 1 is dropped (tier full)
    kv.admit(3, 24.0)
    kv.check()
    assert stats.demotions == 1
    assert kv.lookup(0, 16) == (16, "dram")
    assert kv.lookup(1, 16) == (0, "")
    kv.release(3, -1, 24, t=3.0)
    kv.check()


def test_pinned_entries_survive_pressure():
    kv = make_alloc(n_hbm=8, n_dram=0)
    kv.admit(1, 16.0)
    kv.release(1, sid=0, ctx_tokens=16, t=1.0)
    kv.pin(2, 0, 16, t=2.0)
    # all 8 blocks: 4 pinned + 4 free; a 6-block admission cannot reclaim
    # the pinned entry
    assert kv.available() == 4
    assert not kv.can_admit(3, 24.0)
    kv.unpin(2)
    assert kv.available() == 8
    kv.check()


def test_swap_out_roundtrip_and_tier_full_fallback():
    stats = KVStats()
    kv = make_alloc(n_hbm=16, n_dram=4, stats=stats)
    kv.admit(1, 16.0)                     # 4 owned blocks
    kind, nbytes = kv.swap_out(1)
    assert kind == "swap" and nbytes == 16.0
    assert kv.dram_free == 0 and kv.hard_used == 0
    kv.check()
    assert kv.swap_in_release(1) == 4
    assert kv.dram_free == 4
    kv.check()
    # tier already holds nothing now; fill it, then overflow falls back
    kv.admit(2, 16.0)
    kv.admit(3, 4.0)
    assert kv.swap_out(2)[0] == "swap"
    assert kv.swap_out(3)[0] == "drop"    # DRAM full: recompute fallback
    kv.check()
    assert stats.swap_outs == 2


def test_pinned_entry_replaced_by_longer_release_leaves_no_stale_pin():
    """Regression for the requeue-once pin-leak hazard: a request pins a
    session entry (the penalty path), then the session owner finishes and
    re-caches a *longer* context under the same sid — dropping the pinned
    entry.  The retired-entry bookkeeping must keep the pin accounted
    (``check()``'s no-stale-pins invariant) until unpin drains it."""
    kv = make_alloc(n_hbm=16, n_dram=0)
    kv.admit(1, 16.0)
    kv.release(1, sid=0, ctx_tokens=16, t=1.0)       # 4-block entry
    kv.pin(2, 0, 16, t=2.0)                          # requeued arrival
    kv.admit(3, 24.0)
    kv.release(3, sid=0, ctx_tokens=24, t=3.0)       # replaces while pinned
    kv.check()                                       # no stale pin
    assert len(kv._retired) == 1
    assert kv.lookup(0, 64) == (24, "hbm")           # new entry serves
    kv.unpin(2)                                      # drains the retiree
    kv.check()
    assert not kv._retired
    while kv._reclaim_one():
        kv.check()
    assert len(kv.free) == kv.cfg.n_hbm              # nothing leaked
    assert not kv.ref and not kv.hard


# ---------------------------------------------------------------------------
# block conservation: seeded random-ops fuzz with the double-entry audit
# ---------------------------------------------------------------------------

def test_allocator_fuzz_conserves_blocks():
    rng = np.random.RandomState(0)
    kv = make_alloc(n_hbm=24, n_dram=8, bs=4)
    live: dict[int, int] = {}     # rid -> sid
    swapped: list[int] = []
    sessions: list[int] = []
    rid = 0
    for step in range(2000):
        op = rng.randint(6)
        if op <= 1:                                   # admit (maybe pinned)
            rid += 1
            nbytes = float(rng.randint(1, 40))
            sid = int(rng.randint(4))
            if sessions and rng.rand() < 0.5:
                psid = sessions[rng.randint(len(sessions))]
                tok, tier = kv.lookup(psid, prefix_len=rng.randint(1, 64))
                if tok > 0 and tier == "hbm":
                    kv.pin(rid, psid, tok, t=float(step))
            if kv.can_admit(rid, nbytes):
                kv.admit(rid, nbytes)
                live[rid] = sid
            else:
                kv.unpin(rid)
        elif op == 2 and live:                        # finish -> cache
            r = list(live)[rng.randint(len(live))]
            sid = live.pop(r)
            kv.release(r, sid, ctx_tokens=int(rng.randint(1, 64)),
                       t=float(step))
            if sid not in sessions:
                sessions.append(sid)
        elif op == 3 and live:                        # evict (recompute)
            r = list(live)[rng.randint(len(live))]
            live.pop(r)
            kv.drop(r)
        elif op == 4 and live:                        # pause (swap tier)
            r = list(live)[rng.randint(len(live))]
            live.pop(r)
            if kv.swap_out(r)[0] == "swap":
                swapped.append(r)
        elif op == 5 and swapped:                     # swap-in completes
            kv.swap_in_release(swapped.pop(rng.randint(len(swapped))))
        kv.check()                                    # audit EVERY step
    for r in list(live):
        kv.release(r, live.pop(r), 16, t=9999.0)
    for r in swapped:
        kv.swap_in_release(r)
    kv.check()
    assert kv.hard_used == 0
    # drain the prefix cache: once every entry is reclaimed, every HBM
    # block must be back on the free list — nothing leaked
    while kv._reclaim_one():
        kv.check()
    assert len(kv.free) == kv.cfg.n_hbm
    assert not kv.ref and not kv.hard


# ---------------------------------------------------------------------------
# multi-turn session traces
# ---------------------------------------------------------------------------

def test_sessions_do_not_perturb_arrivals():
    plain = generate(TRACES["azure_conv"], 60.0, 8.0, seed=5)
    sess = generate(TRACES["azure_conv"], 60.0, 8.0, seed=5,
                    session_prob=0.7)
    assert [(r.t, r.in_len, r.out_len, r.priority) for r in plain] \
        == [(r.t, r.in_len, r.out_len, r.priority) for r in sess]
    assert all(r.session == -1 and r.prefix_len == 0 for r in plain)


def test_sessions_deterministic_and_well_formed():
    a = get_trace("azure_code", 120.0, 8.0, seed=3, session_prob=0.6)
    b = get_trace("azure_code", 120.0, 8.0, seed=3, session_prob=0.6)
    assert [(r.session, r.prefix_len) for r in a] \
        == [(r.session, r.prefix_len) for r in b]
    follow = [r for r in a if r.prefix_len > 0]
    assert follow, "no follow-up turns drawn"
    for r in a:
        assert r.session >= 0
        assert 0 <= r.prefix_len <= r.in_len
    # sessions are chains: a follow-up shares its session with an earlier
    # arrival, and the shared prefix equals the prior turn's context
    by_t = sorted(a, key=lambda r: (r.t, r.rid))
    last_ctx: dict[int, int] = {}
    for r in by_t:
        if r.prefix_len > 0:
            assert r.session in last_ctx
            assert r.prefix_len == min(last_ctx[r.session], r.in_len)
        last_ctx[r.session] = r.in_len + r.out_len
    # roughly session_prob of eligible arrivals join an open session
    frac = len(follow) / max(len(a), 1)
    assert 0.2 < frac < 0.85


def test_mixed_trace_sessions_span_components():
    trace = get_trace("mixed", 60.0, 8.0, seed=0, session_prob=0.5)
    assert any(r.prefix_len > 0 for r in trace)


# ---------------------------------------------------------------------------
# end-to-end: both engines, every mode, allocators audited afterwards
# ---------------------------------------------------------------------------

def run_contended(engine, mode, duration=22.0, prefix=True):
    """The kvtiers contention scenario with the cluster object exposed, so
    tests can audit every decoder's allocator after the run."""
    fleet_spec = single_pool_fleet(
        "qwen25_32b", "a100", 2, trace="azure_code", rps=7.0,
        n_convertible=1, priority_mix=DEFAULT_PRIORITY_MIX,
        session_prob=0.5, block_size=16, prefix_cache=prefix)
    spec = ExperimentSpec(fleet=fleet_spec, policy="tokenscale",
                          engine=engine, preemption=mode, duration=duration,
                          seed=0, max_instances=2)
    fleet = build_fleet(spec.fleet)
    trace = build_traces(spec)
    g = fleet.groups[fleet.default_model]
    stats = trace_stats(trace)
    pol = build_policy("tokenscale", g.prefill.prof,
                       decode_prof=g.decode.prof, mean_in=stats.mean_in,
                       mean_out=stats.mean_out, n_convertible=1)
    cl = get_engine(engine)(
        fleet, policy=PerModelFleetPolicy({fleet.default_model: pol}),
        predictor=OutputPredictor(0.85, 0), preemption=mode,
        max_instances=2)
    rep = cl.run(trace, spec.duration + spec.extra_horizon)
    return cl, rep, trace


@pytest.fixture(scope="module", params=["fluid", "events"])
def engine(request):
    return request.param


@pytest.fixture(scope="module",
                params=["evict-lowest", "evict-least-slack",
                        "pause-requeue"])
def contended_kv(request, engine):
    return run_contended(engine, request.param)


def test_blocks_conserved_end_to_end(contended_kv):
    """The ISSUE's conservation property at system level: after a full
    contended run (admissions, evictions, swaps, completions, prefix
    reuse) every allocator passes the double-entry audit and its live
    allocations are exactly the decoder's resident requests."""
    cl, rep, trace = contended_kv
    audited = 0
    for d in cl.decoders + cl.convertibles:
        if d.kv is None:
            continue
        d.kv.check()
        assert set(d.kv.allocs) == {r.src.rid for r in d.active}
        audited += 1
    assert audited > 0
    assert len(rep.requests) == len(trace)          # nothing lost
    assert len(rep.requests) == len({id(r) for r in rep.requests})


def test_preemption_fires_and_victims_strictly_lower(contended_kv):
    cl, rep, _ = contended_kv
    assert len(rep.preemptions) > 0
    for _, victim_pri, preemptor_pri, _ in rep.preemptions:
        assert victim_pri > preemptor_pri


def test_swap_accounting_consistent(contended_kv):
    cl, rep, _ = contended_kv
    ks = rep.kv_summary()
    if cl.preemption.mode == "pause-requeue":
        assert ks["swap_outs"] > 0
        assert ks["offload_bytes"] > 0
        assert ks["swap_stall_s"] > 0
        assert ks["swap_ins"] <= ks["swap_outs"]
    else:
        assert ks["swap_outs"] == 0
    assert 0 < ks["peak_blocks_frac"] <= 1.0
    assert 0.0 <= ks["prefix_hit_rate"] < 1.0


def test_prefix_reuse_hits_on_session_trace(contended_kv):
    cl, rep, _ = contended_kv
    ks = rep.kv_summary()
    assert ks["prefix_hit_rate"] > 0
    assert ks["hit_tokens"] > 0
    saved = sum(r.kv_hit_tokens for r in rep.requests)
    assert saved == ks["hit_tokens"]
    total_in = sum(r.src.in_len for r in rep.requests)
    assert sum(r.src.in_len - r.kv_hit_tokens
               for r in rep.requests) < total_in


# ---------------------------------------------------------------------------
# differential band with the KV subsystem enabled (acceptance)
# ---------------------------------------------------------------------------

def test_kv_differential_band_holds():
    """Fluid vs events must stay inside the historical 15% band with
    paging + prefix reuse + sessions enabled (same tolerance and dt as
    tests/test_sim_differential.py)."""
    reps = compare_engines("tokenscale", "azure_conv", duration=40.0,
                           rps=6.0, seed=0, dt=0.0125, block_size=16,
                           prefix_cache=True, session_prob=0.6)
    fl, ev = reps["fluid"], reps["events"]
    assert len(fl.requests) == len(ev.requests)

    def close(a, b, abs_tol):
        return abs(a - b) <= max(0.15 * max(abs(a), abs(b)), abs_tol)

    assert close(fl.throughput(), ev.throughput(), 0.1)
    assert close(fl.mean("ttft"), ev.mean("ttft"), 0.020)
    assert close(fl.mean("tpot"), ev.mean("tpot"), 0.005)
    # both engines agree the cache is working
    assert fl.kv["prefix_hit_rate"] > 0
    assert ev.kv["prefix_hit_rate"] > 0


# ---------------------------------------------------------------------------
# knobs: hbm_frac, spec round trip, legacy default
# ---------------------------------------------------------------------------

def test_hbm_frac_knob_threads_to_decoders():
    caps = {}
    for frac in (0.5, 0.9):
        fs = single_pool_fleet("llama31_8b", "a100", 1, hbm_frac=frac)
        spec = ExperimentSpec(fleet=fs, duration=1.0)
        fleet = build_fleet(spec.fleet)
        g = fleet.groups[fleet.default_model]
        stats = trace_stats([])
        pol = build_policy("tokenscale", g.prefill.prof,
                           decode_prof=g.decode.prof, mean_in=stats.mean_in,
                           mean_out=stats.mean_out, n_convertible=0)
        cl = get_engine("fluid")(
            fleet, policy=PerModelFleetPolicy({fleet.default_model: pol}))
        d = cl.decoders[0]
        caps[frac] = d.mem_cap()
        assert d.hbm_frac == frac
    spec_cap = 40e9           # a100 hbm_cap
    assert caps[0.9] - caps[0.5] == pytest.approx(0.4 * spec_cap, rel=1e-6)


def test_hbm_frac_threads_into_velocity_profile():
    """The autoscaler's Eq. 1/Eq. 3 capacity bounds must match what the
    pool's decoders enforce: a lower usable-HBM fraction shrinks the
    profiled max batch (and never inflates decode velocity)."""
    from repro.core.velocity import profile_for
    full = profile_for("llama31_8b", "a100", 1)
    tight = profile_for("llama31_8b", "a100", 1, hbm_frac=0.5)
    assert any(tight.max_batch[b] < full.max_batch[b]
               for b in full.max_batch)
    assert all(tight.max_batch[b] <= full.max_batch[b]
               for b in full.max_batch)
    assert all(tight.v_decode[b] <= full.v_decode[b] + 1e-9
               for b in full.v_decode)


def test_pool_spec_validates_kv_knobs():
    with pytest.raises(ValueError):
        PoolSpec("d", "decode", block_size=-1)
    with pytest.raises(ValueError):
        PoolSpec("d", "decode", hbm_frac=0.0)
    with pytest.raises(ValueError):
        PoolSpec("d", "decode", hbm_frac=1.5)


def test_experiment_spec_roundtrips_kv_knobs():
    fs = single_pool_fleet("llama31_8b", "a100", 1, block_size=32,
                           hbm_frac=0.8, offload_gb=12.0, prefix_cache=True,
                           session_prob=0.4)
    spec = ExperimentSpec(fleet=fs, policy="tokenscale", duration=5.0)
    back = ExperimentSpec.from_json(spec.to_json())
    assert back == spec
    dec = [p for p in back.fleet.pools if p.role == "decode"][0]
    assert (dec.block_size, dec.hbm_frac, dec.offload_gb,
            dec.prefix_cache) == (32, 0.8, 12.0, True)
    assert back.fleet.routes[0].session_prob == 0.4


def test_kv_disabled_by_default():
    rep = run_policy("tokenscale", "azure_conv", duration=10.0, rps=4.0,
                     seed=0)
    # KV tiers off: raw stats stay empty, but the summary degrades to the
    # full key set with zero values (stable schema for dashboards)
    assert rep.kv == {}
    kv = rep.kv_summary()
    assert set(kv) == set(KVStats().summary()) | {
        "n_preempted", "preempted_ttft_p99", "preempted_tpot_p99"}
    assert all(v == 0 for v in kv.values())


# ---------------------------------------------------------------------------
# evict-least-slack (SLO-aware victim selection; ROADMAP satellite)
# ---------------------------------------------------------------------------

CONTENTION = dict(model="qwen25_32b", tp=2, duration=22.0, rps=8.0, seed=0,
                  max_instances=2, priority_mix=DEFAULT_PRIORITY_MIX)


def test_evict_least_slack_fires_and_respects_priority():
    rep = run_policy("tokenscale", "burstgpt2", engine="events",
                     preemption="evict-least-slack", **CONTENTION)
    assert len(rep.preemptions) > 0
    for _, victim_pri, preemptor_pri, _ in rep.preemptions:
        assert victim_pri > preemptor_pri     # never same-or-higher class


def test_evict_least_slack_protects_high_priority_tail():
    none = run_policy("tokenscale", "burstgpt2", engine="events",
                      preemption="none", **CONTENTION)
    slack = run_policy("tokenscale", "burstgpt2", engine="events",
                       preemption="evict-least-slack", **CONTENTION)
    assert slack.percentile("ttft", 99, priority=0) \
        < none.percentile("ttft", 99, priority=0)
    assert slack.slo_attainment(0) >= none.slo_attainment(0)
