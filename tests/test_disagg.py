"""PD-disaggregated runtime: kvtransfer + PDCluster on real engines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # JAX engine tests: minutes-scale on CPU

from repro.configs import get_config
from repro.core import CHIPS, InstanceSpec, TokenScalePolicy, profile
from repro.models import (greedy_generate, init_params, init_state, prefill)
from repro.serving import (Engine, PDCluster, Request, TransferStats,
                           extract, insert, payload_bytes)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama31_8b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_kv_payload_roundtrip(setup):
    """extract -> insert across two independent state pools preserves the
    decode stream exactly (the KVC transfer contract)."""
    cfg, params = setup
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab_size, size=(11,)).astype(np.int32)
    # prefill on "prefiller" state pool
    st_p = init_state(cfg, 1, 64)
    logits, st_p = prefill(cfg, params, st_p,
                           jnp.asarray(prompt[None]),
                           jnp.array([11], jnp.int32))
    payload = extract(cfg, st_p, 11, slot=0)
    assert payload_bytes(payload) > 0
    # insert into slot 2 of a "decoder" pool and continue decoding
    eng = Engine(cfg, params, num_slots=4, max_len=64)
    req = Request(rid=0, prompt=prompt, max_new_tokens=5)
    req.slot = eng._alloc_slot(req)
    assert req.slot == 0
    eng.state = insert(cfg, eng.state, payload, req.slot)
    eng.last_tokens[req.slot] = int(jnp.argmax(logits[0]))
    eng.cur_lens[req.slot] = 11
    req.prefill_done = 11
    req.output.append(int(jnp.argmax(logits[0])))
    eng.run_until_drained()
    ref = greedy_generate(cfg, params, jnp.asarray(prompt[None]),
                          jnp.array([11], jnp.int32), 5)
    assert np.array_equal(np.array(req.output), np.asarray(ref[0]))


def test_payload_is_length_trimmed(setup):
    cfg, params = setup
    st = init_state(cfg, 1, 4096)
    p_short = extract(cfg, st, 10)
    p_long = extract(cfg, st, 3000)
    assert payload_bytes(p_short) < payload_bytes(p_long)


def test_ssm_payload_smaller_than_attention():
    """RWKV's O(1) state payload is tiny vs an attention KVC at the same
    length — the §III-C network-velocity asymmetry, measured."""
    cfg_a = get_config("llama31_8b", smoke=True)
    cfg_s = get_config("rwkv6_3b", smoke=True)
    st_a = init_state(cfg_a, 1, 2048)
    st_s = init_state(cfg_s, 1, 2048)
    b_a = payload_bytes(extract(cfg_a, st_a, 2000))
    b_s = payload_bytes(extract(cfg_s, st_s, 2000))
    assert b_s < b_a / 4


def test_pd_cluster_exact_outputs(setup):
    cfg, params = setup
    prof = profile(get_config("llama31_8b"), InstanceSpec(CHIPS["v5e"], 1))
    cl = PDCluster(cfg, params, TokenScalePolicy(prof, convertible=1),
                   n_prefillers=1, n_decoders=1, n_convertible=1,
                   max_len=96)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       size=(L,)).astype(np.int32),
                    max_new_tokens=6)
            for i, L in enumerate([7, 12, 5, 20, 9])]
    for r in reqs:
        cl.submit(r)
    cl.run_until_drained()
    for r in reqs:
        ref = greedy_generate(cfg, params, jnp.asarray(r.prompt[None]),
                              jnp.array([len(r.prompt)], jnp.int32), 6)
        assert np.array_equal(np.array(r.output), np.asarray(ref[0])), r.rid
    # the network stage actually carried the KVC
    assert cl.transfers.n_transfers >= 1
    assert cl.transfers.total_bytes > 0


def test_pd_cluster_autoscales(setup):
    cfg, params = setup
    prof = profile(get_config("llama31_8b"), InstanceSpec(CHIPS["v5e"], 1))
    cl = PDCluster(cfg, params, TokenScalePolicy(prof, convertible=0),
                   n_prefillers=1, n_decoders=1, n_convertible=0,
                   max_len=64, slots_per_decoder=2)
    rng = np.random.RandomState(2)
    for i in range(10):
        cl.submit(Request(rid=i,
                          prompt=rng.randint(0, cfg.vocab_size,
                                             size=(8,)).astype(np.int32),
                          max_new_tokens=4))
    cl.run_until_drained(autoscale_every=3)
    # with 2 slots/decoder and 10 concurrent requests the scaler must have
    # grown the decode pool (or drained everything anyway)
    assert all(len(getattr(r, "output", [])) >= 0 for r in [])
    assert len(cl.decoders) >= 1


def test_transfer_stats_velocity():
    s = TransferStats()
    s.record(nbytes=131072 * 100, tokens=100, wall_s=0.01)
    assert s.bytes_per_token() == pytest.approx(131072)
    # at 50 GB/s a 131 KB/token KVC sustains ~381k tok/s
    assert s.measured_network_velocity(50e9) == pytest.approx(
        50e9 / 131072, rel=1e-6)
