"""Expert-parallel MoE (shard_map) vs the dense-masked reference path.

The multi-device case runs in a subprocess (XLA device count is locked at
first init; smoke tests must keep seeing 1 device — see conftest).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import forward_train, init_params

_SUB = r"""
import os, dataclasses
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import init_params, forward_train
from repro.launch.mesh import compat_make_mesh, compat_set_mesh
from repro.sharding import axis_rules

cfg = get_config("kimi_k2_1t_a32b", smoke=True)
cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
params = init_params(cfg, jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
ref, aux_ref = forward_train(cfg, params, toks)

mesh = compat_make_mesh((2, 4), ("data", "model"))
rules = {"batch": ("data",), "experts": ("model",), "heads": ("model",),
         "kv_heads": ("model",), "ff": ("model",), "vocab": ("model",),
         "embed": (), "ctx": (), "kv_lora": (), "seq": (), "state": ()}
with axis_rules(rules, mesh):
    with compat_set_mesh(mesh):
        out, aux = jax.jit(lambda p, t: forward_train(cfg, p, t))(params, toks)
err = float(jnp.abs(out - ref).max())
assert err < 1e-4, f"EP path diverged: {err}"
print("EP_OK", err)
"""


@pytest.mark.slow
def test_moe_ep_matches_dense_multidevice():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", _SUB], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "EP_OK" in r.stdout


def test_moe_capacity_drops_tokens_gracefully():
    """With a tiny capacity factor outputs stay finite (dropped tokens fall
    back to the shared expert / residual) — GShard semantics."""
    import dataclasses
    cfg = get_config("kimi_k2_1t_a32b", smoke=True)
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=0.1))
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    logits, aux = forward_train(cfg, params, toks)
    assert bool(jnp.isfinite(logits).all())


def test_router_aux_loss_increases_with_imbalance():
    from repro.models.ops import _router
    cfg = get_config("kimi_k2_1t_a32b", smoke=True)
    d, e = cfg.d_model, cfg.moe.num_experts
    key = jax.random.PRNGKey(0)
    # positive activations so the skewed column is dominant for EVERY token
    h = jnp.abs(jax.random.normal(key, (1, 32, d), jnp.float32))
    balanced = {"router": jnp.zeros((d, e), jnp.float32)
                + 1e-3 * jax.random.normal(key, (d, e))}
    skew = jnp.zeros((d, e), jnp.float32).at[:, 0].set(5.0)
    skewed = {"router": skew}
    _, _, aux_b = _router(cfg, balanced, h)
    _, _, aux_s = _router(cfg, skewed, h)
    assert float(aux_s) > float(aux_b)
