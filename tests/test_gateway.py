"""KV-locality gateway (PR 8 tentpole; core/gateway.py + sim wiring).

Covers, in order:

  * ``prefix_chain`` label semantics — shared-prompt blocks, session
    blocks, boundary straddling, sessionless tails;
  * the hashtrie property/fuzz suite the ISSUE names: random
    insert/lookup/remove-holder ops checked against a brute-force
    longest-common-prefix reference, with the structural ``check()``
    audit after every operation, plus LRU aging under ``max_nodes``;
  * routing score and replication planning unit semantics;
  * allocator refcount conservation under the gateway's new verbs
    (``cache_alias`` / ``install`` / ``try_grow``) — a seeded random-ops
    fuzz with the double-entry ``check()`` audit every step;
  * the shared-prefix workload knob (arrivals byte-identical,
    deterministic, session-sticky, Zipf-skewed);
  * engine integration — gateway counters on both engines, end-to-end
    allocator + trie audits, the fluid-vs-events differential band with
    the gateway enabled, spec round-trip, legacy-default invariance;
  * the ``gateway_locality`` golden replay incl. the acceptance
    gradient: hashtrie routing strictly beats owner-steering on p99
    TTFT at equal-or-lower GPU count.
"""
import json
import math
import os

import numpy as np
import pytest

from repro.core import ExperimentSpec, OutputPredictor, PerModelFleetPolicy
from repro.core.autoscaler import build_policy
from repro.core.fleet import PoolSpec, single_pool_fleet
from repro.core.gateway import (Gateway, GatewayConfig, PrefixHashTrie,
                                RoutingStats, prefix_chain)
from repro.sim.kvcache import KVError
from repro.sim.runner import (build_fleet, build_traces, compare_engines,
                              get_engine, run_policy)
from repro.sim.traces import TRACES, generate, get_trace, trace_stats

from tests.test_kvcache import make_alloc

GOLDEN_GW = json.load(open(os.path.join(os.path.dirname(__file__), "golden",
                                        "gateway_locality.json")))


# ---------------------------------------------------------------------------
# prefix_chain label semantics
# ---------------------------------------------------------------------------

def test_chain_shared_then_session_blocks():
    # 40 shared of 70 total, bs=16: blocks 0-1 inside the shared prompt,
    # block 2 straddles the boundary -> session block; 70//16 = 4 full
    chain = prefix_chain(shared_id=3, shared_len=40, session=9,
                         in_len=70, block_size=16)
    assert chain == [("sys", 3, 0), ("sys", 3, 1),
                     ("sess", 9, 2), ("sess", 9, 3)]


def test_chain_sessionless_tail_has_no_private_labels():
    chain = prefix_chain(shared_id=1, shared_len=32, session=-1,
                         in_len=100, block_size=16)
    assert chain == [("sys", 1, 0), ("sys", 1, 1)]
    assert prefix_chain(-1, 0, -1, 100, 16) == []


def test_chain_short_prompt_and_disabled_paging():
    assert prefix_chain(0, 64, 5, in_len=10, block_size=16) == []
    assert prefix_chain(0, 64, 5, in_len=100, block_size=0) == []


def test_chains_share_prefix_iff_content_shared():
    a = prefix_chain(2, 64, 10, 128, 16)
    b = prefix_chain(2, 64, 11, 128, 16)    # same prompt, other session
    c = prefix_chain(5, 64, 10, 128, 16)    # other prompt, same session
    lcp = 0
    while lcp < min(len(a), len(b)) and a[lcp] == b[lcp]:
        lcp += 1
    assert lcp == 4                          # exactly the shared blocks
    assert a[0] != c[0]                      # different prompts diverge


# ---------------------------------------------------------------------------
# hashtrie: fuzz vs brute-force LCP reference
# ---------------------------------------------------------------------------

def _rand_chain(rng):
    """A random label chain with the real sys->sess block structure, drawn
    from a small alphabet so chains share prefixes often."""
    sys_id = int(rng.randint(3))
    n_sys = int(rng.randint(4))
    n_sess = int(rng.randint(4))
    sess = int(rng.randint(5))
    chain = [("sys", sys_id, i) for i in range(n_sys)]
    chain += [("sess", sess, i) for i in range(n_sys, n_sys + n_sess)]
    return chain


def _ref_lookup(inserted, query):
    """Brute force: per holder, the deepest common prefix (in blocks)
    between the query and any chain that holder inserted."""
    best = {}
    for chain, holder in inserted:
        lcp = 0
        while lcp < min(len(chain), len(query)) \
                and chain[lcp] == query[lcp]:
            lcp += 1
        if lcp > 0:
            best[holder] = max(best.get(holder, 0), lcp)
    return best


def test_trie_fuzz_matches_lcp_reference():
    rng = np.random.RandomState(0)
    bs = 16
    trie = PrefixHashTrie(max_nodes=10_000)      # no pruning in this fuzz
    inserted: list[tuple] = []                   # (chain, holder)
    holders = ["d0", "d1", "d2", "d3"]
    for step in range(2000):
        op = rng.randint(4)
        if op <= 1:
            chain = _rand_chain(rng)
            h = holders[rng.randint(len(holders))]
            if chain:
                trie.insert(chain, h, t=float(step), block_size=bs)
                inserted.append((chain, h))
        elif op == 2:
            q = _rand_chain(rng)
            got = {h: d for h, (d, _) in trie.lookup(q, t=float(step)).items()}
            want = {h: lcp * bs for h, lcp in _ref_lookup(inserted, q).items()}
            assert got == want, (step, q)
        elif op == 3 and rng.rand() < 0.2:       # teardown is rare
            h = holders[rng.randint(len(holders))]
            trie.remove_holder(h)
            inserted = [(c, hh) for c, hh in inserted if hh != h]
        trie.check(bs)                           # audit EVERY step


def test_trie_ages_out_lru_chains_under_capacity():
    bs = 16
    trie = PrefixHashTrie(max_nodes=64)
    for i in range(200):
        chain = [("sess", i, j) for j in range(4)]    # all-distinct chains
        trie.insert(chain, "d0", t=float(i), block_size=bs)
        trie.check(bs)
        assert trie.n_nodes <= 64
    # the most recent chain survives the pruning, the oldest aged out
    assert trie.holders_of([("sess", 199, j) for j in range(4)]) == ["d0"]
    assert trie.holders_of([("sess", 0, j) for j in range(4)]) == []


def test_trie_replica_flag_upgrades_but_never_downgrades():
    bs = 16
    trie = PrefixHashTrie()
    chain = [("sys", 0, 0), ("sys", 0, 1)]
    trie.insert(chain, "d0", t=0.0, block_size=bs, replica=True)
    node = trie.walk(chain)
    assert node.holders["d0"][1] is True
    trie.insert(chain, "d0", t=1.0, block_size=bs)        # origin insert
    assert node.holders["d0"][1] is False
    trie.insert(chain, "d0", t=2.0, block_size=bs, replica=True)
    assert node.holders["d0"][1] is False                 # no downgrade


# ---------------------------------------------------------------------------
# routing score + replication planning
# ---------------------------------------------------------------------------

class _FakeDecoder:
    def __init__(self, iid, n_active, kv=True):
        self.iid = iid
        self.active = [None] * n_active
        self.kv = object() if kv else None


def test_best_holder_trades_depth_against_queue():
    gw = Gateway(GatewayConfig(alpha=64.0), block_size=16, stats=RoutingStats())
    deep_busy = _FakeDecoder(0, n_active=4)
    shallow_idle = _FakeDecoder(1, n_active=0)
    chain = prefix_chain(0, 128, 7, 256, 16)
    gw.trie.insert(chain, deep_busy, 0.0, 16)             # holds all 16 blocks
    gw.trie.insert(chain[:2], shallow_idle, 0.0, 16)      # holds 2 blocks
    holder, node, depth, replica, score = gw.best_holder(
        chain, 1.0, live=lambda h: True)
    # 256 - 64*4 = 0 for the deep box vs 32 - 0 = 32 for the idle one
    assert holder is shallow_idle and depth == 32 and not replica
    # drown the idle box in queue depth and the deep prefix wins again
    shallow_idle.active = [None] * 8
    holder, _, depth, _, _ = gw.best_holder(chain, 2.0, live=lambda h: True)
    assert holder is deep_busy and depth == 256


def test_best_holder_drops_dead_holders_lazily():
    gw = Gateway(GatewayConfig(), block_size=16)
    d = _FakeDecoder(0, 0)
    chain = prefix_chain(0, 64, -1, 64, 16)
    gw.trie.insert(chain, d, 0.0, 16)
    assert gw.best_holder(chain, 1.0, live=lambda h: False) is None
    assert gw.trie.holders_of(chain) == []    # marking gone, not just skipped


def test_plan_replication_targets_least_loaded_non_holder():
    cfg = GatewayConfig(replicate_threshold=3, replicate_copies=2,
                        min_tokens=32)
    gw = Gateway(cfg, block_size=16)
    origin = _FakeDecoder(0, 1)
    idle = _FakeDecoder(1, 0)
    busy = _FakeDecoder(2, 5)
    chain = prefix_chain(4, 64, -1, 64, 16)
    gw.trie.insert(chain, origin, 0.0, 16)
    for k in range(3):                         # drive the window hit count
        gw.trie.lookup(chain, t=float(k))
    jobs = gw.plan_replication(chain, 3.0, [origin, busy, idle])
    assert len(jobs) == 1
    job = jobs[0]
    assert job.source is origin and job.target is idle
    assert job.tokens == 64 and job.key == ("sys", 4)
    assert gw.trie.walk(chain).pending
    # pending nodes are never re-planned until the cluster clears the flag
    assert gw.plan_replication(chain, 3.5, [origin, busy, idle]) == []


def test_plan_replication_ignores_private_and_cold_chains():
    cfg = GatewayConfig(replicate_threshold=2, min_tokens=32)
    gw = Gateway(cfg, block_size=16)
    d = _FakeDecoder(0, 0)
    private = [("sess", 1, 0), ("sess", 1, 1), ("sess", 1, 2)]
    gw.trie.insert(private, d, 0.0, 16)
    for k in range(5):
        gw.trie.lookup(private, t=float(k))
    assert gw.plan_replication(private, 5.0, [d, _FakeDecoder(1, 0)]) == []
    cold = prefix_chain(0, 64, -1, 64, 16)
    gw.trie.insert(cold, d, 0.0, 16)           # hot threshold never reached
    assert gw.plan_replication(cold, 5.0, [d, _FakeDecoder(1, 0)]) == []


# ---------------------------------------------------------------------------
# allocator refcounts under the gateway verbs (fuzz + unit)
# ---------------------------------------------------------------------------

def test_try_grow_extends_then_backpressures():
    kv = make_alloc(n_hbm=8, n_dram=0)
    kv.admit(1, 4.0)                           # 1 block
    assert kv.try_grow(1, 4.0) == 0            # already covered
    assert kv.try_grow(1, 16.0) == 3           # grown to 4 blocks
    assert kv.hard_used == 4
    kv.check()
    assert kv.try_grow(1, 100.0) is None       # OOM: backpressure, no raise
    kv.check()
    with pytest.raises(KVError):
        kv.try_grow(99, 4.0)
    kv.release(1, -1, 16, t=1.0)
    kv.check()


def test_install_is_cache_only_and_reclaimable():
    kv = make_alloc(n_hbm=8, n_dram=0)
    assert kv.install(("sys", 0), tokens=16, t=0.0)
    kv.check()
    # entry refs only: a replica never reduces admission headroom
    assert kv.hard_used == 0
    assert kv.available() == 8
    assert kv.lookup(("sys", 0), 64) == (16, "hbm")
    kv.admit(1, 32.0)                          # 8 blocks reclaim the replica
    kv.check()
    assert kv.lookup(("sys", 0), 64) == (0, "")
    kv.release(1, -1, 32, t=1.0)
    kv.check()


def test_cache_alias_shares_live_blocks_without_copying():
    kv = make_alloc(n_hbm=16, n_dram=0)
    kv.admit(1, 32.0)                          # 8 blocks live (bs=4)
    assert kv.cache_alias(("sys", 2), 1, tokens=18, t=0.0) == 16  # 4 full
    kv.check()
    assert kv.hard_used == 8                   # no extra hard refs
    assert kv.lookup(("sys", 2), 64) == (16, "hbm")
    # a pinned alias is left alone; an unpinned shorter one is replaced
    kv.pin(5, ("sys", 2), 16, t=1.0)
    assert kv.cache_alias(("sys", 2), 1, tokens=32, t=2.0) == 0
    kv.unpin(5)
    assert kv.cache_alias(("sys", 2), 1, tokens=32, t=3.0) == 32
    kv.check()
    kv.release(1, -1, 32, t=4.0)
    kv.check()


def test_allocator_fuzz_with_gateway_verbs():
    """Refcount conservation under replication + eviction: the PR 4 fuzz
    extended with the gateway verbs (sys-alias pins, ``cache_alias``,
    ``install``, ``try_grow``), double-entry audited every step."""
    rng = np.random.RandomState(1)
    kv = make_alloc(n_hbm=24, n_dram=8, bs=4)
    live: dict[int, int] = {}
    swapped: list[int] = []
    keys: list = []                            # int sids + ("sys", k) aliases
    rid = 0
    for step in range(2000):
        op = rng.randint(8)
        if op <= 1:                                   # admit (maybe pinned)
            rid += 1
            nbytes = float(rng.randint(1, 40))
            if keys and rng.rand() < 0.5:
                key = keys[rng.randint(len(keys))]
                tok, tier = kv.lookup(key, prefix_len=rng.randint(1, 64))
                if tok > 0 and tier == "hbm":
                    kv.pin(rid, key, tok, t=float(step))
            if kv.can_admit(rid, nbytes):
                kv.admit(rid, nbytes)
                live[rid] = int(rng.randint(4))
            else:
                kv.unpin(rid)
        elif op == 2 and live:                        # finish -> cache
            r = list(live)[rng.randint(len(live))]
            sid = live.pop(r)
            if rng.rand() < 0.4:                      # gateway alias first
                kv.cache_alias(("sys", int(rng.randint(3))), r,
                               tokens=int(rng.randint(1, 48)),
                               t=float(step))
            kv.release(r, sid, ctx_tokens=int(rng.randint(1, 64)),
                       t=float(step))
            if sid not in keys:
                keys.append(sid)
        elif op == 3 and live:                        # evict (recompute)
            r = list(live)[rng.randint(len(live))]
            live.pop(r)
            kv.drop(r)
        elif op == 4 and live:                        # pause (swap tier)
            r = list(live)[rng.randint(len(live))]
            live.pop(r)
            if kv.swap_out(r)[0] == "swap":
                swapped.append(r)
        elif op == 5 and swapped:                     # swap-in completes
            kv.swap_in_release(swapped.pop(rng.randint(len(swapped))))
        elif op == 6 and live:                        # lazy paging grow
            r = list(live)[rng.randint(len(live))]
            kv.try_grow(r, float(rng.randint(1, 64)))
        elif op == 7:                                 # replication landing
            key = ("sys", int(rng.randint(3)))
            if kv.install(key, tokens=int(rng.randint(1, 32)),
                          t=float(step)) and key not in keys:
                keys.append(key)
        kv.check()                                    # audit EVERY step
    for r in list(live):
        kv.release(r, live.pop(r), 16, t=9999.0)
    for r in swapped:
        kv.swap_in_release(r)
    kv.check()
    assert kv.hard_used == 0
    while kv._reclaim_one():
        kv.check()
    assert len(kv.free) == kv.cfg.n_hbm
    assert not kv.ref and not kv.hard


# ---------------------------------------------------------------------------
# shared-prefix workload knob
# ---------------------------------------------------------------------------

def test_shared_prefixes_do_not_perturb_arrivals():
    plain = generate(TRACES["azure_code"], 60.0, 8.0, seed=5,
                     session_prob=0.4)
    shared = generate(TRACES["azure_code"], 60.0, 8.0, seed=5,
                      session_prob=0.4, shared_prefix_prob=0.7)
    assert [(r.t, r.in_len, r.out_len, r.priority, r.session, r.prefix_len)
            for r in plain] \
        == [(r.t, r.in_len, r.out_len, r.priority, r.session, r.prefix_len)
            for r in shared]
    assert all(r.shared_id == -1 and r.shared_len == 0 for r in plain)


def test_shared_prefixes_deterministic_sticky_and_skewed():
    a = get_trace("azure_code", 120.0, 8.0, seed=3, session_prob=0.5,
                  shared_prefix_prob=0.6, shared_prefix_len=512,
                  shared_prefix_count=8)
    b = get_trace("azure_code", 120.0, 8.0, seed=3, session_prob=0.5,
                  shared_prefix_prob=0.6, shared_prefix_len=512,
                  shared_prefix_count=8)
    assert [(r.shared_id, r.shared_len) for r in a] \
        == [(r.shared_id, r.shared_len) for r in b]
    tagged = [r for r in a if r.shared_id >= 0]
    assert tagged, "no shared prompts drawn"
    for r in tagged:
        assert 0 <= r.shared_id < 8
        # catalog lengths are drawn in [prefix_len/2, 1.5*prefix_len]
        assert 0 < r.shared_len <= min(512 + 256, r.in_len)
    # session-sticky: every turn of a session carries the same prompt id
    by_session: dict[int, set] = {}
    for r in a:
        if r.session >= 0:
            by_session.setdefault(r.session, set()).add(r.shared_id)
    assert all(len(ids) == 1 for ids in by_session.values())
    # Zipf skew: the most popular prompt strictly dominates the least
    counts = np.bincount([r.shared_id for r in tagged], minlength=8)
    assert counts[0] == counts.max() and counts[0] > counts.min()


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

GW_E2E = dict(duration=25.0, rps=8.0, seed=0, session_prob=0.4,
              block_size=16, prefix_cache=True, gateway=True,
              kv_alloc="lazy", shared_prefix_prob=0.7,
              shared_prefix_len=1024, shared_prefix_count=2,
              preemption="pause-requeue")


def _run_gateway_cluster(engine):
    """The gateway scenario with the cluster object exposed, so tests can
    audit every decoder's allocator and the group trie after the run."""
    fleet_spec = single_pool_fleet(
        "qwen25_32b", "a100", 2, trace="azure_code", rps=GW_E2E["rps"],
        n_convertible=1, session_prob=GW_E2E["session_prob"],
        block_size=16, prefix_cache=True, gateway=True, kv_alloc="lazy",
        shared_prefix_prob=0.7, shared_prefix_len=1024,
        shared_prefix_count=2)
    spec = ExperimentSpec(fleet=fleet_spec, policy="tokenscale",
                          engine=engine, preemption="pause-requeue",
                          duration=GW_E2E["duration"], seed=0,
                          max_instances=2)
    fleet = build_fleet(spec.fleet)
    trace = build_traces(spec)
    g = fleet.groups[fleet.default_model]
    stats = trace_stats(trace)
    pol = build_policy("tokenscale", g.prefill.prof,
                       decode_prof=g.decode.prof, mean_in=stats.mean_in,
                       mean_out=stats.mean_out, n_convertible=1)
    cl = get_engine(engine)(
        fleet, policy=PerModelFleetPolicy({fleet.default_model: pol}),
        predictor=OutputPredictor(0.85, 0), preemption="pause-requeue",
        max_instances=2)
    rep = cl.run(trace, spec.duration + spec.extra_horizon)
    return cl, rep, trace


@pytest.fixture(scope="module", params=["fluid", "events"])
def gateway_cluster(request):
    return _run_gateway_cluster(request.param)


def test_gateway_counters_fire_on_both_engines(gateway_cluster):
    cl, rep, trace = gateway_cluster
    gw = rep.gw_summary()
    assert gw["affinity_hits"] > 0
    assert gw["balanced"] > 0
    assert gw["steered_tokens"] > 0
    assert gw["block_grows"] > 0
    assert gw["affinity_hits"] + gw["balanced"] <= len(trace)
    # gateway steering feeds the same hit accounting as the PR 4 path
    assert rep.kv_summary()["hit_tokens"] >= gw["steered_tokens"]


def test_gateway_invariants_hold_end_to_end(gateway_cluster):
    """After a full contended gateway run (locality routing, replication,
    lazy growth, mid-decode OOM preemption) every allocator passes the
    double-entry + no-stale-pins audit, live allocations are exactly the
    resident requests, and the group trie is structurally sound."""
    cl, rep, trace = gateway_cluster
    audited = 0
    for d in cl.decoders + cl.convertibles:
        if d.kv is None:
            continue
        d.kv.check()
        assert set(d.kv.allocs) == {r.src.rid for r in d.active}
        audited += 1
    assert audited > 0
    for g in cl.fleet.groups.values():
        assert g.gateway is not None
        g.gateway.trie.check(g.gateway.block_size)
    assert len(rep.requests) == len(trace)
    assert len(rep.requests) == len({id(r) for r in rep.requests})


def test_gateway_differential_band_holds():
    """Fluid vs events must stay inside the historical 15% band with the
    gateway enabled (locality routing + replication + lazy paging), same
    tolerance and dt as tests/test_sim_differential.py."""
    reps = compare_engines("tokenscale", "azure_conv", duration=40.0,
                           rps=6.0, seed=0, dt=0.0125, **{
                               k: v for k, v in GW_E2E.items()
                               if k not in ("duration", "rps", "seed")})
    fl, ev = reps["fluid"], reps["events"]
    assert len(fl.requests) == len(ev.requests)

    def close(a, b, abs_tol):
        return abs(a - b) <= max(0.15 * max(abs(a), abs(b)), abs_tol)

    assert close(fl.throughput(), ev.throughput(), 0.1)
    assert close(fl.mean("ttft"), ev.mean("ttft"), 0.020)
    assert close(fl.mean("tpot"), ev.mean("tpot"), 0.005)
    assert fl.gw["affinity_hits"] > 0
    assert ev.gw["affinity_hits"] > 0


def test_pool_spec_validates_gateway_knobs():
    with pytest.raises(ValueError):
        PoolSpec("d", "decode", kv_alloc="eager")
    with pytest.raises(ValueError):
        PoolSpec("d", "decode", kv_alloc="lazy")          # needs paging
    with pytest.raises(ValueError):
        PoolSpec("d", "decode", gateway=True, block_size=16)  # needs cache
    with pytest.raises(ValueError):
        PoolSpec("p", "prefill", gateway=True, block_size=16,
                 prefix_cache=True)                       # decode-side only
    PoolSpec("d", "decode", gateway=True, kv_alloc="lazy", block_size=16,
             prefix_cache=True)


def test_experiment_spec_roundtrips_gateway_knobs():
    fs = single_pool_fleet("llama31_8b", "a100", 1, block_size=16,
                           prefix_cache=True, gateway=True, kv_alloc="lazy",
                           shared_prefix_prob=0.5, shared_prefix_len=256,
                           shared_prefix_count=4)
    spec = ExperimentSpec(fleet=fs, policy="tokenscale", duration=5.0)
    back = ExperimentSpec.from_json(spec.to_json())
    assert back == spec
    dec = [p for p in back.fleet.pools if p.role == "decode"][0]
    assert (dec.gateway, dec.kv_alloc) == (True, "lazy")
    route = back.fleet.routes[0]
    assert (route.shared_prefix_prob, route.shared_prefix_len,
            route.shared_prefix_count) == (0.5, 256, 4)


def test_gateway_disabled_by_default_and_spec_stays_legacy():
    rep = run_policy("tokenscale", "azure_conv", duration=10.0, rps=4.0,
                     seed=0)
    # gateway off: raw stats stay empty, but the summary degrades to the
    # full key set with zero values (stable schema for dashboards)
    assert rep.gw == {}
    gw = rep.gw_summary()
    assert gw == RoutingStats().summary()
    assert set(gw) and all(v == 0 for v in gw.values())
    # default knobs serialize away entirely, keeping old spec JSON stable
    fs = single_pool_fleet("llama31_8b", "a100", 1)
    d = ExperimentSpec(fleet=fs, duration=5.0).to_dict()
    for pool in d["fleet"]["pools"]:
        assert "gateway" not in pool and "kv_alloc" not in pool
    for route in d["fleet"]["routes"]:
        assert "shared_prefix_prob" not in route


# ---------------------------------------------------------------------------
# golden replay + acceptance gradient
# ---------------------------------------------------------------------------

def _run_gateway_variant(variant, engine):
    """Replay one gateway cell entirely from the recorded fixture (same
    recipe as benchmarks.run.run_gateway_variant and the regenerator)."""
    g = GOLDEN_GW
    gw, alloc = g["variants"][variant]
    return run_policy("tokenscale", g["trace"], engine=engine,
                      preemption="pause-requeue",
                      session_prob=g["session_prob"],
                      block_size=g["block_size"], prefix_cache=True,
                      gateway=gw, kv_alloc=alloc, **g["shared_prefix"],
                      **g["fleet"])


@pytest.fixture(scope="module")
def gateway_reports():
    return {(eng, v): _run_gateway_variant(v, eng)
            for eng in GOLDEN_GW["engines"]
            for v in GOLDEN_GW["variants"]}


@pytest.mark.parametrize("engine", list(GOLDEN_GW["engines"]))
@pytest.mark.parametrize("variant", list(GOLDEN_GW["variants"]))
def test_gateway_matches_golden(gateway_reports, engine, variant):
    rep = gateway_reports[(engine, variant)]
    want = GOLDEN_GW["engines"][engine][variant]
    assert len(rep.requests) == want["n_requests"]
    assert rep.percentile("ttft", 99) == pytest.approx(want["ttft_p99"],
                                                       rel=0.05)
    assert rep.slo_attainment() == pytest.approx(want["slo_attainment"],
                                                 rel=0.05)
    assert rep.avg_gpus() == pytest.approx(want["avg_gpus"], rel=0.05)
    got_kv = rep.kv_summary()
    assert set(got_kv) == set(want["kv"]), (engine, variant)
    for key, expect in want["kv"].items():
        if expect is None:
            assert math.isnan(got_kv[key]), (engine, variant, key)
        else:
            assert got_kv[key] == pytest.approx(expect, rel=0.05), \
                (engine, variant, key)
    got_gw = rep.gw_summary()
    assert set(got_gw) == set(want["gw"]), (engine, variant)
    for key, expect in want["gw"].items():
        assert got_gw[key] == pytest.approx(expect, rel=0.05), \
            (engine, variant, key)


@pytest.mark.parametrize("engine", list(GOLDEN_GW["engines"]))
def test_gateway_beats_owner_steering(gateway_reports, engine):
    """The tentpole acceptance gradient: hashtrie locality routing
    strictly improves p99 TTFT over owner-steering at equal-or-lower GPU
    count, with a strictly higher prefix hit rate, on the hot-system-
    prompt session trace."""
    owner = gateway_reports[(engine, "owner")]
    gw = gateway_reports[(engine, "gateway")]
    assert gw.percentile("ttft", 99) < owner.percentile("ttft", 99)
    # equal-or-lower up to float summation noise in the GPU-second integral
    assert gw.avg_gpus() <= owner.avg_gpus() + 1e-6
    assert gw.kv_summary()["prefix_hit_rate"] \
        > owner.kv_summary()["prefix_hit_rate"]
    s = gw.gw_summary()
    assert s["affinity_hits"] > 0 and s["replications"] > 0
