"""Launcher CLIs and examples execute end-to-end (subprocess smoke)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))


def _run(args, timeout=240, env=ENV):
    r = subprocess.run([sys.executable] + args, env=env, cwd=ROOT,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-1500:])
    return r.stdout


@pytest.mark.slow
def test_train_cli(tmp_path):
    out = _run(["-m", "repro.launch.train", "--arch", "qwen2-0.5b",
                "--smoke", "--steps", "6", "--batch", "2",
                "--seq-len", "32",
                "--checkpoint", str(tmp_path / "ck")])
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["steps"] == 6
    assert rec["loss_last"] > 0
    assert (tmp_path / "ck" / "index.json").exists()


@pytest.mark.slow
def test_serve_cli():
    out = _run(["-m", "repro.launch.serve", "--arch", "llama-3.1-8b",
                "--requests", "4", "--max-new", "4", "--chunk-size", "8"])
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["completed"] == 4
    assert rec["convertible_mode"] is True


@pytest.mark.slow
def test_dryrun_cli_single_pair():
    out = _run(["-m", "repro.launch.dryrun", "--arch", "qwen2_0_5b",
                "--shape", "decode_32k"], timeout=300)
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    assert rec["bottleneck"] in ("compute", "memory", "collective")


def test_dryrun_cli_skip_reason():
    out = _run(["-m", "repro.launch.dryrun", "--arch", "yi_9b",
                "--shape", "long_500k"], timeout=300)
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["status"] == "skipped"
    assert "sub-quadratic" in rec["reason"]


@pytest.mark.parametrize("example", ["burst_absorption.py"])
def test_example_runs(example):
    _run([os.path.join("examples", example)], timeout=300)
