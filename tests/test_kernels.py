"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.ops import (decode_attention_op, prefill_attention,
                               wkv6_op)

RNG = np.random.RandomState(42)


def _rnd(*shape, dtype=np.float32):
    return jnp.asarray(RNG.randn(*shape).astype(dtype))


# ---------------------------------------------------------------------------
# chunked prefill attention
# ---------------------------------------------------------------------------

SWEEP = [
    # B, Sq, Skv, Hq, Hkv, D, window, softcap
    (1, 8, 8, 1, 1, 16, 0, 0.0),
    (2, 24, 40, 4, 2, 64, 0, 0.0),
    (2, 24, 40, 4, 2, 64, 16, 0.0),
    (2, 24, 40, 4, 2, 64, 0, 30.0),
    (1, 128, 128, 8, 8, 32, 0, 0.0),     # MHA
    (3, 17, 33, 6, 1, 64, 0, 0.0),       # MQA, ragged sizes
    (1, 256, 384, 2, 2, 128, 64, 50.0),  # gemma2-style local+softcap
]


@pytest.mark.parametrize("B,Sq,Skv,Hq,Hkv,D,window,cap", SWEEP)
def test_chunked_prefill_attention(B, Sq, Skv, Hq, Hkv, D, window, cap):
    q = _rnd(B, Sq, Hq, D)
    k = _rnd(B, Skv, Hkv, D)
    v = _rnd(B, Skv, Hkv, D)
    off = jnp.asarray(RNG.randint(0, Skv - Sq + 1, size=(B,)), jnp.int32)
    lens = jnp.asarray(RNG.randint(1, Skv + 1, size=(B,)), jnp.int32)
    out = prefill_attention(q, k, v, off, lens, window=window, softcap=cap)
    want = ref.chunked_prefill_attention_ref(q, k, v, off, lens,
                                             window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_chunked_prefill_attention_bf16():
    B, Sq, Skv, Hq, Hkv, D = 2, 16, 32, 4, 2, 64
    q = _rnd(B, Sq, Hq, D).astype(jnp.bfloat16)
    k = _rnd(B, Skv, Hkv, D).astype(jnp.bfloat16)
    v = _rnd(B, Skv, Hkv, D).astype(jnp.bfloat16)
    off = jnp.zeros((B,), jnp.int32)
    lens = jnp.full((B,), Skv, jnp.int32)
    out = prefill_attention(q, k, v, off, lens)
    want = ref.chunked_prefill_attention_ref(q, k, v, off, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=3e-2, rtol=3e-2)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    sq=st.integers(1, 24),
    extra=st.integers(0, 24),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2, 3]),
    d=st.sampled_from([8, 32]),
)
def test_prefill_attention_property(b, sq, extra, hkv, g, d):
    """Property: kernel == oracle for arbitrary (chunk, cache) geometry."""
    rng = np.random.RandomState(b * 1000 + sq * 10 + extra)
    skv = sq + extra
    hq = hkv * g
    q = jnp.asarray(rng.randn(b, sq, hq, d).astype(np.float32))
    k = jnp.asarray(rng.randn(b, skv, hkv, d).astype(np.float32))
    v = jnp.asarray(rng.randn(b, skv, hkv, d).astype(np.float32))
    off = jnp.asarray(rng.randint(0, extra + 1, size=(b,)), jnp.int32)
    lens = jnp.asarray(rng.randint(1, skv + 1, size=(b,)), jnp.int32)
    out = prefill_attention(q, k, v, off, lens)
    want = ref.chunked_prefill_attention_ref(q, k, v, off, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,L,Hq,Hkv,D,window", [
    (1, 16, 1, 1, 16, 0),
    (2, 64, 8, 2, 64, 0),
    (2, 64, 8, 2, 64, 16),
    (4, 129, 4, 1, 128, 0),     # non-multiple cache length
    (1, 512, 16, 16, 64, 0),    # MHA long-ish
])
def test_decode_attention(B, L, Hq, Hkv, D, window):
    q = _rnd(B, Hq, D)
    k = _rnd(B, L, Hkv, D)
    v = _rnd(B, L, Hkv, D)
    cur = jnp.asarray(RNG.randint(0, L, size=(B,)), jnp.int32)
    out = decode_attention_op(q, k, v, cur, window=window, block_k=32)
    want = ref.decode_attention_ref(q, k, v, cur, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_block_skipping():
    """Blocks beyond cur_len are skipped: result must not depend on garbage
    in the dead region."""
    B, L, H, D = 1, 64, 2, 32
    q = _rnd(B, H, D)
    k = _rnd(B, L, H, D)
    v = _rnd(B, L, H, D)
    cur = jnp.array([10], jnp.int32)
    out1 = decode_attention_op(q, k, v, cur, block_k=16)
    k2 = k.at[:, 20:].set(jnp.nan)
    v2 = v.at[:, 20:].set(jnp.nan)
    out2 = decode_attention_op(q, k2, v2, cur, block_k=16)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------

def _wkv_inputs(B, S, H, K, seed=0):
    rng = np.random.RandomState(seed)
    r = rng.randn(B, S, H, K).astype(np.float32)
    k = rng.randn(B, S, H, K).astype(np.float32)
    v = rng.randn(B, S, H, K).astype(np.float32)
    w = np.exp(-np.exp(rng.randn(B, S, H, K).astype(np.float32) * 0.5 - 1))
    u = rng.randn(H, K).astype(np.float32)
    s0 = rng.randn(B, H, K, K).astype(np.float32)
    return map(jnp.asarray, (r, k, v, w, u, s0))


@pytest.mark.parametrize("B,S,H,K,chunk", [
    (1, 16, 1, 8, 16),
    (2, 37, 2, 16, 16),      # padded tail
    (1, 64, 4, 32, 32),
    (2, 16, 2, 64, 8),
])
def test_wkv6(B, S, H, K, chunk):
    r, k, v, w, u, s0 = _wkv_inputs(B, S, H, K, seed=B * 100 + S)
    y, sT = wkv6_op(r, k, v, w, u, s0, chunk=chunk)
    tr = lambda x: x.transpose(0, 2, 1, 3)  # noqa: E731
    y_ref, sT_ref = ref.wkv6_ref(tr(r), tr(k), tr(v), tr(w), u, s0)
    np.testing.assert_allclose(np.asarray(tr(y)), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_ref),
                               atol=2e-4, rtol=2e-4)


def test_wkv6_state_carry_composes():
    """Running two halves with state carry == running the whole sequence
    (the chunked-prefill invariant for SSM layers)."""
    B, S, H, K = 1, 32, 2, 16
    r, k, v, w, u, s0 = _wkv_inputs(B, S, H, K, seed=7)
    y_full, sT_full = wkv6_op(r, k, v, w, u, s0)
    half = S // 2
    y1, s_mid = wkv6_op(r[:, :half], k[:, :half], v[:, :half], w[:, :half],
                        u, s0)
    y2, sT = wkv6_op(r[:, half:], k[:, half:], v[:, half:], w[:, half:],
                     u, s_mid)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_full),
                               atol=2e-4, rtol=2e-4)


def test_model_pallas_path_matches_default(monkeypatch):
    """End-to-end: REPRO_USE_PALLAS=1 reproduces the jnp model path."""
    from repro.configs import get_config
    from repro.models import forward_train, init_params
    for arch in ["llama31_8b", "gemma2_9b", "rwkv6_3b"]:
        cfg = get_config(arch, smoke=True)
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                  cfg.vocab_size)
        monkeypatch.setenv("REPRO_USE_PALLAS", "0")
        a, _ = forward_train(cfg, params, toks)
        monkeypatch.setenv("REPRO_USE_PALLAS", "1")
        b, _ = forward_train(cfg, params, toks)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)
